//! Offline stand-in for the `anyhow` crate.
//!
//! This environment vendors no external crates, so the subset of anyhow
//! the project uses — [`Result`], [`Error`], the [`anyhow!`]/[`bail!`]
//! macros, and the [`Context`] extension trait — is implemented here as
//! a message chain.  Semantics mirror the real crate where they overlap:
//! `{e}` displays the outermost message, `{e:#}` joins the whole cause
//! chain with `": "`, and `{e:?}` renders a `Caused by:` listing.
//! Swapping back to the registry crate is a one-line Cargo.toml change.

use std::fmt;

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error: outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or_default()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// Extension trait adding context to fallible results.
pub trait Context<T, E> {
    /// Wrap the error (if any) with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error (if any) with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
    }

    #[test]
    fn macros_format() {
        let n = 3;
        let e = anyhow!("bad count {n}");
        assert_eq!(format!("{e}"), "bad count 3");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(f().is_err());
    }
}
