//! CLI argument parsing.
//!
//! The offline environment vendors no `clap`; this is a small
//! subcommand + `--key value` / `--flag` parser with typed accessors,
//! shared by the `landscape` binary, the examples, and the bench
//! targets.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit token stream.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value or --key value or bare --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("bench fig3 --workers 8 --dataset kron12 --verbose");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig3"]);
        assert_eq!(a.get_u64("workers", 1), 8);
        assert_eq!(a.get_str("dataset", "x"), "kron12");
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("ingest --gamma=0.05 --k=4");
        assert!((a.get_f64("gamma", 0.0) - 0.05).abs() < 1e-12);
        assert_eq!(a.get_u64("k", 1), 4);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_u64("missing", 7), 7);
        assert_eq!(a.get_str("missing", "d"), "d");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("cmd --flag");
        assert!(a.get_bool("flag"));
    }
}
