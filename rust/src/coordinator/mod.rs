//! The main-node coordinator layer (paper §5, §6, App. E): configuration
//! types, the shard-affine work queues, the distributor threads, and the
//! tiered query engine.
//!
//! The *public* surface moved to [`crate::session`]: build a
//! [`crate::session::Landscape`] with `Landscape::builder()`, spawn
//! [`crate::session::IngestHandle`]s for N concurrent producers, and
//! query through [`crate::session::QueryHandle`].  The single-owner
//! [`Coordinator`] remains as a deprecated thin shim over one session +
//! one ingest handle so existing code keeps compiling for one release.
//!
//! Data flow (Fig. 2).  Every stage after batching is sharded by vertex
//! (`shard = hash(v) % N`, one shard per distributor thread), so a batch
//! is queued, popped, processed, and XOR-merged by the same thread and
//! the merge path never takes a global lock:
//!
//! ```text
//! producer 1 ─► IngestHandle ─┐ (thread-local levels + update log)
//! producer … ─► IngestHandle ─┤
//! producer N ─► IngestHandle ─┴► shared hypertree ──► vertex batches
//!                                                        │ (1 queue
//!             sketch shard s  ◄── XOR merge ◄── deltas ◄─┘  per shard)
//!                                            (distributor s only)
//! ```

pub mod arena;
pub(crate) mod distributor;
pub mod query;
pub mod work_queue;

use anyhow::{anyhow, Result};

use crate::connectivity::kconn::KConnectivity;
use crate::connectivity::SpanningForest;
use crate::hypertree::VertexBatch;
use crate::metrics::MetricsSnapshot;
use crate::session::{IngestHandle, Landscape, LandscapeBuilder};
use crate::sketch::params::SketchParams;
use crate::sketch::shard::ShardSpec;
use crate::stream::update::Update;
use crate::stream::GraphStream;
#[cfg(feature = "xla")]
use crate::worker::XlaWorker;
use crate::worker::{CubeWorker, NativeWorker, WorkerBackend, WorkerSeeds};

pub use crate::session::IngestReport;
pub use query::{QueryEngine, QueryTier};

/// Identifier of one logical graph multiplexed over the shared pipeline
/// (see [`crate::serve`]).  A single-tenant [`Landscape`] session is
/// tenant `0` everywhere; the serving fabric allocates ids from 1.
pub type TenantId = u32;

/// The tenant id a plain single-tenant session runs under.
pub const SOLO_TENANT: TenantId = 0;

/// Everything a distributor needs to resolve per tenant: the tenant's
/// own sketch store, epoch barrier, merge gate, metrics, and (optional)
/// write-ahead log.  A solo session has exactly one of these; the
/// serving fabric keeps one per live tenant and shares the distributor
/// pool across them.
pub(crate) struct TenantRuntime {
    pub kconn: std::sync::Arc<KConnectivity>,
    pub barrier: std::sync::Arc<work_queue::EpochBarrier>,
    pub merge_gate: std::sync::Arc<std::sync::RwLock<()>>,
    pub metrics: std::sync::Arc<crate::metrics::Metrics>,
    pub wal: Option<std::sync::Arc<crate::storage::DurabilityLog>>,
}

/// Resolve a [`TenantId`] to its runtime.  The solo session's directory
/// always answers with its single runtime; the fabric's registry
/// answers `None` for a tenant dropped while work was in flight — the
/// distributor then takes the defensive metered-drop path (unreachable
/// by construction: tenant drop settles the barrier first, see
/// `serve::TenantRegistry`).
pub(crate) trait TenantDirectory: Send + Sync {
    fn runtime(&self, tenant: TenantId) -> Option<std::sync::Arc<TenantRuntime>>;
}

/// The single-tenant directory: every lookup answers the session's one
/// runtime (the id is ignored — a solo pipeline only ever mints
/// [`SOLO_TENANT`] items), so resolution costs one `Arc` clone and the
/// solo path stays behaviorally identical to the pre-tenant code.
pub(crate) struct SoloDirectory(std::sync::Arc<TenantRuntime>);

impl SoloDirectory {
    pub(crate) fn new(runtime: std::sync::Arc<TenantRuntime>) -> Self {
        Self(runtime)
    }
}

impl TenantDirectory for SoloDirectory {
    fn runtime(&self, _tenant: TenantId) -> Option<std::sync::Arc<TenantRuntime>> {
        Some(self.0.clone())
    }
}

/// Build an in-process worker backend inside a distributor thread.
/// `WorkerKind::Remote` never comes through here — the distributor
/// builds a pipelined connection (with failover) for it instead.
pub(crate) fn build_inline_backend(
    kind: &WorkerKind,
    params: SketchParams,
    graph_seed: u64,
    k: u32,
    hybrid_threshold: u32,
) -> Result<Box<dyn WorkerBackend>> {
    let seeds = WorkerSeeds::derive(params, graph_seed, k);
    Ok(match kind {
        // only the native kernel computes exact deltas; Cube/Xla always
        // return sketch deltas and the store force-promotes cold
        // vertices on merge, so correctness never depends on the worker
        WorkerKind::Native => Box::new(NativeWorker::with_threshold(seeds, hybrid_threshold)),
        WorkerKind::Cube => Box::new(CubeWorker::new(seeds)),
        #[cfg(feature = "xla")]
        WorkerKind::Xla { artifact_dir } => Box::new(XlaWorker::load(artifact_dir, seeds)?),
        WorkerKind::Remote { .. } => {
            return Err(anyhow!("remote workers use the pipelined backend"))
        }
    })
}

/// Which delta-computation backend the distributor threads use.
#[derive(Clone, Debug, Default)]
pub enum WorkerKind {
    /// Native Rust CameoSketch kernel (the perf path).
    #[default]
    Native,
    /// CubeSketch kernel (GraphZeppelin-mode ablation).
    Cube,
    /// The AOT Pallas artifact via PJRT (three-layer composition path;
    /// needs the non-default `xla` cargo feature).
    #[cfg(feature = "xla")]
    Xla { artifact_dir: std::path::PathBuf },
    /// Remote TCP workers, round-robin over addresses.
    Remote { addrs: Vec<String> },
}

/// Which update-buffering structure the main node uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BufferKind {
    /// The pipeline hypertree (the paper's design).
    #[default]
    Hypertree,
    /// GraphZeppelin-style gutters (ablation baseline).
    Gutter,
}

/// Coordinator configuration (defaults mirror §6 / App. E).
///
/// This is the underlying knob store for
/// [`crate::session::LandscapeBuilder`]; prefer the builder, which
/// validates every field with a typed
/// [`crate::session::ConfigError`] instead of clamping or panicking.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub vertices: u64,
    pub graph_seed: u64,
    /// k-connectivity copies (1 = plain connectivity).
    pub k: u32,
    pub columns: u32,
    /// Batch-size factor α: a leaf holds α× the delta's size in updates.
    pub alpha: u32,
    /// Query-flush fullness threshold γ (paper default 4%).
    pub gamma: f64,
    pub distributor_threads: usize,
    /// Work-queue capacity in batches, *per shard queue* (one queue per
    /// distributor thread), so total buffering scales with
    /// `distributor_threads × queue_capacity`.
    pub queue_capacity: usize,
    pub worker: WorkerKind,
    /// In-flight window per remote-worker connection: how many batches a
    /// distributor keeps on the wire before submission backpressures
    /// (1 ≈ lockstep; the paper's latency-hiding regime wants ≥ 8).
    /// In-process backends complete inline and ignore this.
    pub remote_window: usize,
    pub buffer: BufferKind,
    pub use_greedycc: bool,
    /// Hybrid vertex-tier promotion threshold: a vertex stays an exact
    /// neighbor set until its set exceeds this many surviving edge
    /// indices, then promotes to a CAMEO sketch.  0 disables the hybrid
    /// tier (every vertex gets a dense sketch block up front).
    pub hybrid_threshold: u32,
    /// Demotion hysteresis floor: a promoted vertex whose tracked
    /// neighbor set shrinks below this demotes back to exact.  0 means
    /// "derive as `hybrid_threshold / 2`"; must stay strictly below the
    /// threshold (validated by the builder).
    pub hybrid_demote_floor: u32,
}

impl CoordinatorConfig {
    pub fn for_vertices(vertices: u64) -> Self {
        Self {
            vertices,
            graph_seed: 0x1A5D5CAFE,
            k: 1,
            columns: crate::sketch::params::DEFAULT_COLUMNS,
            alpha: 1,
            gamma: 0.04,
            distributor_threads: 2,
            queue_capacity: 64,
            worker: WorkerKind::Native,
            remote_window: 8,
            buffer: BufferKind::Hypertree,
            use_greedycc: true,
            hybrid_threshold: 0,
            hybrid_demote_floor: 0,
        }
    }

    /// The effective hybrid configuration: `None` when the tier is
    /// disabled, otherwise the threshold plus the (possibly derived)
    /// demotion floor.
    pub fn hybrid(&self) -> Option<crate::sketch::store::HybridConfig> {
        if self.hybrid_threshold == 0 {
            return None;
        }
        let floor = if self.hybrid_demote_floor == 0 {
            self.hybrid_threshold / 2
        } else {
            self.hybrid_demote_floor
        };
        Some(crate::sketch::store::HybridConfig {
            threshold: self.hybrid_threshold,
            floor,
        })
    }

    pub fn params(&self) -> SketchParams {
        SketchParams::with_columns(self.vertices, self.columns)
    }

    /// The vertex shard map: one sketch shard (and one shard queue) per
    /// distributor thread, so each thread merges only into storage it
    /// owns.
    pub fn shard_spec(&self) -> ShardSpec {
        ShardSpec::new(self.distributor_threads.max(1))
    }

    /// Leaf capacity in updates: α·φ scaled by k (paper §5.4).  With
    /// 4-byte batch entries, a full batch occupies α× the bytes of the
    /// delta it returns (φ = words·8 bytes → capacity = α·words·2).
    pub fn leaf_capacity(&self) -> usize {
        self.params().words() * 2 * self.alpha as usize * self.k as usize
    }
}

/// One unit of shard-affine work for a distributor thread, carrying the
/// epoch-barrier [`work_queue::Ticket`] minted when it was enqueued.
/// The ticket stays with the work through its whole asynchronous
/// lifetime (queue → submit → out-of-order completion, surviving
/// failover resubmission) and is retired exactly once at the merge or
/// the metered drop.
/// Each item also names the [`TenantId`] whose logical graph it belongs
/// to, so a shared distributor can resolve the right store/barrier pair
/// through its [`TenantDirectory`]; a solo session tags everything
/// [`SOLO_TENANT`].
pub(crate) enum WorkItem {
    /// A γ-full batch: worker backend → sketch delta → exclusive merge.
    Distribute(TenantId, work_queue::Ticket, VertexBatch),
    /// An underfull leaf at flush time: per-update local application on
    /// the shard owner (§5.3's hybrid policy — no delta overhead).
    Local(TenantId, work_queue::Ticket, VertexBatch),
}

/// The legacy single-owner facade: one session + one ingest handle
/// behind the old `&mut self` surface.
///
/// Kept for one release so the session redesign is a migration, not a
/// flag-day break.  Semantics match the old coordinator exactly (the
/// shim's handle applies query maintenance and metric folding eagerly
/// per update, so `query_plan` and the metrics are current after every
/// `ingest`, and hypertree buffering behaves exactly as before), at the
/// cost of two short uncontended mutex acquisitions per update that the
/// session API amortizes away.
#[deprecated(
    since = "0.2.0",
    note = "use `Landscape::builder()` — the session API ingests from N \
            concurrent producers and queries without `&mut`"
)]
pub struct Coordinator {
    // declared before `session`: the handle's Drop publishes its tail
    // while the distributors are still alive
    handle: IngestHandle,
    session: Landscape,
}

#[allow(deprecated)]
impl Coordinator {
    /// Build the session and its single ingest handle.  Configuration
    /// errors that the builder rejects with a typed
    /// [`crate::session::ConfigError`] surface here as `anyhow` errors.
    pub fn new(config: CoordinatorConfig) -> Result<Self> {
        let session = LandscapeBuilder::from_config(config)
            .build()
            .map_err(|e| anyhow!("invalid coordinator config: {e}"))?;
        let handle = session.shim_handle();
        Ok(Self { handle, session })
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.session.metrics()
    }

    pub fn params(&self) -> &SketchParams {
        self.session.params()
    }

    pub fn config(&self) -> &CoordinatorConfig {
        self.session.config()
    }

    /// Main-node sketch memory in bytes.
    pub fn sketch_bytes(&self) -> usize {
        self.session.sketch_bytes()
    }

    /// Ingest one stream update.
    pub fn ingest(&mut self, update: Update) {
        self.handle.ingest(update);
    }

    /// Ingest an entire stream, returning the throughput report.
    pub fn ingest_all<S: GraphStream>(&mut self, stream: S) -> IngestReport {
        self.handle.ingest_all(stream)
    }

    /// The query barrier (§5.3): publish this owner's buffered tail,
    /// flush all pending updates — γ-full leaves to workers, the rest
    /// locally — then take a stream cut and sleep until every item
    /// registered before it has merged.  As the single owner of both
    /// ingestion and queries, this is exactly the legacy "wait until
    /// the pipeline drains" semantics (nothing else can register work
    /// behind the cut).
    pub fn flush_pending(&mut self) {
        self.handle.flush();
        self.session.flush();
    }

    /// The tier that would answer [`Self::connected_components`] now.
    pub fn query_plan(&self) -> QueryTier {
        self.session.query_handle().query_plan()
    }

    /// Global connectivity query, answered by the cheapest valid tier
    /// (see [`crate::session::QueryHandle::connected_components`]).
    pub fn connected_components(&mut self) -> SpanningForest {
        self.handle.flush();
        self.session.query_handle().connected_components()
    }

    /// Force the full (flush + Borůvka) query path — tier 2.
    pub fn full_connectivity_query(&mut self) -> SpanningForest {
        self.handle.flush();
        self.session.query_handle().full_connectivity_query()
    }

    /// Batched reachability query (§5.3).
    pub fn reachability(&mut self, pairs: &[(u32, u32)]) -> Vec<bool> {
        self.handle.flush();
        self.session.query_handle().reachability(pairs)
    }

    /// k-edge-connectivity query: `Some(w)` when the min cut w < k,
    /// `None` meaning "at least k".
    pub fn k_connectivity(&mut self) -> Option<u64> {
        self.handle.flush();
        self.session.query_handle().k_connectivity()
    }

    /// Access the underlying sketch copies (benches, tests).
    pub fn kconn(&self) -> &KConnectivity {
        self.session.kconn()
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::connectivity::dsu::Dsu;
    use crate::stream::dynamify::Dynamify;
    use crate::stream::erdos::ErdosRenyi;
    use crate::stream::{edge_list, VecStream};

    fn small_config(v: u64) -> CoordinatorConfig {
        let mut c = CoordinatorConfig::for_vertices(v);
        // tiny batches so the distributed path is exercised even on
        // small test streams
        c.alpha = 1;
        c.distributor_threads = 2;
        c
    }

    fn ref_partition(v: u64, edges: &[(u32, u32)]) -> Vec<u32> {
        let mut d = Dsu::new(v as usize);
        for &(a, b) in edges {
            d.union(a, b);
        }
        d.component_map()
    }

    fn same_partition(a: &[u32], b: &[u32]) -> bool {
        let mut fwd = std::collections::HashMap::new();
        let mut bwd = std::collections::HashMap::new();
        for (x, y) in a.iter().zip(b) {
            if *fwd.entry(*x).or_insert(*y) != *y || *bwd.entry(*y).or_insert(*x) != *x {
                return false;
            }
        }
        true
    }

    #[test]
    fn shim_rejects_invalid_configs_instead_of_panicking() {
        let mut cfg = small_config(64);
        cfg.queue_capacity = 0;
        assert!(Coordinator::new(cfg).is_err(), "typed rejection, no panic");
        let cfg0 = CoordinatorConfig::for_vertices(0);
        assert!(Coordinator::new(cfg0).is_err());
    }

    #[test]
    fn end_to_end_connectivity_small_dense() {
        let v = 128u64;
        let model = ErdosRenyi::new(v, 0.15, 99);
        let want = ref_partition(v, &edge_list(&model));
        let mut coord = Coordinator::new(small_config(v)).unwrap();
        coord.ingest_all(Dynamify::new(model, 3));
        let forest = coord.connected_components();
        assert!(same_partition(&forest.component, &want));
        assert_eq!(coord.metrics().batches_dropped, 0);
    }

    #[test]
    fn greedycc_survives_insert_only_stream_without_flush() {
        let v = 64u64;
        let model = ErdosRenyi::new(v, 0.2, 5);
        let mut coord = Coordinator::new(small_config(v)).unwrap();
        coord.ingest_all(Dynamify::new(model, 1)); // inserts only
        let m_before = coord.metrics();
        let forest = coord.connected_components();
        let m_after = coord.metrics();
        // insert-only stream keeps GreedyCC valid: no full query needed
        assert_eq!(m_after.queries_full, m_before.queries_full);
        assert_eq!(m_after.queries_greedy, m_before.queries_greedy + 1);
        let want = ref_partition(v, &edge_list(&model));
        assert!(same_partition(&forest.component, &want));
    }

    #[test]
    fn forest_deletion_routes_to_partial_tier_and_recovers() {
        let v = 64u64;
        let mut coord = Coordinator::new(small_config(v)).unwrap();
        let updates = vec![
            Update::insert(0, 1),
            Update::insert(1, 2),
            Update::insert(3, 4),
            Update::delete(1, 2), // forest edge: dirties {0,1,2} only
        ];
        coord.ingest_all(VecStream::new(v, updates));
        assert_eq!(coord.query_plan(), QueryTier::Partial);
        let forest = coord.connected_components();
        let m = coord.metrics();
        assert_eq!(m.queries_partial, 1, "dirty component resolves partially");
        assert_eq!(m.queries_full, 0, "no full Borůvka needed");
        assert_eq!(m.dirty_components, 1);
        assert_eq!(m.batches_dropped, 0);
        assert!(forest.connected(0, 1));
        assert!(!forest.connected(1, 2));
        assert!(forest.connected(3, 4));
        // the partial query re-seeded the accelerator: tier 0 again
        assert_eq!(coord.query_plan(), QueryTier::Greedy);
        let _ = coord.connected_components();
        assert_eq!(coord.metrics().queries_greedy, 1);
    }

    #[test]
    fn non_forest_deletion_never_triggers_a_flush_or_boruvka() {
        let v = 32u64;
        let mut coord = Coordinator::new(small_config(v)).unwrap();
        let updates = vec![
            Update::insert(0, 1),
            Update::insert(1, 2),
            Update::insert(0, 2), // cycle edge
            Update::delete(0, 2), // non-forest delete: partition unchanged
        ];
        coord.ingest_all(VecStream::new(v, updates));
        assert_eq!(coord.query_plan(), QueryTier::Greedy);
        let forest = coord.connected_components();
        let m = coord.metrics();
        assert_eq!(m.queries_full, 0, "non-forest delete must not cost a full query");
        assert_eq!(m.queries_partial, 0, "…nor a partial one");
        assert_eq!(m.queries_greedy, 1);
        assert_eq!(m.dirty_components, 0);
        assert!(forest.connected(0, 2));
    }

    #[test]
    fn multiple_dirty_components_resolve_in_one_partial_query() {
        let v = 64u64;
        let mut coord = Coordinator::new(small_config(v)).unwrap();
        let mut updates = Vec::new();
        // three disjoint paths of 4 vertices each, plus a spare edge
        for base in [0u32, 8, 16] {
            updates.push(Update::insert(base, base + 1));
            updates.push(Update::insert(base + 1, base + 2));
            updates.push(Update::insert(base + 2, base + 3));
        }
        updates.push(Update::insert(30, 31));
        // delete a forest edge in two of the three paths
        updates.push(Update::delete(1, 2));
        updates.push(Update::delete(17, 18));
        coord.ingest_all(VecStream::new(v, updates));

        let forest = coord.connected_components();
        let m = coord.metrics();
        assert_eq!(m.queries_partial, 1);
        assert_eq!(m.dirty_components, 2);
        assert_eq!(m.batches_dropped, 0);
        // dirty paths split exactly at the deleted edges
        assert!(forest.connected(0, 1) && !forest.connected(1, 2));
        assert!(forest.connected(2, 3));
        assert!(forest.connected(16, 17) && !forest.connected(17, 18));
        // untouched components intact
        assert!(forest.connected(8, 11));
        assert!(forest.connected(30, 31));
    }

    #[test]
    fn reachability_pairs() {
        let v = 32u64;
        let mut coord = Coordinator::new(small_config(v)).unwrap();
        coord.ingest_all(VecStream::new(
            v,
            vec![Update::insert(0, 1), Update::insert(1, 2), Update::insert(4, 5)],
        ));
        let ans = coord.reachability(&[(0, 2), (0, 4), (4, 5)]);
        assert_eq!(ans, vec![true, false, true]);
    }

    #[test]
    fn communication_factor_within_theorem_bound() {
        let v = 256u64;
        let model = ErdosRenyi::new(v, 0.3, 11);
        let mut cfg = small_config(v);
        cfg.use_greedycc = false;
        let mut coord = Coordinator::new(cfg).unwrap();
        coord.ingest_all(Dynamify::new(model, 7));
        let _ = coord.full_connectivity_query();
        let m = coord.metrics();
        // In-process (Native) workers never touch the network: delta
        // bytes must not be metered as communication at all.
        assert_eq!(
            m.delta_bytes_received, 0,
            "native deltas wrongly accounted as network traffic"
        );
        // With the delta leg gone, the batch leg alone is the network
        // cost: 8B per update (4B per endpoint entry) + batch headers vs
        // 9B of stream — well under 2x, far inside the Theorem 5.2 bound
        // of (3 + 1/(gamma*alpha))x that the remote-mode test checks.
        let bound = 2.0 * m.stream_bytes as f64;
        assert!(
            (m.network_bytes() as f64) < bound,
            "network {} vs tightened bound {bound}",
            m.network_bytes()
        );
        assert_eq!(m.updates_ingested * 2, m.updates_local + distributed(&m));
        assert_eq!(m.batches_dropped, 0);
    }

    fn distributed(m: &MetricsSnapshot) -> u64 {
        // every ingested update lands exactly twice (one per endpoint):
        // either locally or in some shipped batch
        (m.batch_bytes_sent - 8 * m.batches_sent) / 4
    }

    #[test]
    fn gutter_buffer_mode_matches_hypertree_results() {
        let v = 96u64;
        let model = ErdosRenyi::new(v, 0.2, 21);
        let want = ref_partition(v, &edge_list(&model));

        let mut cfg = small_config(v);
        cfg.buffer = BufferKind::Gutter;
        let mut coord = Coordinator::new(cfg).unwrap();
        coord.ingest_all(Dynamify::new(model, 3));
        let forest = coord.connected_components();
        assert!(same_partition(&forest.component, &want));
    }

    #[test]
    fn cube_worker_mode_matches() {
        let v = 96u64;
        let model = ErdosRenyi::new(v, 0.15, 31);
        let want = ref_partition(v, &edge_list(&model));
        let mut cfg = small_config(v);
        cfg.worker = WorkerKind::Cube;
        cfg.use_greedycc = false;
        let mut coord = Coordinator::new(cfg).unwrap();
        coord.ingest_all(Dynamify::new(model, 3));
        let forest = coord.connected_components();
        assert!(same_partition(&forest.component, &want));
    }

    #[test]
    fn k_connectivity_end_to_end() {
        // two K6s joined by 2 parallel-ish edges: min cut 2 < k=3
        let v = 12u64;
        let mut edges = Vec::new();
        for a in 0..6u32 {
            for b in (a + 1)..6 {
                edges.push(Update::insert(a, b));
                edges.push(Update::insert(a + 6, b + 6));
            }
        }
        edges.push(Update::insert(0, 6));
        edges.push(Update::insert(1, 7));
        let mut cfg = small_config(v);
        cfg.k = 3;
        let mut coord = Coordinator::new(cfg).unwrap();
        coord.ingest_all(VecStream::new(v, edges));
        assert_eq!(coord.k_connectivity(), Some(2));
    }

    #[test]
    fn remote_worker_mode_end_to_end() {
        let v = 64u64;
        let model = ErdosRenyi::new(v, 0.2, 77);
        let want = ref_partition(v, &edge_list(&model));

        let server = crate::worker::remote::WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve(2));

        let mut cfg = small_config(v);
        cfg.worker = WorkerKind::Remote { addrs: vec![addr] };
        cfg.distributor_threads = 2;
        let mut coord = Coordinator::new(cfg).unwrap();
        coord.ingest_all(Dynamify::new(model, 3));
        let forest = coord.connected_components();
        assert!(same_partition(&forest.component, &want));
        let m = coord.metrics();
        assert_eq!(m.batches_dropped, 0);
        assert!(
            m.deltas_merged == 0 || m.delta_bytes_received > 0,
            "remote deltas must be metered as network traffic"
        );
        assert!(
            m.deltas_merged == 0 || m.remote_in_flight_peak >= 1,
            "pipelined submissions must be visible in the in-flight gauge"
        );
        assert_eq!(m.worker_failures, 0);
        assert_eq!(m.batches_requeued, 0);
        drop(coord); // closes connections so the server exits
        let _ = handle.join();
    }
}
