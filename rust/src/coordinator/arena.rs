//! Reusable batch-buffer arena for the `QueueSink → ShardedWorkQueue →
//! distributor` path.
//!
//! Every underfull-leaf flush used to allocate a fresh `Vec<u32>`
//! (`others.to_vec()`) that lived exactly one queue hop and died at the
//! distributor — at millions of updates per second that is a steady
//! malloc/free churn on the hot path.  The arena closes the loop:
//! [`BatchArena::acquire`] hands out a cleared buffer (reusing a
//! recycled allocation when one is pooled), the buffer rides the
//! `WorkItem` through the shard queue, crosses a worker backend inside a
//! `PendingBatch`, comes back attached to its `Completion`, and the
//! distributor returns it with [`BatchArena::recycle`] once the batch's
//! delta has merged (or the batch was dropped).
//!
//! Pools are per shard, matching the pipeline's shard-affine routing:
//! producers acquire from and the owning distributor recycles into the
//! same pool, so two distributor threads never contend on one mutex.
//!
//! **Aliasing contract:** a buffer is either *live* (owned by exactly
//! one batch in flight) or *pooled* — recycling transfers ownership into
//! the arena, so a recycled buffer can never alias a live batch.  Rust's
//! move semantics enforce this statically; as a belt-and-braces check
//! for debug builds, [`BatchArena::recycle`] overwrites the buffer's
//! contents with [`POISON`] before clearing it, so any stale read of a
//! recycled batch (e.g. through a leaked raw pointer) surfaces as an
//! obviously-wrong sentinel instead of plausible vertex ids, and
//! [`BatchArena::acquire`] debug-asserts the buffer it hands out is
//! empty.

use std::sync::Mutex;

/// Debug-build sentinel written over recycled buffer contents: any code
/// still reading a buffer after it was recycled sees this value, never a
/// plausible vertex id.
pub const POISON: u32 = 0xDEAD_BEEF;

/// Upper bound on pooled buffers per shard.  Steady state needs about
/// one buffer per queue slot plus the remote in-flight window; beyond
/// that, returning memory to the allocator beats hoarding it.
const MAX_POOLED_PER_SHARD: usize = 256;

/// A per-shard pool of recycled batch buffers (see the module docs).
pub struct BatchArena {
    pools: Vec<Mutex<Vec<Vec<u32>>>>,
}

impl BatchArena {
    /// An arena with one pool per shard.
    pub fn new(shards: usize) -> Self {
        Self {
            pools: (0..shards.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Number of per-shard pools.
    pub fn shards(&self) -> usize {
        self.pools.len()
    }

    /// Take an empty buffer for a batch bound for `shard`, reusing a
    /// recycled allocation when one is pooled.
    pub fn acquire(&self, shard: usize) -> Vec<u32> {
        let buf = self.pools[shard % self.pools.len()]
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_default();
        debug_assert!(buf.is_empty(), "arena handed out a non-empty buffer");
        buf
    }

    /// Return a batch buffer whose work is complete (delta merged,
    /// batch applied locally, or batch dropped).  The buffer's contents
    /// are dead from this point on — debug builds poison them to make
    /// any lingering alias scream.
    pub fn recycle(&self, shard: usize, mut buf: Vec<u32>) {
        if buf.capacity() == 0 {
            return; // nothing worth pooling
        }
        #[cfg(debug_assertions)]
        for w in buf.iter_mut() {
            *w = POISON;
        }
        buf.clear();
        let mut pool = self.pools[shard % self.pools.len()].lock().unwrap();
        // double-recycle detector: the same allocation entering the pool
        // twice means two owners believed they held the buffer — the
        // second "owner" is an alias of pooled (soon re-acquired) memory.
        // Checked under the pool lock so the comparison set is exact.
        #[cfg(debug_assertions)]
        assert!(
            pool.iter().all(|p| p.as_ptr() != buf.as_ptr()),
            "double-recycle: this buffer's allocation is already pooled for \
             shard {} — two owners of one batch buffer; see docs/INVARIANTS.md",
            shard % self.pools.len()
        );
        if pool.len() < MAX_POOLED_PER_SHARD {
            pool.push(buf);
        }
    }

    /// Buffers currently pooled for `shard` (test/diagnostic hook).
    pub fn pooled(&self, shard: usize) -> usize {
        self.pools[shard % self.pools.len()].lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_recycles_allocations() {
        let arena = BatchArena::new(2);
        let mut a = arena.acquire(0);
        a.extend_from_slice(&[1, 2, 3, 4]);
        let cap = a.capacity();
        arena.recycle(0, a);
        assert_eq!(arena.pooled(0), 1);
        let b = arena.acquire(0);
        assert!(b.is_empty(), "recycled buffers come back empty");
        assert_eq!(b.capacity(), cap, "the allocation itself is reused");
        assert_eq!(arena.pooled(0), 0);
    }

    #[test]
    fn pools_are_per_shard() {
        let arena = BatchArena::new(2);
        let mut a = arena.acquire(0);
        a.push(9);
        arena.recycle(0, a);
        assert_eq!(arena.pooled(0), 1);
        assert_eq!(arena.pooled(1), 0);
        // acquiring from the other shard must not steal shard 0's buffer
        let b = arena.acquire(1);
        assert_eq!(b.capacity(), 0);
        assert_eq!(arena.pooled(0), 1);
    }

    /// The no-aliasing contract: while one batch buffer is live, other
    /// acquires never return the same allocation, and a recycle followed
    /// by a re-acquire yields an *empty* buffer — never one exposing the
    /// previous batch's vertex ids.
    #[test]
    fn recycled_buffers_never_alias_live_batches() {
        let arena = BatchArena::new(1);
        let mut live = arena.acquire(0);
        live.extend_from_slice(&[7, 7, 7]);
        let live_ptr = live.as_ptr();

        // a second acquire while `live` is out must be a distinct buffer
        let mut other = arena.acquire(0);
        other.extend_from_slice(&[8, 8]);
        assert_ne!(live_ptr, other.as_ptr());
        assert_eq!(live, vec![7, 7, 7], "live batch untouched by acquires");

        arena.recycle(0, other);
        let again = arena.acquire(0);
        assert!(again.is_empty());
        assert_eq!(live, vec![7, 7, 7], "live batch untouched by recycling");
    }

    /// Debug builds poison recycled contents: if anything still reads
    /// the old allocation after recycle, it sees `POISON`, not the
    /// original data.  (Release builds skip the write; the ownership
    /// transfer is what actually enforces the contract.)
    #[test]
    #[cfg(debug_assertions)]
    fn recycle_poisons_contents_in_debug() {
        let arena = BatchArena::new(1);
        let mut buf = arena.acquire(0);
        buf.extend_from_slice(&[1, 2, 3]);
        arena.recycle(0, buf);
        let mut back = arena.acquire(0);
        assert!(back.is_empty());
        // the old elements are within the reused capacity; re-expose
        // them to prove recycle() overwrote the stale batch data.  The
        // memory was initialized by the poison writes, so this is safe.
        assert!(back.capacity() >= 3);
        unsafe { back.set_len(3) };
        assert_eq!(back, vec![POISON, POISON, POISON]);
    }

    /// The double-recycle detector: forging a second owner of a pooled
    /// allocation (via a raw-pointer alias — the only way past move
    /// semantics) must trip the debug assert instead of letting the
    /// arena hand one allocation to two future batches.  Not run under
    /// Miri (the deliberate alias is the crime being detected).
    #[test]
    #[cfg(debug_assertions)]
    fn double_recycle_is_detected_in_debug() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let arena = BatchArena::new(1);
        let mut buf = arena.acquire(0);
        buf.extend_from_slice(&[1, 2, 3]);
        // the aliasing second owner that move semantics would forbid
        let alias = unsafe { std::ptr::read(&buf) };
        arena.recycle(0, buf);
        let result = catch_unwind(AssertUnwindSafe(|| arena.recycle(0, alias)));
        assert!(result.is_err(), "second recycle of one allocation must panic");
        // the alias was freed during the unwind, so the pooled copy now
        // dangles: leak the arena rather than double-free on drop
        std::mem::forget(arena);
    }

    #[test]
    fn pool_is_bounded() {
        let arena = BatchArena::new(1);
        for _ in 0..300 {
            let mut b = Vec::with_capacity(4);
            b.push(1);
            arena.recycle(0, b);
        }
        assert!(arena.pooled(0) <= 256);
    }

    #[test]
    fn zero_capacity_buffers_are_not_pooled() {
        let arena = BatchArena::new(1);
        arena.recycle(0, Vec::new());
        assert_eq!(arena.pooled(0), 0);
    }
}
