//! The tiered QueryEngine (paper Fig. 5 / App. E.4, generalized).
//!
//! The seed design's query path was binary: either GreedyCC was valid
//! (O(V) answer) or a single forest-edge deletion forced a *full* flush
//! + sketch-Borůvka over all V vertices — so one deletion cost four
//! orders of magnitude of latency forever after.  The engine grades
//! that cliff into three tiers:
//!
//! | tier | trigger | cost |
//! |------|---------|------|
//! | 0 `Greedy`  | no dirty components | O(V) copy-out, **no flush** |
//! | 1 `Partial` | some components dirty | flush + warm-started Borůvka aggregating **only dirty-region vertices** |
//! | 2 `Full`    | accelerator disabled / forced | flush + Borůvka over all V |
//!
//! Tier 1 is sound because clean components are exact (see
//! [`GreedyCC`]): they have no crossing edges, so excluding them from
//! Borůvka's aggregation loses nothing.  After a tier-1 or tier-2 run
//! the engine re-seeds itself from the fresh forest, returning every
//! component to tier 0.
//!
//! With the hybrid vertex tier on (`LandscapeBuilder::hybrid_threshold`),
//! the tier-1/2 Borůvka runs consume cold vertices' exact neighbor sets
//! *directly* — their edges union into the DSU up front with no ℓ₀
//! decoding and no failure probability — and fall through to sketch
//! sampling only for promoted vertices (see
//! `crate::connectivity::boruvka`'s exact pre-pass).
//!
//! Locking contract: the ingest hot path (332M updates/s in the paper)
//! never locks per update.  A single exclusive owner may call
//! [`QueryEngine::on_update`] through `&mut self` and `Mutex::get_mut`
//! (a compile-time-exclusive borrow — no lock acquisition, no atomic
//! RMW); the session's concurrent ingest handles instead buffer updates
//! in bounded private logs and drain them through
//! [`QueryEngine::apply_log`], which takes the mutex **once per log**,
//! amortizing it to a fraction of a nanosecond per update.  The mutex
//! is otherwise taken only by the query-side methods, which run from
//! shared [`crate::session::QueryHandle`]s.

use std::sync::{Arc, Mutex};

use crate::connectivity::greedycc::{GreedyCC, PartialSeed};
use crate::connectivity::SpanningForest;
use crate::metrics::Metrics;
use crate::stream::update::{Update, UpdateKind};

/// Which tier would (or did) answer a connectivity query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryTier {
    /// GreedyCC answers in O(V) without touching the pipeline.
    Greedy,
    /// Warm-started Borůvka over the dirty region only.
    Partial,
    /// Flush + full sketch-Borůvka over all V vertices.
    Full,
}

/// Tiered query accelerator state shared between the ingest hot path
/// (exclusive, lock-free) and the query path (locked).
pub struct QueryEngine {
    /// `None` when the accelerator is disabled — every query is tier 2.
    greedy: Option<Mutex<GreedyCC>>,
    metrics: Arc<Metrics>,
}

impl QueryEngine {
    pub fn new(vertices: u64, enabled: bool, metrics: Arc<Metrics>) -> Self {
        Self {
            greedy: enabled.then(|| Mutex::new(GreedyCC::fresh(vertices))),
            metrics,
        }
    }

    /// Is the accelerator on at all?
    pub fn enabled(&self) -> bool {
        self.greedy.is_some()
    }

    /// Ingest hot path: track one stream update.  `&mut self` +
    /// `get_mut` makes this an uncontended plain-memory update — the
    /// mutex is not locked.
    #[inline]
    pub fn on_update(&mut self, update: &Update) {
        let Some(m) = self.greedy.as_mut() else {
            return;
        };
        // lint: allow(hot-path-unwrap) — Mutex::get_mut: same poisoning-propagation policy as lock().unwrap(), without locking
        let g = m.get_mut().unwrap();
        match update.kind {
            UpdateKind::Insert => g.on_insert(update.u, update.v),
            UpdateKind::Delete => {
                let newly = g.on_delete(update.u, update.v);
                if newly > 0 {
                    Metrics::add(&self.metrics.dirty_components, newly as u64);
                }
            }
        }
    }

    /// Multi-producer path: apply one ingest handle's drained update log
    /// under a single lock acquisition.  The per-update cost is plain
    /// memory work; the mutex is amortized over the whole chunk, which
    /// keeps GreedyCC maintenance off the cross-thread hot path (each
    /// handle logs locally and drains here only when its bounded log
    /// fills or at a flush).
    ///
    /// Logs from different handles may interleave in an order that is
    /// not a valid serialization of the original stream; [`GreedyCC`]
    /// stays sound under such reorderings by conservatively dirtying on
    /// deletes it cannot classify (see [`GreedyCC::on_delete`]).
    pub fn apply_log(&self, updates: &[Update]) {
        let Some(m) = &self.greedy else {
            return;
        };
        let mut newly = 0u64;
        {
            let mut g = m.lock().unwrap();
            for update in updates {
                match update.kind {
                    UpdateKind::Insert => g.on_insert(update.u, update.v),
                    UpdateKind::Delete => {
                        newly += g.on_delete(update.u, update.v) as u64;
                    }
                }
            }
        }
        if newly > 0 {
            Metrics::add(&self.metrics.dirty_components, newly);
        }
    }

    /// The tier that would answer a global query right now.
    pub fn plan(&self) -> QueryTier {
        match &self.greedy {
            None => QueryTier::Full,
            Some(m) => {
                if m.lock().unwrap().is_valid() {
                    QueryTier::Greedy
                } else {
                    QueryTier::Partial
                }
            }
        }
    }

    /// Tier 0: the full partition, iff every component is clean.
    pub fn try_greedy(&self) -> Option<SpanningForest> {
        self.greedy.as_ref()?.lock().unwrap().components()
    }

    /// Tier 0, reachability flavour: answers iff no queried pair touches
    /// a dirty component (clean components stay exact even while others
    /// are dirty).
    pub fn try_reachability(&self, pairs: &[(u32, u32)]) -> Option<Vec<bool>> {
        self.greedy.as_ref()?.lock().unwrap().reachability(pairs)
    }

    /// Tier 1 warm-start state: the surviving forest contracted into a
    /// DSU plus the dirty-region vertex list.  `None` when tier 0 can
    /// answer or the accelerator is off.
    pub fn partial_seed(&self) -> Option<PartialSeed> {
        self.greedy.as_ref()?.lock().unwrap().partial_seed()
    }

    /// Re-seed from a freshly computed forest (after a tier-1/2 query):
    /// every component returns to tier 0.
    pub fn reseed(&self, vertices: u64, forest: &SpanningForest) {
        if let Some(m) = &self.greedy {
            *m.lock().unwrap() = GreedyCC::from_forest(vertices, forest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(v: u64) -> QueryEngine {
        QueryEngine::new(v, true, Arc::new(Metrics::new()))
    }

    #[test]
    fn disabled_engine_always_plans_full() {
        let mut e = QueryEngine::new(16, false, Arc::new(Metrics::new()));
        assert_eq!(e.plan(), QueryTier::Full);
        e.on_update(&Update::insert(0, 1));
        assert!(e.try_greedy().is_none());
        assert!(e.partial_seed().is_none());
        assert!(e.try_reachability(&[(0, 1)]).is_none());
    }

    #[test]
    fn tier_walk_greedy_partial_greedy() {
        let mut e = engine(8);
        e.on_update(&Update::insert(0, 1));
        e.on_update(&Update::insert(1, 2));
        assert_eq!(e.plan(), QueryTier::Greedy);
        let f = e.try_greedy().unwrap();
        assert!(f.connected(0, 2));

        // non-forest delete: still tier 0
        e.on_update(&Update::insert(0, 2));
        e.on_update(&Update::delete(0, 2));
        assert_eq!(e.plan(), QueryTier::Greedy);

        // forest delete: tier 1
        e.on_update(&Update::delete(1, 2));
        assert_eq!(e.plan(), QueryTier::Partial);
        assert!(e.try_greedy().is_none());
        let seed = e.partial_seed().unwrap();
        assert_eq!(seed.dirty_components, 1);
        assert_eq!(seed.dirty_vertices, vec![0, 1, 2]);

        // a (partial or full) query re-seeds back to tier 0
        e.reseed(
            8,
            &SpanningForest {
                edges: vec![(0, 1)],
                component: vec![0, 0, 2, 3, 4, 5, 6, 7],
            },
        );
        assert_eq!(e.plan(), QueryTier::Greedy);
    }

    #[test]
    fn dirty_transitions_are_metered() {
        let metrics = Arc::new(Metrics::new());
        let mut e = QueryEngine::new(8, true, metrics.clone());
        e.on_update(&Update::insert(0, 1));
        e.on_update(&Update::insert(1, 2));
        e.on_update(&Update::insert(4, 5));
        e.on_update(&Update::delete(0, 1));
        e.on_update(&Update::delete(1, 2)); // same component: no transition
        e.on_update(&Update::delete(4, 5)); // second component dirties
        assert_eq!(metrics.snapshot().dirty_components, 2);
    }
}
