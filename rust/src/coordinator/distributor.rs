//! The Work Distributor loop (paper App. E), reworked for the pipelined
//! transport: instead of one blocking round trip per batch, the loop
//! **interleaves submission and completion** — it keeps popping work and
//! submitting it while the backend holds a window of batches in flight,
//! and XOR-merges completions whenever they surface, in whatever order
//! the worker answered them (merging commutes, so order is free).
//!
//! Failure handling: when a remote connection dies, the distributor
//! recovers every unacknowledged batch from the dead backend, reconnects
//! to the next surviving worker address, and resubmits them
//! (`batches_requeued`).  Only when *no* worker survives does it fall
//! back to PR 2's fail-fast path: close the shard queue so producers
//! take their metered drop path, and account every lost batch in
//! `batches_dropped`.
//!
//! Multi-tenancy: every work item carries a [`TenantId`], and the
//! distributor resolves the owning tenant's state (store, epoch
//! barrier, merge gate, metrics, WAL) through a [`TenantDirectory`] at
//! merge time.  Single-tenant sessions install a directory with one
//! runtime aliasing the session's own state, so the solo path is
//! behaviorally identical to the pre-tenant code.

use std::collections::HashSet;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::hypertree::VertexBatch;
use crate::metrics::Metrics;
use crate::net::tbatch2_wire_bytes;
use crate::sketch::params::{encode_edge, SketchParams};
use crate::sketch::store::TierTransitions;
use crate::sketch::CameoSketch;
use crate::storage::DurabilityLog;
use crate::worker::remote::PipelinedRemote;
use crate::worker::{Completion, InlineSubmit, PendingBatch, SubmitBackend};

use super::arena::BatchArena;
use super::work_queue::{ShardedWorkQueue, Ticket};
use super::{build_inline_backend, TenantDirectory, TenantId, TenantRuntime, WorkItem, WorkerKind};

/// Everything a distributor thread needs, bundled so the spawn site
/// stays readable.
pub(crate) struct Distributor {
    pub shard: usize,
    pub kind: WorkerKind,
    pub params: SketchParams,
    pub graph_seed: u64,
    pub k: u32,
    /// In-flight window per remote connection (inline kinds ignore it).
    pub window: usize,
    /// Hybrid vertex-tier threshold forwarded to the worker backend
    /// (HELLO field for remote, `NativeWorker::with_threshold` inline);
    /// 0 = sketch-only.
    pub hybrid_threshold: u32,
    pub queue: Arc<ShardedWorkQueue<WorkItem>>,
    /// Resolves each work item's tenant id to the owning tenant's
    /// runtime (store, epoch barrier, merge gate, metrics, WAL).  Solo
    /// sessions install a single-entry directory aliasing the session
    /// state; the multi-tenant fabric installs its registry.
    pub tenants: Arc<dyn TenantDirectory>,
    /// Session/fabric-global metrics for connection-level accounting
    /// that is not attributable to one tenant: worker failures,
    /// requeues, the in-flight peak gauge, exact framing-layer wire
    /// bytes, and resolve-miss drops.  For solo sessions this is the
    /// same object as the lone runtime's metrics.
    pub metrics: Arc<Metrics>,
    /// Shared with `QueueSink`: batch buffers are recycled here once
    /// their work completes (delta merged, applied locally, or dropped)
    /// so the producer side can reuse them instead of allocating.
    pub arena: Arc<BatchArena>,
    /// Tenant-tagged wire mode (the multi-tenant fabric sets this):
    /// remote connections frame every batch as a standalone TBATCH2 and
    /// the batch leg is metered per tenant at submit time from
    /// `tbatch2_wire_bytes` — exact in steady state (each submitted
    /// batch is one frame); a failover resubmission re-crosses the wire
    /// without re-metering the tenant, so only the fabric-global
    /// framing-layer meter counts retransmissions.  Solo sessions keep
    /// classic BATCH2/MULTIBATCH framing.
    pub tagged_wire: bool,
}

impl Distributor {
    /// The thread body.
    pub fn run(self) {
        // remote worker addresses this distributor has given up on
        let mut failed: HashSet<usize> = HashSet::new();
        let mut current_slot = 0usize;
        let mut backend = match self.build_backend(&mut failed, &mut current_slot) {
            Ok(b) => b,
            Err(e) => {
                crate::log_error!("distributor {}: backend init failed: {e:#}", self.shard);
                self.abandon_shard();
                return;
            }
        };
        let is_remote = matches!(self.kind, WorkerKind::Remote { .. });
        let mut next_token = 1u64;
        let mut scratch: Vec<Completion> = Vec::new();
        // bytes of this backend's wire writes already folded into
        // `batch_bytes_sent` (remote batches are metered byte-exactly
        // from the framing layer, not from the nominal accounting)
        let mut wire_metered = 0u64;
        self.reconcile_wire_bytes(&*backend, &mut wire_metered);

        loop {
            // 1. merge whatever has completed so far — possibly out of
            //    submission order; XOR-merging commutes
            if !self.drain_and_merge(&mut *backend, &mut scratch, false)
                && !self.failover(&mut backend, &mut failed, &mut current_slot, &mut wire_metered)
            {
                return;
            }
            self.reconcile_wire_bytes(&*backend, &mut wire_metered);

            // 2. next work item: block on the queue only when nothing is
            //    in flight, so completions never rot behind a quiet queue
            let item = if backend.in_flight() == 0 {
                match self.queue.pop(self.shard) {
                    Some(item) => item,
                    None => break, // closed and drained
                }
            } else {
                match self.queue.try_pop(self.shard) {
                    Some(item) => item,
                    None => {
                        // queue momentarily empty: push buffered frames
                        // onto the wire and wait briefly on the reader
                        if !self.drain_and_merge(&mut *backend, &mut scratch, true)
                            && !self.failover(
                                &mut backend,
                                &mut failed,
                                &mut current_slot,
                                &mut wire_metered,
                            )
                        {
                            return;
                        }
                        continue;
                    }
                }
            };

            match item {
                WorkItem::Local(tenant, ticket, batch) => {
                    self.apply_local(tenant, ticket, &batch);
                    self.arena.recycle(self.shard, batch.others);
                }
                WorkItem::Distribute(tenant, ticket, batch) => {
                    let token = next_token;
                    next_token += 1;
                    let n_others = batch.others.len();
                    // the epoch ticket rides inside the PendingBatch, so
                    // it survives window buffering, the wire, and any
                    // failover resubmission — a requeued batch retires
                    // against its ORIGINAL epoch, never the current one
                    let pending = PendingBatch {
                        tenant,
                        token,
                        ticket,
                        vertex: batch.vertex,
                        others: batch.others,
                    };
                    match backend.submit(pending) {
                        Ok(()) => {
                            if is_remote {
                                if self.tagged_wire {
                                    // per-tenant batch leg: one standalone
                                    // TBATCH2 frame per submitted batch,
                                    // so the helper is frame-exact
                                    if let Some(rt) = self.tenants.runtime(tenant) {
                                        Metrics::add(
                                            &rt.metrics.batch_bytes_sent,
                                            tbatch2_wire_bytes(n_others),
                                        );
                                    }
                                }
                                // occupancy, not in_flight(): completions
                                // awaiting drain are no longer on the wire
                                Metrics::raise(
                                    &self.metrics.remote_in_flight_peak,
                                    backend.wire_occupancy() as u64,
                                );
                            }
                        }
                        Err(e) => {
                            if backend.dead() {
                                if !self.failover(
                                    &mut backend,
                                    &mut failed,
                                    &mut current_slot,
                                    &mut wire_metered,
                                ) {
                                    return;
                                }
                            } else {
                                // per-batch computation error: the
                                // backend survives, the batch does not
                                self.drop_one(tenant, ticket);
                                crate::log_warn!("worker error (batch dropped): {e:#}");
                            }
                        }
                    }
                }
            }
        }

        // queue closed and drained: let the wire drain, then hand the
        // connection down cleanly (SHUTDOWN → BYE)
        while backend.in_flight() > 0 {
            if !self.drain_and_merge(&mut *backend, &mut scratch, true)
                && !self.failover(&mut backend, &mut failed, &mut current_slot, &mut wire_metered)
            {
                return;
            }
            self.reconcile_wire_bytes(&*backend, &mut wire_metered);
        }
        if let Err(e) = backend.finish() {
            crate::log_warn!("distributor {}: close handshake failed: {e:#}", self.shard);
        }
        self.reconcile_wire_bytes(&*backend, &mut wire_metered);
    }

    /// Fold this backend's freshly written wire bytes (exact, framing
    /// layer) into `batch_bytes_sent`.  In-process backends report 0 and
    /// keep the nominal accounting from `QueueSink`.
    fn reconcile_wire_bytes(&self, backend: &dyn SubmitBackend, metered: &mut u64) {
        let wire = backend.wire_bytes_sent();
        if wire > *metered {
            Metrics::add(&self.metrics.batch_bytes_sent, wire - *metered);
            *metered = wire;
        }
    }

    /// Drain available completions and merge them.  Returns false when
    /// the backend is dead (caller must fail over).
    fn drain_and_merge(
        &self,
        backend: &mut dyn SubmitBackend,
        scratch: &mut Vec<Completion>,
        block: bool,
    ) -> bool {
        let alive = backend.drain(scratch, block).is_ok();
        for c in scratch.drain(..) {
            self.merge(c);
        }
        alive
    }

    /// A work item named a tenant the directory cannot resolve.
    /// Unreachable by construction — tenants settle their epoch barrier
    /// (cut + wait) before unregistering, so no in-flight work can
    /// outlive its runtime — but a bug here must not panic the
    /// distributor thread: meter the drop against the global metrics
    /// (there is no tenant to charge) and keep going.  The ticket cannot
    /// be retired (its barrier is gone with the runtime).
    fn resolve_miss(&self, tenant: TenantId) {
        Metrics::add(&self.metrics.batches_dropped, 1);
        crate::log_error!(
            "distributor {}: no runtime for tenant {tenant} — batch dropped",
            self.shard
        );
    }

    /// Meter one lost batch against its tenant and retire its ticket.
    fn drop_one(&self, tenant: TenantId, ticket: Ticket) {
        match self.tenants.runtime(tenant) {
            Some(rt) => {
                Metrics::add(&rt.metrics.batches_dropped, 1);
                rt.barrier.complete(ticket);
            }
            None => self.resolve_miss(tenant),
        }
    }

    /// XOR-merge one completed delta into its tenant's shard, retire its
    /// epoch ticket, and recycle its batch buffer.
    ///
    /// Two flavors arrive: sketch deltas (`k × words` of XOR words) and,
    /// in hybrid mode, exact deltas (raw parity-reduced edge indices for
    /// a cold vertex — the same seed-independent list serves all k
    /// copies).
    fn merge(&self, c: Completion) {
        let Some(rt) = self.tenants.runtime(c.tenant) else {
            self.resolve_miss(c.tenant);
            self.arena.recycle(self.shard, c.others);
            return;
        };
        let words = self.params.words();
        let k = self.k as usize;
        // exact deltas are variable-length by design; only sketch deltas
        // carry the fixed k×words layout worth validating
        if !c.exact && c.delta.len() != words * k {
            // a protocol-corrupt delta (version-skewed worker) must not
            // panic the distributor — that would strand the barrier.
            // Treat it as a metered lost batch instead.
            crate::log_warn!(
                "distributor {}: delta for vertex {} has {} words, want {} — dropped",
                self.shard,
                c.vertex,
                c.delta.len(),
                words * k
            );
            Metrics::add(&rt.metrics.batches_dropped, 1);
            self.arena.recycle(self.shard, c.others);
            rt.barrier.complete(c.ticket);
            return;
        }
        let mut transitions = TierTransitions::default();
        {
            // batch-granular atomicity for concurrent readers: the gate
            // is uncontended except while a query is reading the store
            let _merging = rt.merge_gate.read().unwrap();
            if let Some(wal) = &rt.wal {
                // durability path (spill store, hybrid tier excluded by
                // the builder): log first, then merge stamped with the
                // record's OWN end offset — the shared watermark can
                // transiently trail other appenders, so stamping from it
                // here could tag a block past a not-yet-merged record
                // and make recovery skip that record's replay
                if !self.log_and_merge(&rt, wal, &c) {
                    Metrics::add(&rt.metrics.batches_dropped, 1);
                    self.arena.recycle(self.shard, c.others);
                    rt.barrier.complete(c.ticket);
                    return;
                }
            } else {
                for copy in 0..k {
                    let t = if c.exact {
                        rt.kconn.stores()[copy].merge_exact_delta(c.vertex, &c.delta)
                    } else {
                        let delta = &c.delta[copy * words..(copy + 1) * words];
                        // the batch's endpoint list rides along so the
                        // shadow set stays current across a sketch merge
                        rt.kconn.stores()[copy].merge_sketch_delta(c.vertex, delta, &c.others)
                    };
                    if copy == 0 {
                        // all copies mirror tier state; meter copy 0 only
                        transitions = t;
                    }
                }
            }
        }
        self.meter_transitions(&rt, transitions);
        // the endpoint buffer's work is done, recycle it for producers
        self.arena.recycle(self.shard, c.others);
        Metrics::add(&rt.metrics.deltas_merged, 1);
        if c.wire_bytes > 0 {
            // real network traffic, metered byte-exactly at the framing
            // layer (inline backends report 0 — Theorem 5.2 counts only
            // bytes that crossed a wire).  Tagged TDELTA2 frames carry
            // exactly one tenant's delta, so the per-tenant charge is
            // frame-exact too.
            Metrics::add(&rt.metrics.delta_bytes_received, c.wire_bytes);
            if c.exact {
                // compact-frame share of the delta leg (Theorem 5.2's
                // win from the hybrid tier is exactly this gap)
                Metrics::add(&rt.metrics.exact_bytes, c.wire_bytes);
            }
        }
        rt.barrier.complete(c.ticket);
        // ticket-retire scheduling point: flush this shard's delta
        // gutter past its high-water mark and evict back to the
        // resident budget (a no-op for resident backings)
        rt.kconn.maintain(self.shard);
    }

    /// Append one completion to the WAL and merge it, stamping every
    /// copy's merge with the record's **own** end offset.  Must be
    /// called with the merge gate held shared.  Returns false when the
    /// append failed — the caller takes the metered-drop path, because
    /// merging an unlogged delta would silently void the recovery
    /// contract.
    fn log_and_merge(&self, rt: &TenantRuntime, wal: &DurabilityLog, c: &Completion) -> bool {
        let words = self.params.words();
        let receipt = if c.exact {
            wal.append_exact(c.vertex, &c.delta)
        } else {
            wal.append_delta(c.vertex, &c.delta)
        };
        let a = match receipt {
            Ok(a) => a,
            Err(e) => {
                crate::log_warn!(
                    "distributor {}: WAL append failed (batch dropped): {e}",
                    self.shard
                );
                return false;
            }
        };
        Metrics::add(&rt.metrics.wal_bytes, a.bytes);
        if c.exact {
            // exact completions need the hybrid tier, which the builder
            // rejects alongside spilling — but tolerate one anyway,
            // exactly the way recovery replay would: expand the indices
            // per copy under its own seeds
            for store in rt.kconn.stores() {
                let delta = CameoSketch::delta_of_batch(store.params(), store.seeds(), &c.delta);
                store.merge_delta_logged(c.vertex, &delta, a.end);
            }
        } else {
            for (copy, store) in rt.kconn.stores().iter().enumerate() {
                let delta = &c.delta[copy * words..(copy + 1) * words];
                store.merge_delta_logged(c.vertex, delta, a.end);
            }
        }
        true
    }

    /// Fold copy-0 tier transitions into the tenant's counters.
    fn meter_transitions(&self, rt: &TenantRuntime, t: TierTransitions) {
        if t.promotions > 0 {
            Metrics::add(&rt.metrics.promotions, t.promotions);
        }
        if t.demotions > 0 {
            Metrics::add(&rt.metrics.demotions, t.demotions);
        }
    }

    /// §5.3's hybrid policy: underfull leaves apply per-update on the
    /// shard owner, no delta overhead.
    fn apply_local(&self, tenant: TenantId, ticket: Ticket, batch: &VertexBatch) {
        let Some(rt) = self.tenants.runtime(tenant) else {
            // caller recycles the buffer; the ticket's barrier is gone
            self.resolve_miss(tenant);
            return;
        };
        let v = self.params.v;
        if let Some(wal) = &rt.wal {
            // durability path: one copy-independent Exact record per
            // underfull leaf (the same compact form the network's
            // EXACTDELTA2 frames use), logged and merged under the gate
            // with the record's own end offset as the LSN
            let indices: Vec<u64> = batch
                .others
                .iter()
                .map(|&other| encode_edge(batch.vertex, other, v))
                .collect();
            let logged = {
                let _merging = rt.merge_gate.read().unwrap();
                match wal.append_exact(batch.vertex, &indices) {
                    Ok(a) => {
                        Metrics::add(&rt.metrics.wal_bytes, a.bytes);
                        for store in rt.kconn.stores() {
                            let delta = CameoSketch::delta_of_batch(
                                store.params(),
                                store.seeds(),
                                &indices,
                            );
                            store.merge_delta_logged(batch.vertex, &delta, a.end);
                        }
                        true
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "distributor {}: WAL append failed (batch dropped): {e}",
                            self.shard
                        );
                        false
                    }
                }
            };
            if logged {
                Metrics::add(&rt.metrics.updates_local, batch.others.len() as u64);
            } else {
                Metrics::add(&rt.metrics.batches_dropped, 1);
            }
            rt.barrier.complete(ticket);
            rt.kconn.maintain(self.shard);
            return;
        }
        let mut transitions = TierTransitions::default();
        {
            let _merging = rt.merge_gate.read().unwrap();
            for &other in &batch.others {
                let idx = encode_edge(batch.vertex, other, v);
                for (copy, store) in rt.kconn.stores().iter().enumerate() {
                    // ingest-path write: hybrid stores evaluate
                    // promotion/demotion here (copy 0 is metered; all
                    // copies mirror tier state)
                    let t = store.ingest_index(batch.vertex, idx);
                    if copy == 0 {
                        transitions.absorb(t);
                    }
                }
            }
        }
        self.meter_transitions(&rt, transitions);
        Metrics::add(&rt.metrics.updates_local, batch.others.len() as u64);
        rt.barrier.complete(ticket);
        rt.kconn.maintain(self.shard);
    }

    fn build_backend(
        &self,
        failed: &mut HashSet<usize>,
        current_slot: &mut usize,
    ) -> Result<Box<dyn SubmitBackend>> {
        match &self.kind {
            WorkerKind::Remote { addrs } => {
                let (slot, conn) = self.connect_remote(addrs, failed)?;
                *current_slot = slot;
                Ok(Box::new(conn))
            }
            inline => Ok(Box::new(InlineSubmit::new(build_inline_backend(
                inline,
                self.params,
                self.graph_seed,
                self.k,
                self.hybrid_threshold,
            )?))),
        }
    }

    /// Try every not-yet-failed address once, starting at this
    /// distributor's slot so distributors spread across workers.
    fn connect_remote(
        &self,
        addrs: &[String],
        failed: &mut HashSet<usize>,
    ) -> Result<(usize, PipelinedRemote)> {
        if addrs.is_empty() {
            bail!("no remote worker addresses");
        }
        for i in 0..addrs.len() {
            let slot = (self.shard + i) % addrs.len();
            if failed.contains(&slot) {
                continue;
            }
            let conn = if self.tagged_wire {
                PipelinedRemote::connect_tagged(
                    &addrs[slot],
                    self.params,
                    self.graph_seed,
                    self.k,
                    self.window,
                )
            } else {
                PipelinedRemote::connect_hybrid(
                    &addrs[slot],
                    self.params,
                    self.graph_seed,
                    self.k,
                    self.window,
                    self.hybrid_threshold,
                )
            };
            match conn {
                Ok(conn) => return Ok((slot, conn)),
                Err(e) => {
                    crate::log_warn!(
                        "distributor {}: connect {} failed: {e:#}",
                        self.shard, addrs[slot]
                    );
                    failed.insert(slot);
                }
            }
        }
        bail!("no surviving remote workers");
    }

    /// The connection died: salvage completions that already arrived,
    /// requeue every unacknowledged batch onto a surviving worker, and
    /// only if none survives abandon the shard fail-fast.  Returns true
    /// when `backend` has been replaced and work can continue.
    // the &mut Box is deliberate: on success the box itself is replaced
    #[allow(clippy::borrowed_box)]
    fn failover(
        &self,
        backend: &mut Box<dyn SubmitBackend>,
        failed: &mut HashSet<usize>,
        current_slot: &mut usize,
        wire_metered: &mut u64,
    ) -> bool {
        Metrics::add(&self.metrics.worker_failures, 1);
        failed.insert(*current_slot);
        // everything the dead backend managed to put on the wire is
        // real, metered traffic
        self.reconcile_wire_bytes(&**backend, wire_metered);
        // take the unacknowledged set FIRST: once a seq is out of the
        // pending map, a delta racing in behind it cannot complete it a
        // second time (the reader drops unknown seqs), so a batch is
        // either requeued or merged — never both, never neither.  Then
        // salvage the completions that did arrive before the death.
        let mut unacked = backend.take_unacked();
        let mut scratch = Vec::new();
        let _ = backend.drain(&mut scratch, false);
        for c in scratch.drain(..) {
            self.merge(c);
        }
        crate::log_warn!(
            "distributor {}: worker connection died with {} unacknowledged batches",
            self.shard,
            unacked.len()
        );
        let WorkerKind::Remote { addrs } = &self.kind else {
            // inline backends never report dead(); defensive
            self.drop_batches(unacked);
            self.abandon_shard();
            return false;
        };
        loop {
            let (slot, mut conn) = match self.connect_remote(addrs, failed) {
                Ok(sc) => sc,
                Err(_) => break,
            };
            let n = unacked.len() as u64;
            let mut replacement_died = false;
            // remove() one at a time (NOT drain: breaking out of a
            // Drain drops the un-iterated tail) so a mid-requeue death
            // leaves the unattempted batches still owned here
            while !unacked.is_empty() {
                let b = unacked.remove(0);
                if conn.submit(b).is_err() {
                    replacement_died = true;
                    break;
                }
            }
            if replacement_died {
                // the replacement's death is a worker failure too
                Metrics::add(&self.metrics.worker_failures, 1);
                failed.insert(slot);
                // same two-step recovery as above — the failed/pending
                // batches come back from the replacement, the
                // unattempted tail is still in `unacked` — then merge
                // whatever the short-lived replacement did answer
                let mut recovered = conn.take_unacked();
                recovered.append(&mut unacked);
                recovered.sort_by_key(|b| b.token);
                unacked = recovered;
                let _ = conn.drain(&mut scratch, false);
                for c in scratch.drain(..) {
                    self.merge(c);
                }
                self.reconcile_wire_bytes(&conn, &mut 0);
                continue;
            }
            if n > 0 {
                Metrics::add(&self.metrics.batches_requeued, n);
                crate::log_info!(
                    "distributor {}: requeued {n} batches to {}",
                    self.shard, addrs[slot]
                );
            }
            *current_slot = slot;
            // restart wire accounting for the fresh connection (meter
            // its HELLO + anything the resubmits already flushed)
            *wire_metered = 0;
            self.reconcile_wire_bytes(&conn, wire_metered);
            *backend = Box::new(conn);
            return true;
        }
        // no worker survived: everything unacknowledged is lost work
        self.drop_batches(unacked);
        self.abandon_shard();
        false
    }

    /// Meter lost batches against their tenants, retire each one's epoch
    /// ticket (so no cut waits forever on work that can no longer
    /// complete), and recycle their buffers — lost work, not lost
    /// memory.
    fn drop_batches(&self, batches: Vec<PendingBatch>) {
        for b in batches {
            self.drop_one(b.tenant, b.ticket);
            self.arena.recycle(self.shard, b.others);
        }
    }

    /// Fail-fast shard teardown (PR 2): close the shard queue first so
    /// later pushes fail immediately and take QueueSink's metered drop
    /// path instead of wedging the epoch barrier, then drain and meter
    /// what already got in — all of it is lost work, retired against
    /// whatever epoch each item was registered in.
    fn abandon_shard(&self) {
        self.queue.close_shard(self.shard);
        while let Some(item) = self.queue.pop(self.shard) {
            let (WorkItem::Distribute(tenant, ticket, batch)
            | WorkItem::Local(tenant, ticket, batch)) = item;
            self.drop_one(tenant, ticket);
            self.arena.recycle(self.shard, batch.others);
        }
    }
}
