//! The Work Queue (paper App. E.2): a bounded many-producer
//! many-consumer queue built from *two* lists and two mutex/condvar
//! pairs, so that both operations hold locks only for constant-time
//! pointer swaps — Graph Insertion threads (producers) and Work
//! Distributor threads (consumers) never contend on the same mutex
//! except at the empty↔nonempty boundary.
//!
//! [`ShardedWorkQueue`] layers the vertex shard map on top: one
//! [`WorkQueue`] per sketch shard, so each distributor thread drains its
//! own queue and merges only into its own shard — producers and the
//! merge path stay contention-free end-to-end.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Bounded MPMC queue.
pub struct WorkQueue<T> {
    /// producers append here
    producer: Mutex<VecDeque<T>>,
    /// consumers drain here, refilling by swapping with `producer`
    consumer: Mutex<VecDeque<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    closed: AtomicBool,
}

impl<T> WorkQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            producer: Mutex::new(VecDeque::new()),
            consumer: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            closed: AtomicBool::new(false),
        }
    }

    /// Blocking push (backpressure: waits while the producer list is at
    /// capacity).  Returns false if the queue has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut p = self.producer.lock().unwrap();
        while p.len() >= self.capacity {
            if self.closed.load(Ordering::Acquire) {
                return false;
            }
            p = self.not_full.wait(p).unwrap();
        }
        if self.closed.load(Ordering::Acquire) {
            return false;
        }
        p.push_back(item);
        drop(p);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop.  Returns `None` once the queue is closed *and*
    /// drained.
    pub fn pop(&self) -> Option<T> {
        loop {
            // fast path: the consumer list
            {
                let mut c = self.consumer.lock().unwrap();
                if let Some(x) = c.pop_front() {
                    return Some(x);
                }
            }
            // refill: swap the producer list in (constant-time)
            let mut p = self.producer.lock().unwrap();
            if p.is_empty() {
                if self.closed.load(Ordering::Acquire) {
                    return None;
                }
                let (guard, _timeout) = self
                    .not_empty
                    .wait_timeout(p, std::time::Duration::from_millis(50))
                    .unwrap();
                p = guard;
                if p.is_empty() {
                    continue;
                }
            }
            {
                // lock order is always producer -> consumer
                let mut c = self.consumer.lock().unwrap();
                std::mem::swap(&mut *p, &mut *c);
            }
            drop(p);
            self.not_full.notify_all();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        {
            let mut c = self.consumer.lock().unwrap();
            if let Some(x) = c.pop_front() {
                return Some(x);
            }
        }
        let mut p = self.producer.lock().unwrap();
        if p.is_empty() {
            return None;
        }
        let item = p.pop_front();
        drop(p);
        self.not_full.notify_all();
        item
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.producer.lock().unwrap().len() + self.consumer.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Counts work items from enqueue to completion and lets the query
/// barrier **sleep until the pipeline drains** instead of poll-sleeping.
///
/// The seed design's `flush_pending` spun on
/// `sleep(200µs); load(in_flight)`, which quantized every query's
/// latency to the poll interval — precisely the cost the paper's Fig. 5
/// measures in microseconds.  Here the last `complete()` call notifies a
/// condvar, so the barrier wakes within the OS scheduler's latency.
///
/// Protocol: producers call [`FlushBarrier::register`] *before* an item
/// becomes visible to a consumer and consumers call
/// [`FlushBarrier::complete`] after fully processing it (or the producer
/// calls it itself if the hand-off fails), so `pending() == 0` implies
/// every registered item has been fully processed.
///
/// With the pipelined remote transport an item stays registered across
/// its whole asynchronous lifetime: queued → submitted on the wire →
/// completed out of order → XOR-merged.  `complete()` fires only at the
/// merge (or at the metered drop if the batch is lost after failover
/// exhausts every worker), so the barrier transparently counts remote
/// in-flight batches and `wait_idle()` still means "every update has
/// reached a sketch".
#[derive(Debug, Default)]
pub struct FlushBarrier {
    pending: AtomicU64,
    lock: Mutex<()>,
    idle: Condvar,
}

impl FlushBarrier {
    pub fn new() -> Self {
        Self::default()
    }

    /// Account one in-flight work item.
    #[inline]
    pub fn register(&self) {
        self.pending.fetch_add(1, Ordering::AcqRel);
    }

    /// Mark one work item fully processed; wakes the barrier when the
    /// count reaches zero.
    #[inline]
    pub fn complete(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // take the lock so the notify can't slip between a waiter's
            // count check and its wait()
            let _guard = self.lock.lock().unwrap();
            self.idle.notify_all();
        }
    }

    /// Currently in-flight items.
    #[inline]
    pub fn pending(&self) -> u64 {
        self.pending.load(Ordering::Acquire)
    }

    /// Block until every registered item has completed.
    pub fn wait_idle(&self) {
        if self.pending() == 0 {
            return;
        }
        let mut guard = self.lock.lock().unwrap();
        while self.pending() != 0 {
            // the condvar delivers the wake-up; the timeout is pure
            // defense-in-depth against a notify bug and does NOT restore
            // liveness if a consumer dies holding an uncompleted item —
            // consumers must complete() every registered item on every
            // exit path (the coordinator closes a shard's queue before
            // abandoning it so producers take their drop path instead)
            let (g, _timeout) = self
                .idle
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
    }
}

/// One bounded [`WorkQueue`] per sketch shard (see
/// [`crate::sketch::shard::ShardSpec`]): batches are pushed to the queue
/// of the shard owning their vertex, and distributor thread `s` pops
/// exclusively from queue `s`.
pub struct ShardedWorkQueue<T> {
    queues: Vec<WorkQueue<T>>,
}

impl<T> ShardedWorkQueue<T> {
    /// `shards` queues of `capacity` items each.
    pub fn new(shards: usize, capacity: usize) -> Self {
        assert!(shards > 0);
        Self {
            queues: (0..shards).map(|_| WorkQueue::new(capacity)).collect(),
        }
    }

    /// Number of shard queues (= distributor threads).
    pub fn shards(&self) -> usize {
        self.queues.len()
    }

    /// Blocking push onto shard `shard`'s queue; false once closed.
    pub fn push(&self, shard: usize, item: T) -> bool {
        self.queues[shard].push(item)
    }

    /// Blocking pop from shard `shard`'s queue; `None` once closed and
    /// drained.
    pub fn pop(&self, shard: usize) -> Option<T> {
        self.queues[shard].pop()
    }

    /// Non-blocking pop from shard `shard`'s queue.
    pub fn try_pop(&self, shard: usize) -> Option<T> {
        self.queues[shard].try_pop()
    }

    /// Close every shard queue.
    pub fn close(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    /// Close a single shard's queue (e.g. its distributor cannot serve
    /// it): subsequent pushes to this shard fail fast instead of
    /// enqueueing work nobody will pop, letting the producer take its
    /// metered drop path.  Other shards keep running.
    pub fn close_shard(&self, shard: usize) {
        self.queues[shard].close();
    }

    /// Items queued across all shards (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = WorkQueue::new(16);
        for i in 0..10 {
            assert!(q.push(i));
        }
        for i in 0..10 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_none() {
        let q = WorkQueue::new(4);
        q.push(1);
        q.push(2);
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert!(!q.push(3), "push after close must fail");
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let q = Arc::new(WorkQueue::new(8));
        let producers = 3;
        let consumers = 3;
        let per_producer = 2000u64;

        let mut handles = Vec::new();
        for p in 0..producers {
            let q2 = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    assert!(q2.push(p * per_producer + i));
                }
            }));
        }
        let mut consumers_h = Vec::new();
        for _ in 0..consumers {
            let q2 = q.clone();
            consumers_h.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(x) = q2.pop() {
                    got.push(x);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for h in consumers_h {
            all.extend(h.join().unwrap());
        }
        all.sort_unstable();
        let want: Vec<u64> = (0..producers * per_producer).collect();
        assert_eq!(all, want);
    }

    #[test]
    fn sharded_queues_are_independent() {
        let q: ShardedWorkQueue<u64> = ShardedWorkQueue::new(4, 2);
        assert_eq!(q.shards(), 4);
        for shard in 0..4 {
            assert!(q.push(shard, shard as u64 * 10));
            assert!(q.push(shard, shard as u64 * 10 + 1));
        }
        assert_eq!(q.len(), 8);
        // each shard pops only its own items, in FIFO order
        for shard in 0..4 {
            assert_eq!(q.try_pop(shard), Some(shard as u64 * 10));
            assert_eq!(q.try_pop(shard), Some(shard as u64 * 10 + 1));
            assert_eq!(q.try_pop(shard), None);
        }
        assert!(q.is_empty());
        q.close();
        assert!(!q.push(0, 9), "push after close must fail");
        assert_eq!(q.pop(1), None);
    }

    #[test]
    fn sharded_full_shard_does_not_block_others() {
        let q: Arc<ShardedWorkQueue<u64>> = Arc::new(ShardedWorkQueue::new(2, 1));
        assert!(q.push(0, 1)); // shard 0 now at capacity
        let q2 = q.clone();
        let other = std::thread::spawn(move || q2.push(1, 2));
        assert!(other.join().unwrap(), "shard 1 must accept while 0 is full");
        assert_eq!(q.try_pop(1), Some(2));
        assert_eq!(q.try_pop(0), Some(1));
    }

    #[test]
    fn close_shard_fails_only_that_shards_pushes() {
        let q: ShardedWorkQueue<u64> = ShardedWorkQueue::new(2, 4);
        assert!(q.push(0, 1));
        q.close_shard(0);
        assert!(!q.push(0, 2), "closed shard must reject pushes");
        assert!(q.push(1, 3), "other shards keep accepting");
        // closed shard still drains what got in before the close
        assert_eq!(q.pop(0), Some(1));
        assert_eq!(q.pop(0), None);
        assert_eq!(q.try_pop(1), Some(3));
    }

    #[test]
    fn flush_barrier_wait_idle_returns_immediately_when_idle() {
        let b = FlushBarrier::new();
        b.wait_idle(); // must not hang
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flush_barrier_blocks_until_all_complete() {
        let b = Arc::new(FlushBarrier::new());
        let n = 64u64;
        for _ in 0..n {
            b.register();
        }
        let b2 = b.clone();
        let completer = std::thread::spawn(move || {
            for _ in 0..n {
                std::thread::yield_now();
                b2.complete();
            }
        });
        b.wait_idle();
        assert_eq!(b.pending(), 0);
        completer.join().unwrap();
    }

    #[test]
    fn flush_barrier_many_waiters_all_wake() {
        let b = Arc::new(FlushBarrier::new());
        b.register();
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let b2 = b.clone();
                std::thread::spawn(move || b2.wait_idle())
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(10));
        b.complete();
        for w in waiters {
            w.join().unwrap();
        }
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(WorkQueue::new(2));
        q.push(1);
        q.push(2);
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push(3));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!pusher.is_finished(), "push should block at capacity");
        assert_eq!(q.pop(), Some(1));
        assert!(pusher.join().unwrap());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }
}
