//! The Work Queue (paper App. E.2) and the epoch-based cut barrier
//! behind every query's consistency guarantee.
//!
//! [`WorkQueue`] is a bounded many-producer many-consumer queue built
//! from *two* lists and two mutex/condvar pairs, so that both
//! operations hold locks only for constant-time pointer swaps — Graph
//! Insertion threads (producers) and Work Distributor threads
//! (consumers) never contend on the same mutex except at the
//! empty↔nonempty boundary.
//!
//! [`ShardedWorkQueue`] layers the vertex shard map on top: one
//! [`WorkQueue`] per sketch shard, so each distributor thread drains its
//! own queue and merges only into its own shard — producers and the
//! merge path stay contention-free end-to-end.
//!
//! [`EpochBarrier`] is the read-side consistency primitive: instead of
//! waiting for an instant of *global* pipeline idleness (the retired
//! `FlushBarrier` design, which under sustained full-rate ingest could
//! wait indefinitely for a lull), a query takes a **cut** — an explicit
//! stream boundary in the style of GraphZeppelin's flush points — and
//! waits only for the work items registered *before* that cut.  Work
//! registered after the cut never extends the wait, so query latency is
//! bounded by the in-flight window at cut time, not by stream length.

#![deny(missing_docs)]

#[cfg(debug_assertions)]
use std::collections::HashSet;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};

/// Bounded MPMC queue.
pub struct WorkQueue<T> {
    /// producers append here
    producer: Mutex<VecDeque<T>>,
    /// consumers drain here, refilling by swapping with `producer`
    consumer: Mutex<VecDeque<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    closed: AtomicBool,
}

impl<T> WorkQueue<T> {
    /// A queue holding at most `capacity` items (> 0) on the producer
    /// side.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            producer: Mutex::new(VecDeque::new()),
            consumer: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            closed: AtomicBool::new(false),
        }
    }

    /// Blocking push (backpressure: waits while the producer list is at
    /// capacity).  If the queue has been closed the item is handed back
    /// as `Err(item)` so the caller can reclaim any resources it carries
    /// (the session sink recycles the rejected batch's buffer into the
    /// [`crate::coordinator::arena::BatchArena`]).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut p = self.producer.lock().unwrap();
        while p.len() >= self.capacity {
            if self.closed.load(Ordering::Acquire) {
                return Err(item);
            }
            p = self.not_full.wait(p).unwrap();
        }
        if self.closed.load(Ordering::Acquire) {
            return Err(item);
        }
        p.push_back(item);
        drop(p);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop.  Returns `None` once the queue is closed *and*
    /// drained.
    pub fn pop(&self) -> Option<T> {
        loop {
            // fast path: the consumer list
            {
                let mut c = self.consumer.lock().unwrap();
                if let Some(x) = c.pop_front() {
                    return Some(x);
                }
            }
            // refill: swap the producer list in (constant-time)
            let mut p = self.producer.lock().unwrap();
            if p.is_empty() {
                if self.closed.load(Ordering::Acquire) {
                    return None;
                }
                let (guard, _timeout) = self
                    .not_empty
                    .wait_timeout(p, std::time::Duration::from_millis(50))
                    .unwrap();
                p = guard;
                if p.is_empty() {
                    continue;
                }
            }
            {
                // lock order is always producer -> consumer
                let mut c = self.consumer.lock().unwrap();
                std::mem::swap(&mut *p, &mut *c);
            }
            drop(p);
            self.not_full.notify_all();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        {
            let mut c = self.consumer.lock().unwrap();
            if let Some(x) = c.pop_front() {
                return Some(x);
            }
        }
        let mut p = self.producer.lock().unwrap();
        if p.is_empty() {
            return None;
        }
        let item = p.pop_front();
        drop(p);
        self.not_full.notify_all();
        item
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.producer.lock().unwrap().len() + self.consumer.lock().unwrap().len()
    }

    /// Whether the queue currently holds no items (approximate under
    /// concurrency).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One in-flight work item's registration, stamped with the epoch it
/// was registered in.
///
/// A ticket is minted by [`EpochBarrier::register`] *before* the item
/// becomes visible to a consumer, travels with the item through the
/// shard queues, the submit/drain transport, and — crucially — any
/// failover resubmission (a requeued batch keeps its original ticket,
/// hence its original epoch), and is retired exactly once by
/// [`EpochBarrier::complete`] when the item's delta has merged (or the
/// item is accounted as a metered drop).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket {
    epoch: u64,
    /// Debug-only process-unique registration id, the key of the
    /// barrier's live-ticket set: [`EpochBarrier::complete`] panics on a
    /// second retirement of the same id, and dropping a barrier with
    /// live ids panics (a registered item was abandoned without its
    /// drop-path completion).  See docs/INVARIANTS.md.
    #[cfg(debug_assertions)]
    id: u64,
}

impl Ticket {
    /// The epoch this ticket's work item was registered in.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// A stream cut taken by [`EpochBarrier::cut`]: the boundary between
/// everything registered before it and everything after.
///
/// Pass it to [`EpochBarrier::wait_for`] to block until every ticket
/// registered before this cut has completed.  `Cut` is `Copy` and can
/// be held arbitrarily long: waiting on an already-retired cut returns
/// immediately.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cut {
    epoch: u64,
}

impl Cut {
    /// The last epoch this cut covers (every ticket with
    /// `ticket.epoch() <= cut.epoch()` is inside the cut).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

/// Per-epoch registration accounting behind the [`EpochBarrier`].
#[derive(Debug)]
struct EpochState {
    /// Epoch number of `outstanding[0]`.  Every epoch below `low` has
    /// fully retired — this is the barrier's monotone low-watermark.
    low: u64,
    /// Unretired ticket counts for epochs `low ..= low + len - 1`;
    /// never empty (the last slot is the currently open epoch).
    outstanding: VecDeque<u64>,
    /// Debug-only ids of every registered-but-unretired ticket.
    #[cfg(debug_assertions)]
    live: HashSet<u64>,
    /// Debug-only next registration id.
    #[cfg(debug_assertions)]
    next_id: u64,
}

impl EpochState {
    /// The currently open epoch (the one `register` stamps).
    fn current(&self) -> u64 {
        self.low + self.outstanding.len() as u64 - 1
    }

    /// Pop fully-retired *closed* epochs off the front, advancing the
    /// low-watermark.  The open epoch is never popped, so `outstanding`
    /// stays non-empty.  Returns true if the watermark moved.
    fn advance(&mut self) -> bool {
        let mut moved = false;
        while self.outstanding.len() > 1 && self.outstanding[0] == 0 {
            self.outstanding.pop_front();
            self.low += 1;
            moved = true;
        }
        moved
    }
}

/// The epoch-based cut barrier: lets a query wait for **a consistent
/// cut of the stream** instead of an instant of global pipeline
/// idleness.
///
/// Protocol: a producer calls [`EpochBarrier::register`] *before* an
/// item becomes visible to a consumer and keeps the returned [`Ticket`]
/// with the item; the consumer calls [`EpochBarrier::complete`] with
/// that ticket after fully processing it (or the producer does, if the
/// hand-off fails).  With the pipelined remote transport an item stays
/// registered across its whole asynchronous lifetime: queued →
/// submitted on the wire → completed out of order → XOR-merged; on
/// worker failover a resubmitted batch carries its *original* ticket.
/// `complete` fires only at the merge (or at the metered drop once
/// failover exhausts every worker).
///
/// A reader calls [`EpochBarrier::cut`] to close the current epoch and
/// open a new one, then [`EpochBarrier::wait_for`] to block until every
/// ticket registered before the cut has retired.  Items registered
/// *after* the cut land in later epochs and never extend the wait, so
/// the wait is bounded by the work in flight at cut time — under
/// sustained full-rate multi-producer ingest a query still returns
/// promptly.
///
/// Soundness under out-of-order completion: retirement is tracked as a
/// **per-epoch outstanding count** plus a monotone low-watermark over
/// fully-retired epochs.  A single registered/completed counter pair
/// would be unsound here — a completion for an old epoch and a fresh
/// registration for the open epoch are indistinguishable to a pair of
/// global counters, so a "cut" read off them could report an old epoch
/// drained while one of its items is still on the wire.  Completing
/// each ticket against its own epoch makes the watermark advance only
/// when an epoch is *actually* empty, no matter how completions
/// interleave across cuts.
///
/// Like its `FlushBarrier` predecessor, the last `complete` of an epoch
/// notifies a condvar, so waiters wake within the OS scheduler's
/// latency rather than a poll interval (the cost the paper's Fig. 5
/// measures in microseconds).
///
/// Cost model: `register`/`complete` take one short mutex each — **per
/// batch**, never per update.  A batch carries O(leaf-capacity)
/// updates (hundreds at paper parameters) and its delta costs a full
/// hashing pass, so the lock amortizes to well under a nanosecond per
/// update and the per-update ingest path stays lock-free exactly as
/// before.  The predecessor's lock-free `fetch_add` pair cannot
/// express per-epoch counts (see above); if this mutex ever surfaces
/// in profiles, an atomic fast path for the open epoch folded in at
/// `cut()` is the next step.
#[derive(Debug)]
pub struct EpochBarrier {
    state: Mutex<EpochState>,
    retired: Condvar,
}

impl Default for EpochBarrier {
    fn default() -> Self {
        Self::new()
    }
}

impl EpochBarrier {
    /// A fresh barrier at epoch 0 with nothing registered.
    pub fn new() -> Self {
        Self {
            state: Mutex::new(EpochState {
                low: 0,
                outstanding: VecDeque::from([0]),
                #[cfg(debug_assertions)]
                live: HashSet::new(),
                #[cfg(debug_assertions)]
                next_id: 0,
            }),
            retired: Condvar::new(),
        }
    }

    /// Account one in-flight work item, returning its ticket (stamped
    /// with the currently open epoch).
    pub fn register(&self) -> Ticket {
        let mut st = self.state.lock().unwrap();
        // lint: allow(hot-path-unwrap) — `outstanding` is never empty by the EpochState invariant (the open epoch always has a slot)
        *st.outstanding.back_mut().unwrap() += 1;
        #[cfg(debug_assertions)]
        let id = {
            let id = st.next_id;
            st.next_id += 1;
            st.live.insert(id);
            id
        };
        Ticket {
            epoch: st.current(),
            #[cfg(debug_assertions)]
            id,
        }
    }

    /// Retire one work item against the epoch it was registered in.
    /// Wakes waiters when this was the last outstanding item of the
    /// oldest unretired epoch (the low-watermark advances).
    pub fn complete(&self, ticket: Ticket) {
        let mut st = self.state.lock().unwrap();
        #[cfg(debug_assertions)]
        if !st.live.remove(&ticket.id) {
            panic!(
                "ticket-retire-exactly-once violation: second complete() of \
                 ticket id {} (epoch {}) — a batch's drop path and its merge \
                 path both retired it; see docs/INVARIANTS.md",
                ticket.id, ticket.epoch
            );
        }
        if ticket.epoch < st.low {
            // a second complete() for an already-retired epoch would
            // corrupt a *later* epoch's count; refuse it loudly instead
            if cfg!(debug_assertions) {
                panic!("double-complete of ticket in epoch {}", ticket.epoch);
            }
            crate::log_warn!(
                "epoch barrier: ignoring complete() for already-retired epoch {}",
                ticket.epoch
            );
            return;
        }
        let idx = (ticket.epoch - st.low) as usize;
        debug_assert!(st.outstanding[idx] > 0, "complete() without register()");
        st.outstanding[idx] = st.outstanding[idx].saturating_sub(1);
        if idx == 0 && st.advance() {
            drop(st);
            self.retired.notify_all();
        }
    }

    /// Close the current epoch and open a new one, returning the cut
    /// token covering everything registered so far.  Cheap (no
    /// waiting): the expensive half is [`EpochBarrier::wait_for`].
    pub fn cut(&self) -> Cut {
        let mut st = self.state.lock().unwrap();
        let epoch = st.current();
        st.outstanding.push_back(0);
        // an already-empty closed epoch retires on the spot, so a cut
        // taken on an idle pipeline is immediately waitable-for
        if st.advance() {
            drop(st);
            self.retired.notify_all();
        }
        Cut { epoch }
    }

    /// Block until every ticket registered before `cut` has completed.
    /// Returns immediately if the cut has already retired; never blocks
    /// on work registered after the cut.
    pub fn wait_for(&self, cut: Cut) {
        let mut st = self.state.lock().unwrap();
        while st.low <= cut.epoch {
            // the condvar delivers the wake-up; the timeout is pure
            // defense-in-depth against a notify bug and does NOT restore
            // liveness if a consumer dies holding an uncompleted ticket —
            // consumers must complete() every registered ticket on every
            // exit path (the coordinator closes a shard's queue before
            // abandoning it so producers take their drop path instead)
            let (guard, _timeout) = self
                .retired
                .wait_timeout(st, std::time::Duration::from_millis(50))
                .unwrap();
            st = guard;
        }
    }

    /// The currently open epoch number (monotone; feeds the
    /// `epoch_current` metric).
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().current()
    }

    /// Total unretired tickets across all epochs (approximate the
    /// instant the lock is released; diagnostics and tests).
    pub fn pending(&self) -> u64 {
        self.state.lock().unwrap().outstanding.iter().sum()
    }

    /// Compatibility shim for the retired `FlushBarrier::wait_idle`:
    /// take a cut *now* and wait for it.  For a single-owner caller
    /// (the deprecated `Coordinator`, which never races its own
    /// ingestion against its queries) this is exactly the old "wait
    /// until the pipeline drains"; concurrent producers registering
    /// after the call no longer extend the wait — which is the fix, not
    /// a regression.
    #[deprecated(
        since = "0.3.0",
        note = "take an explicit `cut()` and `wait_for` it — idle-waiting \
                was unbounded under sustained concurrent ingest"
    )]
    pub fn wait_idle(&self) {
        self.wait_for(self.cut());
    }
}

/// Debug-only leaked-ticket detector: a barrier dropped while tickets
/// are still live means some registered work item was abandoned without
/// its drop-path `complete()` — the next `wait_for` on such a barrier
/// would have hung forever.  Skipped mid-unwind (the leak is usually a
/// casualty of the original panic, which must stay the headline) and on
/// a poisoned mutex (same situation).
#[cfg(debug_assertions)]
impl Drop for EpochBarrier {
    fn drop(&mut self) {
        if std::thread::panicking() {
            return;
        }
        if let Ok(st) = self.state.get_mut() {
            if !st.live.is_empty() {
                panic!(
                    "epoch barrier dropped with {} live ticket(s): every \
                     register() must be matched by exactly one complete() on \
                     every exit path — see docs/INVARIANTS.md",
                    st.live.len()
                );
            }
        }
    }
}

/// One bounded [`WorkQueue`] per sketch shard (see
/// [`crate::sketch::shard::ShardSpec`]): batches are pushed to the queue
/// of the shard owning their vertex, and distributor thread `s` pops
/// exclusively from queue `s`.
pub struct ShardedWorkQueue<T> {
    queues: Vec<WorkQueue<T>>,
}

impl<T> ShardedWorkQueue<T> {
    /// `shards` queues of `capacity` items each.
    pub fn new(shards: usize, capacity: usize) -> Self {
        assert!(shards > 0);
        Self {
            queues: (0..shards).map(|_| WorkQueue::new(capacity)).collect(),
        }
    }

    /// Number of shard queues (= distributor threads).
    pub fn shards(&self) -> usize {
        self.queues.len()
    }

    /// Blocking push onto shard `shard`'s queue; once the shard is
    /// closed the item comes back as `Err(item)` (see
    /// [`WorkQueue::push`]).
    pub fn push(&self, shard: usize, item: T) -> Result<(), T> {
        self.queues[shard].push(item)
    }

    /// Blocking pop from shard `shard`'s queue; `None` once closed and
    /// drained.
    pub fn pop(&self, shard: usize) -> Option<T> {
        self.queues[shard].pop()
    }

    /// Non-blocking pop from shard `shard`'s queue.
    pub fn try_pop(&self, shard: usize) -> Option<T> {
        self.queues[shard].try_pop()
    }

    /// Close every shard queue.
    pub fn close(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    /// Close a single shard's queue (e.g. its distributor cannot serve
    /// it): subsequent pushes to this shard fail fast instead of
    /// enqueueing work nobody will pop, letting the producer take its
    /// metered drop path.  Other shards keep running.
    pub fn close_shard(&self, shard: usize) {
        self.queues[shard].close();
    }

    /// Items queued across all shards (approximate under concurrency).
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Whether every shard queue is empty (approximate under
    /// concurrency).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_single_thread() {
        let q = WorkQueue::new(16);
        for i in 0..10 {
            assert!(q.push(i).is_ok());
        }
        for i in 0..10 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_none() {
        let q = WorkQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.push(3), Err(3), "push after close hands the item back");
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let q = Arc::new(WorkQueue::new(8));
        let producers = 3;
        let consumers = 3;
        let per_producer = 2000u64;

        let mut handles = Vec::new();
        for p in 0..producers {
            let q2 = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    assert!(q2.push(p * per_producer + i).is_ok());
                }
            }));
        }
        let mut consumers_h = Vec::new();
        for _ in 0..consumers {
            let q2 = q.clone();
            consumers_h.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(x) = q2.pop() {
                    got.push(x);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for h in consumers_h {
            all.extend(h.join().unwrap());
        }
        all.sort_unstable();
        let want: Vec<u64> = (0..producers * per_producer).collect();
        assert_eq!(all, want);
    }

    #[test]
    fn sharded_queues_are_independent() {
        let q: ShardedWorkQueue<u64> = ShardedWorkQueue::new(4, 2);
        assert_eq!(q.shards(), 4);
        for shard in 0..4 {
            assert!(q.push(shard, shard as u64 * 10).is_ok());
            assert!(q.push(shard, shard as u64 * 10 + 1).is_ok());
        }
        assert_eq!(q.len(), 8);
        // each shard pops only its own items, in FIFO order
        for shard in 0..4 {
            assert_eq!(q.try_pop(shard), Some(shard as u64 * 10));
            assert_eq!(q.try_pop(shard), Some(shard as u64 * 10 + 1));
            assert_eq!(q.try_pop(shard), None);
        }
        assert!(q.is_empty());
        q.close();
        assert_eq!(q.push(0, 9), Err(9), "push after close hands the item back");
        assert_eq!(q.pop(1), None);
    }

    #[test]
    fn sharded_full_shard_does_not_block_others() {
        let q: Arc<ShardedWorkQueue<u64>> = Arc::new(ShardedWorkQueue::new(2, 1));
        assert!(q.push(0, 1).is_ok()); // shard 0 now at capacity
        let q2 = q.clone();
        let other = std::thread::spawn(move || q2.push(1, 2));
        assert!(
            other.join().unwrap().is_ok(),
            "shard 1 must accept while 0 is full"
        );
        assert_eq!(q.try_pop(1), Some(2));
        assert_eq!(q.try_pop(0), Some(1));
    }

    #[test]
    fn close_shard_fails_only_that_shards_pushes() {
        let q: ShardedWorkQueue<u64> = ShardedWorkQueue::new(2, 4);
        assert!(q.push(0, 1).is_ok());
        q.close_shard(0);
        assert_eq!(q.push(0, 2), Err(2), "closed shard must reject pushes");
        assert!(q.push(1, 3).is_ok(), "other shards keep accepting");
        // closed shard still drains what got in before the close
        assert_eq!(q.pop(0), Some(1));
        assert_eq!(q.pop(0), None);
        assert_eq!(q.try_pop(1), Some(3));
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(WorkQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push(3));
        std::thread::sleep(Duration::from_millis(20));
        assert!(!pusher.is_finished(), "push should block at capacity");
        assert_eq!(q.pop(), Some(1));
        assert!(pusher.join().unwrap().is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    // ---- epoch barrier ----

    /// Spawn a waiter for `cut` and assert it is still blocked after a
    /// small grace period.
    fn spawn_blocked_waiter(
        b: &Arc<EpochBarrier>,
        cut: Cut,
    ) -> std::thread::JoinHandle<()> {
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || b2.wait_for(cut));
        std::thread::sleep(Duration::from_millis(20));
        assert!(
            !waiter.is_finished(),
            "wait_for(epoch {}) must block while the cut is unretired",
            cut.epoch()
        );
        waiter
    }

    #[test]
    fn wait_for_on_already_retired_cut_returns_immediately() {
        let b = EpochBarrier::new();
        // a cut on a completely idle barrier retires on the spot
        let idle_cut = b.cut();
        b.wait_for(idle_cut); // must not hang
        assert_eq!(b.pending(), 0);

        // register + complete, then cut: also retired on the spot
        let t = b.register();
        b.complete(t);
        let cut = b.cut();
        b.wait_for(cut); // must not hang
        b.wait_for(idle_cut); // retired cuts stay retired
        assert_eq!(b.pending(), 0);
        assert_eq!(b.epoch(), 2, "two cuts advanced the epoch twice");
    }

    #[test]
    fn wait_for_blocks_until_pre_cut_tickets_complete() {
        let b = Arc::new(EpochBarrier::new());
        let n = 64;
        let tickets: Vec<Ticket> = (0..n).map(|_| b.register()).collect();
        assert!(tickets.iter().all(|t| t.epoch() == 0));
        let cut = b.cut();
        assert_eq!(cut.epoch(), 0);
        let b2 = b.clone();
        let completer = std::thread::spawn(move || {
            for t in tickets {
                std::thread::yield_now();
                b2.complete(t);
            }
        });
        b.wait_for(cut);
        assert_eq!(b.pending(), 0);
        completer.join().unwrap();
    }

    #[test]
    fn post_cut_registrations_never_extend_the_wait() {
        // the liveness property the redesign exists for: a ticket
        // registered AFTER the cut stays outstanding, yet the cut
        // retires as soon as its own (pre-cut) ticket completes
        let b = Arc::new(EpochBarrier::new());
        let pre = b.register();
        let cut = b.cut();
        let post = b.register(); // epoch 1: outside the cut
        assert_eq!(post.epoch(), cut.epoch() + 1);

        let waiter = spawn_blocked_waiter(&b, cut);
        b.complete(pre);
        waiter.join().unwrap();
        assert_eq!(b.pending(), 1, "the post-cut ticket is still in flight");
        b.complete(post);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn ooo_completion_across_cuts_is_tracked_per_epoch() {
        // item registered in epoch N, completed only after epoch N+2's
        // cut — with interleaved younger completions.  A plain
        // registered/completed counter pair would see counts balance
        // and wrongly retire epoch N; the per-epoch counts must not.
        let b = Arc::new(EpochBarrier::new());
        let old = b.register(); // epoch 0
        let cut0 = b.cut();
        let mid = b.register(); // epoch 1
        let cut1 = b.cut();
        let young = b.register(); // epoch 2
        let cut2 = b.cut();

        // complete the two younger items first (out of order)
        b.complete(young);
        b.complete(mid);
        // epochs 1 and 2 are empty, but the watermark is pinned at 0
        let waiter0 = spawn_blocked_waiter(&b, cut0);
        let waiter2 = spawn_blocked_waiter(&b, cut2);

        // retiring the epoch-0 straggler releases everything at once
        b.complete(old);
        waiter0.join().unwrap();
        waiter2.join().unwrap();
        b.wait_for(cut1); // already retired
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn failover_resubmission_keeps_the_original_epoch() {
        // the distributor contract: a batch requeued to a surviving
        // worker carries its ORIGINAL ticket, so however many cuts have
        // passed meanwhile, its eventual completion retires the epoch
        // it was registered in — and every cut taken while it was in
        // flight keeps waiting for it.
        let b = Arc::new(EpochBarrier::new());
        let batch_ticket = b.register(); // epoch 0: submitted to worker A
        let cut = b.cut();
        // worker A dies; cuts keep being taken while the batch is
        // salvaged and resubmitted (same ticket) to worker B
        let _ = b.cut();
        let later_cut = b.cut();
        assert_eq!(batch_ticket.epoch(), 0, "resubmission must not restamp");

        let w0 = spawn_blocked_waiter(&b, cut);
        let w2 = spawn_blocked_waiter(&b, later_cut);
        // worker B answers; the one completion retires epoch 0 and,
        // transitively, every later (empty) epoch
        b.complete(batch_ticket);
        w0.join().unwrap();
        w2.join().unwrap();
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn many_waiters_on_one_cut_all_wake() {
        let b = Arc::new(EpochBarrier::new());
        let t = b.register();
        let cut = b.cut();
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let b2 = b.clone();
                std::thread::spawn(move || b2.wait_for(cut))
            })
            .collect();
        std::thread::sleep(Duration::from_millis(10));
        b.complete(t);
        for w in waiters {
            w.join().unwrap();
        }
    }

    /// The exactly-once retirement detector: a second complete() of the
    /// same ticket must panic in debug builds instead of silently
    /// stealing a sibling ticket's epoch count (which would let a cut
    /// retire while that sibling's delta is still on the wire).
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "ticket-retire-exactly-once violation")]
    fn double_complete_panics_in_debug() {
        let b = EpochBarrier::new();
        let t = b.register();
        // the sibling whose count a double-complete would corrupt
        let _sibling = b.register();
        b.complete(t);
        b.complete(t);
    }

    /// The leaked-ticket detector: dropping a barrier while a ticket is
    /// registered but never completed must panic in debug builds — the
    /// next wait_for on that barrier would have hung forever.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "live ticket")]
    fn leaked_ticket_panics_on_drop_in_debug() {
        let b = EpochBarrier::new();
        let _leaked = b.register();
        drop(b);
    }

    #[test]
    #[allow(deprecated)]
    fn wait_idle_shim_matches_the_old_single_owner_semantics() {
        let b = Arc::new(EpochBarrier::new());
        b.wait_idle(); // idle barrier: must not hang
        let n = 16;
        let tickets: Vec<Ticket> = (0..n).map(|_| b.register()).collect();
        let b2 = b.clone();
        let completer = std::thread::spawn(move || {
            for t in tickets {
                std::thread::yield_now();
                b2.complete(t);
            }
        });
        b.wait_idle();
        assert_eq!(b.pending(), 0);
        completer.join().unwrap();
    }
}
