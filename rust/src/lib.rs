//! # Landscape — distributed graph-stream sketching
//!
//! A reproduction of *"Exploring the Landscape of Distributed Graph
//! Sketching"* (CS.DC 2024): a distributed graph-stream processing system
//! that computes **connected components** and **k-edge-connectivity** over
//! fully dynamic (insert + delete) edge streams using linear sketches.
//!
//! The main node keeps the graph sketch (Θ(V·log³V) bits — independent of
//! edge count, hence the dense-graph advantage) and collects updates into
//! *vertex-based batches* via the **pipeline hypertree**; stateless
//! distributed workers turn batches into fixed-size **sketch deltas**
//! (the expensive hashing work), which are XOR-merged back into the main
//! sketch.  Total network traffic is provably a small constant factor of
//! the input stream size (Theorem 5.2).
//!
//! The merge path is sharded: the sketch store is partitioned per-vertex
//! ([`sketch::shard::ShardSpec`], one shard per distributor thread) and
//! batches are routed shard-affine from the buffers through per-shard
//! work queues, so delta merging never serializes behind a global lock.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: ingestion, batching, worker
//!   dispatch, merging, queries ([`coordinator`], [`hypertree`],
//!   [`worker`], [`connectivity`]).
//! * **Serving layer** ([`serve`]) — optional multi-tenancy on top of
//!   L3: N logical graphs multiplexed over one shared pipeline, with a
//!   TCP front end, per-tenant admission quotas, and per-tenant
//!   isolation metrics.
//! * **L2/L1 (python/, build-time only)** — the sketch-delta computation
//!   graph and its Pallas kernel, AOT-lowered to HLO text artifacts that
//!   [`runtime`] loads and executes via PJRT.  Workers can compute deltas
//!   either natively ([`sketch::cameo`]) or through the artifact
//!   (`worker::XlaWorker`); both paths are bit-identical.  The PJRT
//!   pieces need the non-default `xla` cargo feature — the default build
//!   is pure Rust and runs on a bare toolchain.
//!
//! ## Quick start
//!
//! The public API is **session-based**: [`Landscape::builder`] validates
//! the configuration (typed [`session::ConfigError`], no silent clamps),
//! the session spawns any number of concurrent [`session::IngestHandle`]
//! producers, and [`session::QueryHandle`] answers queries without `&mut`
//! access to ingestion.
//!
//! ```no_run
//! use landscape::Landscape;
//! use landscape::stream::{dynamify::Dynamify, erdos::ErdosRenyi};
//!
//! let session = Landscape::builder().vertices(1 << 10).build().unwrap();
//!
//! // N independent producers, each with its own Send ingest handle
//! std::thread::scope(|scope| {
//!     for producer in 0..4u64 {
//!         let mut handle = session.ingest_handle();
//!         scope.spawn(move || {
//!             let gen = ErdosRenyi::new(1 << 10, 0.5, 7);
//!             for (i, u) in Dynamify::new(gen, 3).enumerate() {
//!                 if i as u64 % 4 == producer {
//!                     handle.ingest(u);
//!                 }
//!             }
//!         }); // dropping the handle publishes its tail
//!     }
//! });
//!
//! // read side: no &mut, cloneable across threads
//! let queries = session.query_handle();
//! let cc = queries.connected_components();
//! println!("{} components", cc.num_components());
//! ```

// Deliberate patterns clippy dislikes: index loops that sidestep borrow
// conflicts (hypertree cascades) and ceil-division helpers predating the
// std API.  `unknown_lints` keeps older clippy versions quiet about the
// newer lint names.
#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]

pub mod analysis;
pub mod baseline;
pub mod benchkit;
pub mod config;
pub mod connectivity;
pub mod coordinator;
pub mod experiments;
pub mod gutter;
pub mod hashing;
pub mod hypertree;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sketch;
pub mod storage;
pub mod stream;
pub mod util;
pub mod worker;

pub use coordinator::work_queue::Cut;
pub use session::{
    ConfigError, IngestHandle, Landscape, LandscapeBuilder, QueryHandle, Snapshot,
};
pub use sketch::params::SketchParams;
pub use stream::update::{Update, UpdateKind};
