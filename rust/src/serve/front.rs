//! The TCP front end: a thin, thread-per-connection server exposing
//! the [`Fabric`](super::Fabric) over the [`super::wire`] protocol,
//! plus the matching blocking [`Client`].
//!
//! Connection model (mirrors the worker server in
//! `crate::worker::remote`): [`Front::serve`] accepts up to N
//! connections, each handled on its own thread.  A connection owns one
//! [`IngestHandle`] per tenant it has ingested into — so a
//! connection's updates take the same lock-free thread-local ingest
//! path as an in-process producer — and those handles are dropped
//! (publishing their buffered tails) on `BYE`, on disconnect, or when
//! the same connection drops the tenant.
//!
//! Admission happens here, **before** any update enters the pipeline:
//! an over-quota `INGEST` is answered `THROTTLED` with a retry-after
//! hint and its updates are not applied, so backpressure is explicit
//! and lossless rather than a silent drop deep in the shared queues.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::TenantId;
use crate::session::IngestHandle;
use crate::stream::update::Update;

use super::wire::{code, Request, Response, WireMetrics};
use super::{Fabric, TenantConfig, TenantError};

/// The front-end TCP server over one [`Fabric`].
pub struct Front {
    listener: TcpListener,
    fabric: Arc<Fabric>,
}

impl Front {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port).
    pub fn bind(addr: &str, fabric: Arc<Fabric>) -> Result<Self> {
        Ok(Self {
            listener: TcpListener::bind(addr)?,
            fabric,
        })
    }

    /// The bound address (hand to clients).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept and serve `max_connections` connections (`usize::MAX` to
    /// run until the process ends), each on its own thread; returns
    /// after the accepted connections have all finished.  A client
    /// disconnecting mid-stream is normal teardown, not a server
    /// error.
    pub fn serve(&self, max_connections: usize) -> Result<()> {
        let mut served = 0usize;
        let mut accept_failures = 0u32;
        let mut workers = Vec::new();
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => {
                    accept_failures = 0;
                    s
                }
                Err(e) => {
                    // transient SYN-drop accepts are served around; a
                    // persistently failing accept (fd exhaustion) must
                    // not become a hot error loop
                    accept_failures += 1;
                    if accept_failures >= 64 {
                        bail!("front end: accept failing persistently: {e}");
                    }
                    crate::log_warn!(target: "front", "accept failed: {e}");
                    continue;
                }
            };
            let fabric = self.fabric.clone();
            workers.push(std::thread::spawn(move || {
                if let Err(e) = handle_connection(fabric, stream) {
                    crate::log_warn!(target: "front", "connection ended with error: {e:#}");
                }
            }));
            served += 1;
            if served >= max_connections {
                break;
            }
        }
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

/// Map a refused fabric operation onto its wire error frame.
fn error_response(e: &TenantError) -> Response {
    let code = match e {
        TenantError::UnknownTenant(_) => code::UNKNOWN_TENANT,
        TenantError::TenantBusy(_) => code::TENANT_BUSY,
        TenantError::TenantLimitReached(_) => code::TENANT_LIMIT,
        TenantError::ZeroVertices
        | TenantError::VerticesExceedFabric(..)
        | TenantError::NameTaken(_)
        | TenantError::InvalidFabric(_) => code::BAD_CONFIG,
    };
    Response::Error {
        code,
        message: e.to_string(),
    }
}

/// Every update must fall inside the tenant's logical range.
fn range_error(tenant: TenantId, vertex: u32, vertices: u64) -> Response {
    Response::Error {
        code: code::VERTEX_RANGE,
        message: format!(
            "vertex {vertex} outside tenant {tenant}'s range 0..{vertices}"
        ),
    }
}

fn first_out_of_range(updates: &[Update], vertices: u64) -> Option<u32> {
    updates
        .iter()
        .flat_map(|u| [u.u, u.v])
        .find(|&x| x as u64 >= vertices)
}

/// One connection's request → response loop.
fn handle_connection(fabric: Arc<Fabric>, stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    // this connection's ingest handles, one per tenant it writes to;
    // dropping one publishes its buffered tail
    let mut handles: HashMap<TenantId, IngestHandle> = HashMap::new();
    loop {
        let req = match Request::read_from(&mut reader) {
            Ok(r) => r,
            // EOF (or a torn frame at teardown) is normal client
            // departure: drop the handles, publishing their tails
            Err(_) => break,
        };
        let mut done = false;
        let resp = match req {
            Request::Create {
                name,
                vertices,
                quota_rate,
                quota_burst,
            } => {
                let cfg = TenantConfig {
                    name,
                    vertices,
                    quota_rate,
                    quota_burst,
                };
                match fabric.create_tenant(cfg) {
                    Ok(tenant) => Response::Created { tenant },
                    Err(e) => error_response(&e),
                }
            }
            Request::Drop { tenant } => {
                // release our own handle first, or the drop would
                // always see this connection as a live writer
                handles.remove(&tenant);
                match fabric.drop_tenant(tenant) {
                    Ok(()) => Response::Ok,
                    Err(e) => error_response(&e),
                }
            }
            Request::Ingest { tenant, updates } => match fabric.tenant_vertices(tenant) {
                Err(e) => error_response(&e),
                Ok(vertices) => {
                    if let Some(bad) = first_out_of_range(&updates, vertices) {
                        range_error(tenant, bad, vertices)
                    } else {
                        match fabric.admit(tenant, updates.len() as u64) {
                            Err(e) => error_response(&e),
                            Ok(Err(backoff)) => Response::Throttled {
                                retry_after_micros: (backoff.as_micros() as u64).max(1),
                            },
                            Ok(Ok(())) => {
                                let handle = match handles.entry(tenant) {
                                    std::collections::hash_map::Entry::Occupied(o) => {
                                        Ok(o.into_mut())
                                    }
                                    std::collections::hash_map::Entry::Vacant(v) => {
                                        fabric.ingest_handle(tenant).map(|h| v.insert(h))
                                    }
                                };
                                match handle {
                                    Err(e) => error_response(&e),
                                    Ok(h) => {
                                        for u in &updates {
                                            h.ingest(*u);
                                        }
                                        Response::Ok
                                    }
                                }
                            }
                        }
                    }
                }
            },
            Request::Flush { tenant } => {
                if let Some(h) = handles.get_mut(&tenant) {
                    h.flush();
                }
                match fabric.flush(tenant) {
                    Ok(()) => Response::Ok,
                    Err(e) => error_response(&e),
                }
            }
            Request::Components { tenant } => {
                // publish this connection's tail first: the reply
                // covers everything this client has sent (other
                // connections' unflushed tails are theirs to publish)
                if let Some(h) = handles.get_mut(&tenant) {
                    h.flush();
                }
                match fabric.connected_components(tenant) {
                    Ok(forest) => Response::Components {
                        num_components: forest.num_components() as u64,
                        component: forest.component,
                    },
                    Err(e) => error_response(&e),
                }
            }
            Request::Reach { tenant, pairs } => match fabric.tenant_vertices(tenant) {
                Err(e) => error_response(&e),
                Ok(vertices) => {
                    let bad = pairs
                        .iter()
                        .flat_map(|&(a, b)| [a, b])
                        .find(|&x| x as u64 >= vertices);
                    match bad {
                        Some(v) => range_error(tenant, v, vertices),
                        None => {
                            if let Some(h) = handles.get_mut(&tenant) {
                                h.flush();
                            }
                            match fabric.reachability(tenant, &pairs) {
                                Ok(answers) => Response::Reach { answers },
                                Err(e) => error_response(&e),
                            }
                        }
                    }
                }
            },
            Request::Metrics { tenant } => match fabric.tenant_metrics(tenant) {
                Ok(s) => Response::Metrics(WireMetrics {
                    updates_ingested: s.updates_ingested,
                    stream_bytes: s.stream_bytes,
                    batch_bytes_sent: s.batch_bytes_sent,
                    delta_bytes_received: s.delta_bytes_received,
                    batches_dropped: s.batches_dropped,
                    quota_rejections: s.quota_rejections,
                    queue_depth: s.queue_depth,
                    query_us: s.query_us,
                }),
                Err(e) => error_response(&e),
            },
            Request::Bye => {
                // publish every tail this connection still buffers
                handles.clear();
                done = true;
                Response::Ok
            }
        };
        resp.write_to(&mut writer)?;
        writer.flush()?;
        if done {
            break;
        }
    }
    Ok(())
}

/// A blocking client for the front-end protocol: one request, one
/// response, in order.  Thin by design — every method is one frame
/// pair.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect to a [`Front`]'s address.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        req.write_to(&mut self.writer)?;
        self.writer.flush()?;
        Response::read_from(&mut self.reader)
    }

    /// `CREATE`: register a tenant, returning its id.
    pub fn create(
        &mut self,
        name: &str,
        vertices: u64,
        quota_rate: u64,
        quota_burst: u64,
    ) -> Result<TenantId> {
        match self.call(&Request::Create {
            name: name.to_string(),
            vertices,
            quota_rate,
            quota_burst,
        })? {
            Response::Created { tenant } => Ok(tenant),
            Response::Error { code, message } => bail!("create refused ({code}): {message}"),
            other => bail!("unexpected reply to CREATE: {other:?}"),
        }
    }

    /// `DROP`: unregister a tenant.
    pub fn drop_tenant(&mut self, tenant: TenantId) -> Result<()> {
        match self.call(&Request::Drop { tenant })? {
            Response::Ok => Ok(()),
            Response::Error { code, message } => bail!("drop refused ({code}): {message}"),
            other => bail!("unexpected reply to DROP: {other:?}"),
        }
    }

    /// `INGEST`: stream one chunk.  `Ok(None)` means accepted;
    /// `Ok(Some(backoff))` means throttled — the chunk was **not**
    /// applied, retry it after the hint.
    pub fn ingest(&mut self, tenant: TenantId, updates: &[Update]) -> Result<Option<Duration>> {
        match self.call(&Request::Ingest {
            tenant,
            updates: updates.to_vec(),
        })? {
            Response::Ok => Ok(None),
            Response::Throttled { retry_after_micros } => {
                Ok(Some(Duration::from_micros(retry_after_micros)))
            }
            Response::Error { code, message } => bail!("ingest refused ({code}): {message}"),
            other => bail!("unexpected reply to INGEST: {other:?}"),
        }
    }

    /// `FLUSH`: publish this connection's tail and settle the
    /// tenant's pipeline.
    pub fn flush(&mut self, tenant: TenantId) -> Result<()> {
        match self.call(&Request::Flush { tenant })? {
            Response::Ok => Ok(()),
            Response::Error { code, message } => bail!("flush refused ({code}): {message}"),
            other => bail!("unexpected reply to FLUSH: {other:?}"),
        }
    }

    /// `COMPONENTS`: `(num_components, component-representative map)`
    /// over the tenant's logical range.
    pub fn components(&mut self, tenant: TenantId) -> Result<(u64, Vec<u32>)> {
        match self.call(&Request::Components { tenant })? {
            Response::Components {
                num_components,
                component,
            } => Ok((num_components, component)),
            Response::Error { code, message } => bail!("components refused ({code}): {message}"),
            other => bail!("unexpected reply to COMPONENTS: {other:?}"),
        }
    }

    /// `REACH`: batched reachability flags.
    pub fn reach(&mut self, tenant: TenantId, pairs: &[(u32, u32)]) -> Result<Vec<bool>> {
        match self.call(&Request::Reach {
            tenant,
            pairs: pairs.to_vec(),
        })? {
            Response::Reach { answers } => Ok(answers),
            Response::Error { code, message } => bail!("reach refused ({code}): {message}"),
            other => bail!("unexpected reply to REACH: {other:?}"),
        }
    }

    /// `METRICS`: the tenant's wire metrics block.
    pub fn metrics(&mut self, tenant: TenantId) -> Result<WireMetrics> {
        match self.call(&Request::Metrics { tenant })? {
            Response::Metrics(m) => Ok(m),
            Response::Error { code, message } => bail!("metrics refused ({code}): {message}"),
            other => bail!("unexpected reply to METRICS: {other:?}"),
        }
    }

    /// `BYE`: orderly goodbye (the server publishes this connection's
    /// buffered tails).
    pub fn bye(mut self) -> Result<()> {
        match self.call(&Request::Bye)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected reply to BYE: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::FabricConfig;
    use super::*;

    fn front(vertices: u64) -> (std::thread::JoinHandle<()>, String) {
        front_with(vertices, 1)
    }

    fn front_with(vertices: u64, connections: usize) -> (std::thread::JoinHandle<()>, String) {
        let mut cfg = FabricConfig::for_vertices(vertices);
        cfg.base.distributor_threads = 2;
        let fabric = Arc::new(Fabric::spawn(cfg).unwrap());
        let server = Front::bind("127.0.0.1:0", fabric).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || server.serve(connections).unwrap());
        (h, addr)
    }

    #[test]
    fn full_session_over_the_wire() {
        let (server, addr) = front(1 << 8);
        let mut c = Client::connect(&addr).unwrap();
        let t = c.create("wire-tenant", 1 << 8, 0, 0).unwrap();
        // a 4-path and an isolated pair
        c.ingest(
            t,
            &[
                Update::insert(0, 1),
                Update::insert(1, 2),
                Update::insert(2, 3),
                Update::insert(10, 11),
            ],
        )
        .unwrap();
        c.flush(t).unwrap();
        let (n, map) = c.components(t).unwrap();
        assert_eq!(map.len(), 1 << 8);
        assert_eq!(n as usize, (1 << 8) - 4);
        assert_eq!(map[0], map[3]);
        assert_ne!(map[0], map[10]);
        let reach = c.reach(t, &[(0, 3), (0, 10), (10, 11)]).unwrap();
        assert_eq!(reach, vec![true, false, true]);
        let m = c.metrics(t).unwrap();
        assert_eq!(m.updates_ingested, 4);
        assert_eq!(m.stream_bytes, 4 * 9);
        assert_eq!(m.batches_dropped, 0);
        assert_eq!(m.quota_rejections, 0);
        c.drop_tenant(t).unwrap();
        c.bye().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn quota_throttles_over_the_wire() {
        let (server, addr) = front(64);
        let mut c = Client::connect(&addr).unwrap();
        let t = c.create("throttled", 64, 10, 20).unwrap();
        let chunk: Vec<Update> = (0..20).map(|i| Update::insert(i, (i + 1) % 64)).collect();
        assert!(c.ingest(t, &chunk).unwrap().is_none(), "burst admits");
        let backoff = c
            .ingest(t, &chunk)
            .unwrap()
            .expect("over-burst chunk must throttle");
        assert!(backoff > Duration::ZERO);
        let m = c.metrics(t).unwrap();
        assert_eq!(m.quota_rejections, 1);
        // the throttled chunk was NOT applied
        assert_eq!(m.updates_ingested as usize, chunk.len());
        c.bye().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn errors_carry_typed_codes() {
        let (server, addr) = front(64);
        let mut c = Client::connect(&addr).unwrap();
        let err = c.create("too-big", 1 << 20, 0, 0).unwrap_err();
        assert!(err.to_string().contains(&format!("({}", code::BAD_CONFIG)));
        let err = c.flush(99).unwrap_err();
        assert!(
            err.to_string()
                .contains(&format!("({}", code::UNKNOWN_TENANT)),
            "{err}"
        );
        let t = c.create("ranged", 16, 0, 0).unwrap();
        let err = c.ingest(t, &[Update::insert(0, 16)]).unwrap_err();
        assert!(err.to_string().contains(&format!("({}", code::VERTEX_RANGE)));
        c.bye().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn drop_from_another_connection_is_busy() {
        let (server, addr) = front_with(64, 2);
        let mut writer = Client::connect(&addr).unwrap();
        let t = writer.create("contested", 64, 0, 0).unwrap();
        // the writer's INGEST opens a server-side handle on tenant t
        writer.ingest(t, &[Update::insert(1, 2)]).unwrap();
        let mut other = Client::connect(&addr).unwrap();
        let err = other.drop_tenant(t).unwrap_err();
        assert!(err.to_string().contains(&format!("({}", code::TENANT_BUSY)));
        // the writer leaves; its handle closes and the drop goes through
        writer.bye().unwrap();
        other.drop_tenant(t).unwrap();
        other.bye().unwrap();
        server.join().unwrap();
    }
}
