//! The multi-tenant serving layer: N logical graphs over **one**
//! shared pipeline.
//!
//! A [`Fabric`] owns the machinery a single [`crate::Landscape`]
//! session owns — the sharded work queues, the batch-buffer arena, and
//! the distributor threads (with their worker backends or remote
//! connections) — but multiplexes any number of *tenants* over it.
//! Each tenant is an independent logical graph: its own sketch stores,
//! epoch barrier, merge gate, GreedyCC accelerator, and metrics,
//! created and dropped at runtime through a validated
//! [`TenantConfig`].  Work items are tagged with a [`TenantId`] from
//! the ingest buffer all the way through the shard queues and (in
//! remote mode) the v2 wire's `TBATCH2`/`TDELTA2` frames, and the
//! distributors resolve the tag back to the right store/barrier pair
//! through the fabric's [`TenantRegistry`] at merge time.
//!
//! Isolation is **structural**, not scheduled: tenants share compute
//! (distributor threads, worker fleet) and contend on queue capacity,
//! but no tenant can read or write another's sketches — a batch
//! resolves to exactly one tenant's stores, the remote path verifies
//! the server echoed the same tenant id before merging, and every
//! byte of worker traffic is metered to the tenant that caused it, so
//! the paper's Theorem 5.2 communication bound is checkable *per
//! tenant*.  The admission layer ([`TenantConfig::quota_rate`]) adds
//! the resource half: an over-rate tenant is refused with an explicit
//! retry-after hint — never a silent drop — while idle tenants keep
//! their query promptness.
//!
//! The TCP front end lives in [`front`]; its wire protocol in
//! [`wire`].  In-process embedders can skip both and drive the fabric
//! directly:
//!
//! ```no_run
//! use landscape::serve::{Fabric, FabricConfig, TenantConfig};
//! use landscape::stream::update::Update;
//!
//! let fabric = Fabric::spawn(FabricConfig::for_vertices(1 << 12)).unwrap();
//! let a = fabric.create_tenant(TenantConfig::named("alice", 1 << 10)).unwrap();
//! let b = fabric.create_tenant(TenantConfig::named("bob", 1 << 12)).unwrap();
//! let mut ingest = fabric.ingest_handle(a).unwrap();
//! ingest.ingest(Update::insert(1, 2));
//! drop(ingest); // publishes the tail
//! fabric.flush(a).unwrap();
//! let forest = fabric.query_handle(b).unwrap().connected_components();
//! assert_eq!(forest.num_components(), 1 << 12); // b never saw a's edge
//! ```

#![deny(missing_docs)]

pub mod front;
pub mod wire;

use std::collections::HashMap;
use std::sync::atomic::AtomicU32;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::connectivity::SpanningForest;
use crate::coordinator::arena::BatchArena;
use crate::coordinator::work_queue::ShardedWorkQueue;
use crate::coordinator::{
    distributor, CoordinatorConfig, TenantDirectory, TenantId, TenantRuntime, WorkItem, WorkerKind,
};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::session::{
    spawn_tenant_core, IngestHandle, LandscapeBuilder, QueryHandle, SessionCore,
    DEFAULT_UPDATE_LOG_CAPACITY,
};

/// Serving-fabric configuration: the shared-pipeline knobs (a
/// [`CoordinatorConfig`], validated exactly like a session's) plus the
/// fabric-level limits.
///
/// Every tenant shares the fabric's [`crate::sketch::params::SketchParams`]
/// and `graph_seed` — that is what keeps the worker fleet
/// tenant-oblivious (a worker computes the same delta function for
/// every tenant; only the tag differs).  A tenant's own
/// [`TenantConfig::vertices`] is a *logical* bound within the fabric's
/// vertex capacity, enforced at admission; each tenant's sketch stores
/// are sized to the fabric capacity.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// The shared-pipeline knobs (vertex capacity, shard/thread count,
    /// worker backend, buffer kind, …).  The fabric is sketch-only:
    /// `hybrid_threshold` must be 0 and no spill directory is
    /// supported — tenants are purely resident.
    pub base: CoordinatorConfig,
    /// Maximum concurrently registered tenants (≥ 1).
    pub max_tenants: usize,
    /// Per-ingest-handle update-log capacity (see
    /// [`crate::session::LandscapeBuilder::update_log_capacity`]).
    pub update_log_capacity: usize,
}

impl FabricConfig {
    /// Paper-default knobs over a fabric-wide vertex capacity.
    pub fn for_vertices(vertices: u64) -> Self {
        Self {
            base: CoordinatorConfig::for_vertices(vertices),
            max_tenants: 64,
            update_log_capacity: DEFAULT_UPDATE_LOG_CAPACITY,
        }
    }
}

/// A validated request to register one logical graph on the fabric.
#[derive(Clone, Debug)]
pub struct TenantConfig {
    /// Human-readable name, unique among live tenants.
    pub name: String,
    /// Logical vertex-id space `0..vertices`; must fit the fabric's
    /// capacity.  Ingest and queries outside the range are refused.
    pub vertices: u64,
    /// Admission quota in updates/second; 0 = unlimited.
    pub quota_rate: u64,
    /// Quota burst in updates; 0 derives one second's worth
    /// (`quota_rate`).  A single ingest chunk larger than the burst
    /// can never be admitted — size chunks below it.
    pub quota_burst: u64,
}

impl TenantConfig {
    /// An unlimited-rate tenant config.
    pub fn named(name: impl Into<String>, vertices: u64) -> Self {
        Self {
            name: name.into(),
            vertices,
            quota_rate: 0,
            quota_burst: 0,
        }
    }

    /// Set the admission quota (updates/second, and burst in updates —
    /// 0 derives one second's worth).
    pub fn quota(mut self, rate: u64, burst: u64) -> Self {
        self.quota_rate = rate;
        self.quota_burst = burst;
        self
    }
}

/// Why a tenant operation was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TenantError {
    /// `vertices` was 0.
    ZeroVertices,
    /// The tenant asked for more vertices than the fabric's capacity.
    VerticesExceedFabric(u64, u64),
    /// The fabric already holds `max_tenants` live tenants.
    TenantLimitReached(usize),
    /// Another live tenant already uses this name.
    NameTaken(String),
    /// No live tenant has this id.
    UnknownTenant(TenantId),
    /// The tenant still has live ingest handles and cannot be dropped.
    TenantBusy(TenantId),
    /// The fabric's own base configuration was rejected (carries the
    /// underlying [`crate::session::ConfigError`] rendering, or the
    /// fabric-specific constraint that was violated).
    InvalidFabric(String),
}

impl std::fmt::Display for TenantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TenantError::ZeroVertices => write!(f, "tenant vertices must be nonzero"),
            TenantError::VerticesExceedFabric(v, cap) => {
                write!(f, "tenant wants {v} vertices but the fabric caps at {cap}")
            }
            TenantError::TenantLimitReached(max) => {
                write!(f, "fabric already holds its maximum of {max} tenants")
            }
            TenantError::NameTaken(name) => write!(f, "tenant name {name:?} is already in use"),
            TenantError::UnknownTenant(t) => write!(f, "tenant {t} is not registered"),
            TenantError::TenantBusy(t) => {
                write!(f, "tenant {t} still has live ingest handles")
            }
            TenantError::InvalidFabric(msg) => write!(f, "invalid fabric config: {msg}"),
        }
    }
}

impl std::error::Error for TenantError {}

/// Token-bucket admission state: `rate` tokens/second refill up to
/// `burst`; a chunk of `n` updates spends `n` tokens or is refused
/// with a retry-after hint.  `rate == 0` disables the quota.
struct QuotaState {
    rate: u64,
    burst: f64,
    inner: Mutex<Bucket>,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

impl QuotaState {
    fn new(rate: u64, burst: u64) -> Self {
        let burst = if burst == 0 { rate } else { burst } as f64;
        Self {
            rate,
            burst,
            inner: Mutex::new(Bucket {
                tokens: burst,
                last: Instant::now(),
            }),
        }
    }

    /// Admit `n` updates now, or refuse with the back-off after which
    /// the bucket will hold `n` tokens again.
    fn admit(&self, n: u64) -> Result<(), Duration> {
        if self.rate == 0 {
            return Ok(());
        }
        let mut b = self.inner.lock().unwrap();
        let now = Instant::now();
        let refill = now.duration_since(b.last).as_secs_f64() * self.rate as f64;
        b.tokens = (b.tokens + refill).min(self.burst);
        b.last = now;
        let need = n as f64;
        if b.tokens >= need {
            b.tokens -= need;
            Ok(())
        } else {
            let deficit = need - b.tokens;
            Err(Duration::from_secs_f64(deficit / self.rate as f64))
        }
    }
}

/// One live logical graph: its engine-room core plus the fabric-side
/// bookkeeping (name, logical size, admission state, and the
/// pre-built runtime bundle the distributors resolve).
struct Tenant {
    id: TenantId,
    name: String,
    vertices: u64,
    core: Arc<SessionCore>,
    runtime: Arc<TenantRuntime>,
    quota: QuotaState,
}

/// The fabric's tenant table: the [`TenantDirectory`] the shared
/// distributor threads resolve tenant tags through, and the map the
/// serving surface administers.
pub struct TenantRegistry {
    map: RwLock<HashMap<TenantId, Arc<Tenant>>>,
    next_id: AtomicU32,
}

impl TenantRegistry {
    fn new() -> Self {
        Self {
            map: RwLock::new(HashMap::new()),
            next_id: AtomicU32::new(1),
        }
    }

    fn get(&self, tenant: TenantId) -> Result<Arc<Tenant>, TenantError> {
        self.map
            .read()
            .unwrap()
            .get(&tenant)
            .cloned()
            .ok_or(TenantError::UnknownTenant(tenant))
    }

    fn live(&self) -> usize {
        self.map.read().unwrap().len()
    }
}

impl TenantDirectory for TenantRegistry {
    fn runtime(&self, tenant: TenantId) -> Option<Arc<TenantRuntime>> {
        self.map
            .read()
            .unwrap()
            .get(&tenant)
            .map(|t| t.runtime.clone())
    }
}

/// One tenant's labeled metrics snapshot.
#[derive(Clone, Debug)]
pub struct TenantMetrics {
    /// The tenant id.
    pub id: TenantId,
    /// The tenant's registered name.
    pub name: String,
    /// The tenant's full counter snapshot (per-tenant stream bytes,
    /// wire bytes, drops, quota rejections, queue depth, query
    /// latency, …).
    pub snapshot: MetricsSnapshot,
}

/// The fabric-wide metrics view: one labeled snapshot per tenant plus
/// the fabric's own connection-level summary.
#[derive(Clone, Debug)]
pub struct FabricMetrics {
    /// Connection-level truth shared by all tenants: whole-connection
    /// wire accounting (HELLO/SHUTDOWN framing, failover
    /// retransmissions), worker failures, requeues, in-flight peaks,
    /// and the `tenants_active` gauge.
    pub fabric: MetricsSnapshot,
    /// Per-tenant labeled snapshots, in tenant-id order.
    pub tenants: Vec<TenantMetrics>,
}

/// The serving fabric: one shared pipeline, N logical graphs.
///
/// See the module docs for the isolation contract.  Dropping the
/// fabric closes the shard queues and joins the distributor threads —
/// drop every tenant ingest handle first (handles outliving the
/// fabric take the metered drop path, exactly as with a session).
pub struct Fabric {
    config: FabricConfig,
    registry: Arc<TenantRegistry>,
    queue: Arc<ShardedWorkQueue<WorkItem>>,
    arena: Arc<BatchArena>,
    /// Fabric-global (connection-level) metrics: what is shared truth
    /// rather than per-tenant attribution.
    metrics: Arc<Metrics>,
    distributors: Vec<JoinHandle<()>>,
}

impl Fabric {
    /// Validate `config` and spawn the shared pipeline (shard queues,
    /// arena, one distributor thread per shard) with **no** tenants
    /// registered yet.
    pub fn spawn(config: FabricConfig) -> Result<Self, TenantError> {
        LandscapeBuilder::from_config(config.base.clone())
            .update_log_capacity(config.update_log_capacity)
            .validate()
            .map_err(|e| TenantError::InvalidFabric(e.to_string()))?;
        if config.base.hybrid_threshold != 0 {
            return Err(TenantError::InvalidFabric(
                "the serving fabric is sketch-only (hybrid_threshold must be 0): \
                 tagged remote workers answer sketch deltas for every tenant"
                    .to_string(),
            ));
        }
        if config.max_tenants == 0 {
            return Err(TenantError::InvalidFabric(
                "max_tenants must be nonzero".to_string(),
            ));
        }
        let spec = config.base.shard_spec();
        let queue = Arc::new(ShardedWorkQueue::new(
            spec.count(),
            config.base.queue_capacity,
        ));
        let arena = Arc::new(BatchArena::new(spec.count()));
        let metrics = Arc::new(Metrics::new());
        let registry = Arc::new(TenantRegistry::new());
        // remote fabrics speak the tenant-tagged frames so the wire
        // carries the attribution; in-process fabrics resolve the tag
        // at the queue and need no framing at all
        let tagged_wire = matches!(config.base.worker, WorkerKind::Remote { .. });
        let tenants: Arc<dyn TenantDirectory> = registry.clone();
        let mut distributors = Vec::new();
        for shard in 0..spec.count() {
            let d = distributor::Distributor {
                shard,
                kind: config.base.worker.clone(),
                params: config.base.params(),
                graph_seed: config.base.graph_seed,
                k: config.base.k,
                window: config.base.remote_window.max(1),
                hybrid_threshold: config.base.hybrid_threshold,
                queue: queue.clone(),
                tenants: tenants.clone(),
                metrics: metrics.clone(),
                arena: arena.clone(),
                tagged_wire,
            };
            distributors.push(std::thread::spawn(move || d.run()));
        }
        crate::log_info!(
            target: "serve",
            "fabric up: {} shard(s), capacity {} vertices, {} backend{}",
            spec.count(),
            config.base.vertices,
            match &config.base.worker {
                WorkerKind::Remote { addrs } => format!("remote×{}", addrs.len()),
                other => format!("{other:?}"),
            },
            if tagged_wire { " (tagged wire)" } else { "" },
        );
        Ok(Self {
            config,
            registry,
            queue,
            arena,
            metrics,
            distributors,
        })
    }

    /// Register a new logical graph, returning its [`TenantId`].
    pub fn create_tenant(&self, cfg: TenantConfig) -> Result<TenantId, TenantError> {
        if cfg.vertices == 0 {
            return Err(TenantError::ZeroVertices);
        }
        if cfg.vertices > self.config.base.vertices {
            return Err(TenantError::VerticesExceedFabric(
                cfg.vertices,
                self.config.base.vertices,
            ));
        }
        let mut map = self.registry.map.write().unwrap();
        if map.len() >= self.config.max_tenants {
            return Err(TenantError::TenantLimitReached(self.config.max_tenants));
        }
        if map.values().any(|t| t.name == cfg.name) {
            return Err(TenantError::NameTaken(cfg.name));
        }
        // lint: allow(relaxed-ordering) — id allocation only needs uniqueness, which fetch_add provides at any ordering
        let id = self.registry.next_id.fetch_add(1, Ordering::Relaxed);
        let core = spawn_tenant_core(
            self.config.base.clone(),
            self.config.update_log_capacity,
            id,
            self.queue.clone(),
            self.arena.clone(),
        );
        let runtime = core.tenant_runtime();
        let tenant = Arc::new(Tenant {
            id,
            name: cfg.name.clone(),
            vertices: cfg.vertices,
            core,
            runtime,
            quota: QuotaState::new(cfg.quota_rate, cfg.quota_burst),
        });
        map.insert(id, tenant);
        Metrics::set(&self.metrics.tenants_active, map.len() as u64);
        drop(map);
        crate::log_info!(
            target: "serve",
            "tenant {id} ({:?}) created: {} vertices, quota {}/s burst {}",
            cfg.name,
            cfg.vertices,
            cfg.quota_rate,
            cfg.quota_burst,
        );
        Ok(id)
    }

    /// Unregister a logical graph, releasing its stores.
    ///
    /// Refused with [`TenantError::TenantBusy`] while any ingest
    /// handle on the tenant is still live.  Otherwise the tenant's
    /// pipeline is **settled first** (epoch cut + wait, so every
    /// in-flight batch merges and retires its barrier ticket) and only
    /// then unregistered — in-flight work never resolves to a missing
    /// runtime.  A handle racing this call can still slip work in
    /// between the settle and the unregister; the distributors drop
    /// such orphans *metered* (fabric-level `batches_dropped`), never
    /// silently.
    pub fn drop_tenant(&self, tenant: TenantId) -> Result<(), TenantError> {
        let t = self.registry.get(tenant)?;
        if t.core.live_handles() > 0 {
            return Err(TenantError::TenantBusy(tenant));
        }
        let cut = t.core.cut_shared();
        t.core.wait_for_cut(cut);
        let mut map = self.registry.map.write().unwrap();
        if t.core.live_handles() > 0 {
            // a handle was spawned while we were settling: abort the
            // drop, the caller retries once the handle closes
            return Err(TenantError::TenantBusy(tenant));
        }
        map.remove(&tenant);
        Metrics::set(&self.metrics.tenants_active, map.len() as u64);
        drop(map);
        crate::log_info!(target: "serve", "tenant {tenant} ({:?}) dropped", t.name);
        Ok(())
    }

    /// Spawn an ingest handle over one tenant's logical graph (one per
    /// producer thread, exactly like [`crate::Landscape::ingest_handle`]).
    pub fn ingest_handle(&self, tenant: TenantId) -> Result<IngestHandle, TenantError> {
        let t = self.registry.get(tenant)?;
        Ok(IngestHandle::new(
            t.core.clone(),
            self.config.update_log_capacity,
        ))
    }

    /// A cloneable read-side query handle over one tenant's graph.
    pub fn query_handle(&self, tenant: TenantId) -> Result<QueryHandle, TenantError> {
        let t = self.registry.get(tenant)?;
        Ok(QueryHandle::new(t.core.clone()))
    }

    /// The tenant's logical vertex-id bound (`0..vertices`).
    pub fn tenant_vertices(&self, tenant: TenantId) -> Result<u64, TenantError> {
        Ok(self.registry.get(tenant)?.vertices)
    }

    /// Run one tenant's admission quota for a chunk of `updates`
    /// updates: `Ok(Ok(()))` admits, `Ok(Err(backoff))` throttles (and
    /// meters `quota_rejections` on the tenant — the refusal is always
    /// accounted, never silent).
    pub fn admit(
        &self,
        tenant: TenantId,
        updates: u64,
    ) -> Result<Result<(), Duration>, TenantError> {
        let t = self.registry.get(tenant)?;
        let verdict = t.quota.admit(updates);
        if verdict.is_err() {
            Metrics::add(&t.core.metrics.quota_rejections, 1);
        }
        Ok(verdict)
    }

    /// Epoch cut + wait over one tenant's pipeline (the §5.3 query
    /// barrier, scoped to that tenant — other tenants' in-flight work
    /// neither extends this wait nor is waited on).
    pub fn flush(&self, tenant: TenantId) -> Result<(), TenantError> {
        let t = self.registry.get(tenant)?;
        let cut = t.core.cut_shared();
        t.core.wait_for_cut(cut);
        Ok(())
    }

    /// Connectivity over one tenant's logical range: the tenant's
    /// tiered query, truncated to its `0..vertices` id space.
    pub fn connected_components(&self, tenant: TenantId) -> Result<SpanningForest, TenantError> {
        let t = self.registry.get(tenant)?;
        let mut forest = t.core.connected_components();
        // the tenant only ever ingests edges within its logical range,
        // so every component root of a vertex < vertices is itself
        // < vertices: the truncated map is self-contained
        forest.component.truncate(t.vertices as usize);
        forest
            .edges
            .retain(|&(u, v)| (u as u64) < t.vertices && (v as u64) < t.vertices);
        Ok(forest)
    }

    /// Batched reachability over one tenant's graph.
    pub fn reachability(
        &self,
        tenant: TenantId,
        pairs: &[(u32, u32)],
    ) -> Result<Vec<bool>, TenantError> {
        let t = self.registry.get(tenant)?;
        Ok(t.core.reachability(pairs))
    }

    /// Live tenants as `(id, name)`, in id order.
    pub fn tenants(&self) -> Vec<(TenantId, String)> {
        let map = self.registry.map.read().unwrap();
        let mut out: Vec<(TenantId, String)> =
            map.values().map(|t| (t.id, t.name.clone())).collect();
        out.sort_unstable_by_key(|(id, _)| *id);
        out
    }

    /// One tenant's metrics snapshot (store gauges and queue depth
    /// refreshed at this call).
    pub fn tenant_metrics(&self, tenant: TenantId) -> Result<MetricsSnapshot, TenantError> {
        Ok(self.registry.get(tenant)?.core.metrics_snapshot())
    }

    /// The fabric-wide labeled metrics view: every tenant's snapshot
    /// plus the fabric's connection-level summary.
    pub fn metrics(&self) -> FabricMetrics {
        let map = self.registry.map.read().unwrap();
        Metrics::set(&self.metrics.tenants_active, map.len() as u64);
        let mut tenants: Vec<TenantMetrics> = map
            .values()
            .map(|t| TenantMetrics {
                id: t.id,
                name: t.name.clone(),
                snapshot: t.core.metrics_snapshot(),
            })
            .collect();
        drop(map);
        tenants.sort_unstable_by_key(|t| t.id);
        FabricMetrics {
            fabric: self.metrics.snapshot(),
            tenants,
        }
    }

    /// The fabric's shared-pipeline configuration.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.config.base
    }
}

impl Drop for Fabric {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.distributors.drain(..) {
            let _ = h.join();
        }
        // remote connections are owned by the (now-joined) distributor
        // threads, which ended them with SHUTDOWN → BYE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::dsu::Dsu;
    use crate::stream::update::Update;

    fn fabric(vertices: u64) -> Fabric {
        let mut cfg = FabricConfig::for_vertices(vertices);
        cfg.base.distributor_threads = 2;
        Fabric::spawn(cfg).unwrap()
    }

    #[test]
    fn config_validation_is_typed() {
        assert!(matches!(
            Fabric::spawn(FabricConfig::for_vertices(0)),
            Err(TenantError::InvalidFabric(_))
        ));
        let mut cfg = FabricConfig::for_vertices(64);
        cfg.max_tenants = 0;
        assert!(matches!(
            Fabric::spawn(cfg),
            Err(TenantError::InvalidFabric(_))
        ));
        let mut cfg = FabricConfig::for_vertices(64);
        cfg.base.hybrid_threshold = 8;
        assert!(matches!(
            Fabric::spawn(cfg),
            Err(TenantError::InvalidFabric(_))
        ));
    }

    #[test]
    fn tenant_validation_is_typed() {
        let f = fabric(256);
        assert_eq!(
            f.create_tenant(TenantConfig::named("z", 0)),
            Err(TenantError::ZeroVertices)
        );
        assert_eq!(
            f.create_tenant(TenantConfig::named("big", 512)),
            Err(TenantError::VerticesExceedFabric(512, 256))
        );
        let a = f.create_tenant(TenantConfig::named("a", 64)).unwrap();
        assert_eq!(
            f.create_tenant(TenantConfig::named("a", 64)),
            Err(TenantError::NameTaken("a".to_string()))
        );
        assert!(matches!(
            f.ingest_handle(a + 100),
            Err(TenantError::UnknownTenant(_))
        ));
        let mut cfg = FabricConfig::for_vertices(256);
        cfg.max_tenants = 1;
        let f1 = Fabric::spawn(cfg).unwrap();
        f1.create_tenant(TenantConfig::named("only", 16)).unwrap();
        assert_eq!(
            f1.create_tenant(TenantConfig::named("second", 16)),
            Err(TenantError::TenantLimitReached(1))
        );
    }

    #[test]
    fn tenants_are_isolated_against_referees() {
        let f = fabric(1 << 9);
        let a = f.create_tenant(TenantConfig::named("a", 1 << 9)).unwrap();
        let b = f.create_tenant(TenantConfig::named("b", 1 << 9)).unwrap();
        let mut dsu_a = Dsu::new(1 << 9);
        let mut dsu_b = Dsu::new(1 << 9);
        let mut ha = f.ingest_handle(a).unwrap();
        let mut hb = f.ingest_handle(b).unwrap();
        // a: a path over evens; b: a clique over 0..8 — overlapping id
        // spaces, disjoint edge sets
        for i in 0..200u32 {
            ha.ingest(Update::insert(2 * i, 2 * i + 2));
            dsu_a.union(2 * i, 2 * i + 2);
        }
        for i in 0..8u32 {
            for j in (i + 1)..8u32 {
                hb.ingest(Update::insert(i, j));
                dsu_b.union(i, j);
            }
        }
        drop(ha);
        drop(hb);
        f.flush(a).unwrap();
        f.flush(b).unwrap();
        let fa = f.connected_components(a).unwrap();
        let fb = f.connected_components(b).unwrap();
        assert_eq!(fa.num_components(), dsu_a.num_components());
        assert_eq!(fb.num_components(), dsu_b.num_components());
        for (u, v) in [(0u32, 402u32), (1, 3), (0, 7)] {
            assert_eq!(
                fa.component[u as usize] == fa.component[v as usize],
                dsu_a.connected(u, v),
                "tenant a pair ({u},{v})"
            );
            assert_eq!(
                fb.component[u as usize] == fb.component[v as usize],
                dsu_b.connected(u, v),
                "tenant b pair ({u},{v})"
            );
        }
        let m = f.metrics();
        assert_eq!(m.tenants.len(), 2);
        for t in &m.tenants {
            assert_eq!(t.snapshot.batches_dropped, 0, "tenant {} dropped", t.id);
        }
        assert_eq!(m.fabric.tenants_active, 2);
    }

    #[test]
    fn drop_tenant_lifecycle() {
        let f = fabric(128);
        let a = f.create_tenant(TenantConfig::named("a", 128)).unwrap();
        let h = f.ingest_handle(a).unwrap();
        assert_eq!(f.drop_tenant(a), Err(TenantError::TenantBusy(a)));
        drop(h);
        f.drop_tenant(a).unwrap();
        assert!(matches!(
            f.drop_tenant(a),
            Err(TenantError::UnknownTenant(_))
        ));
        assert_eq!(f.metrics().fabric.tenants_active, 0);
        // ids are never reused
        let b = f.create_tenant(TenantConfig::named("b", 16)).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn quota_throttles_and_meters() {
        let f = fabric(128);
        let limited = f
            .create_tenant(TenantConfig::named("limited", 128).quota(10, 100))
            .unwrap();
        let free = f.create_tenant(TenantConfig::named("free", 128)).unwrap();
        // burst of 100 admits the first chunk, refuses the next
        assert!(f.admit(limited, 100).unwrap().is_ok());
        let verdict = f.admit(limited, 100).unwrap();
        let backoff = verdict.expect_err("second burst must throttle");
        assert!(backoff > Duration::ZERO);
        // the hint is the honest token deficit: ~100 tokens at 10/s
        assert!(backoff <= Duration::from_secs(11), "hint {backoff:?}");
        assert!(f.admit(free, 1_000_000).unwrap().is_ok());
        let m = f.metrics();
        for t in &m.tenants {
            let expected = if t.id == limited { 1 } else { 0 };
            assert_eq!(t.snapshot.quota_rejections, expected, "tenant {}", t.id);
        }
    }

    #[test]
    fn quota_refills_over_time() {
        let q = QuotaState::new(1_000_000, 10);
        assert!(q.admit(10).is_ok());
        let backoff = q.admit(10).expect_err("bucket is empty");
        // 10 tokens at 1M/s: ~10µs — spin until the bucket refills
        // rather than sleeping (keeps the test robust under load)
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if q.admit(10).is_ok() {
                break;
            }
            assert!(Instant::now() < deadline, "bucket never refilled");
            std::hint::spin_loop();
        }
        assert!(backoff <= Duration::from_millis(1));
    }
}
