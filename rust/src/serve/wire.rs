//! The front-end wire protocol: length-delimited little-endian frames
//! in the same hand-rolled style as the worker protocol
//! (`crate::net`), but for *clients of logical graphs* rather than
//! delta workers.
//!
//! Every frame starts with a one-byte op tag.  Requests flow client →
//! server, responses server → client, strictly one response per
//! request, in order.  Field widths mirror the rest of the codebase:
//! vertex ids are `u32`, counters are `u64`, strings are
//! `u32`-length-prefixed UTF-8.
//!
//! An `INGEST` entry is `(u8 kind, u32 u, u32 v)` — 9 bytes, exactly
//! [`UPDATE_WIRE_BYTES`], so the serving layer's stream-byte
//! accounting equals the bytes a client actually put on this wire.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::coordinator::TenantId;
use crate::net::{read_count, read_u32, read_u64};
use crate::stream::update::{Update, UpdateKind, UPDATE_WIRE_BYTES};

/// Hard cap on `INGEST` entries, `REACH` pairs, and string lengths per
/// frame — a corrupt length prefix must not become a giant allocation.
pub const MAX_FRAME_ITEMS: usize = 1 << 20;

/// Machine-readable error codes carried by [`Response::Error`].
pub mod code {
    /// The named tenant id is not registered on the fabric.
    pub const UNKNOWN_TENANT: u8 = 1;
    /// The tenant still has live ingest handles (e.g. on another
    /// connection) and cannot be dropped yet.
    pub const TENANT_BUSY: u8 = 2;
    /// The fabric is at its configured tenant limit.
    pub const TENANT_LIMIT: u8 = 3;
    /// A tenant config was invalid (zero vertices, capacity above the
    /// fabric's, duplicate name).
    pub const BAD_CONFIG: u8 = 4;
    /// An update or query named a vertex outside the tenant's range.
    pub const VERTEX_RANGE: u8 = 5;
    /// The request itself was malformed or unsupported.
    pub const BAD_REQUEST: u8 = 6;
}

/// A client → server frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Register a new logical graph; answered by [`Response::Created`].
    Create {
        /// Human-readable tenant name (unique on the fabric).
        name: String,
        /// Logical vertex-id space `0..vertices` for this tenant.
        vertices: u64,
        /// Admission quota in updates/second (0 = unlimited).
        quota_rate: u64,
        /// Quota burst in updates (0 = derive one second's worth).
        quota_burst: u64,
    },
    /// Unregister a logical graph (refused while other connections
    /// still hold ingest handles on it).
    Drop {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Stream a chunk of updates into one tenant's graph.  Subject to
    /// the tenant's admission quota — an over-rate chunk is answered
    /// [`Response::Throttled`] and **not** applied (the client retries
    /// the same chunk after the hint).
    Ingest {
        /// Target tenant.
        tenant: TenantId,
        /// The updates, applied in order.
        updates: Vec<Update>,
    },
    /// Publish this connection's buffered tail and run the tenant's
    /// epoch cut + wait (the §5.3 query barrier, per tenant).
    Flush {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Connectivity snapshot query; answered by
    /// [`Response::Components`].
    Components {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Batched reachability query; answered by [`Response::Reach`].
    Reach {
        /// Target tenant.
        tenant: TenantId,
        /// The queried vertex pairs.
        pairs: Vec<(u32, u32)>,
    },
    /// Per-tenant metrics probe; answered by [`Response::Metrics`].
    Metrics {
        /// Target tenant.
        tenant: TenantId,
    },
    /// Orderly goodbye: the server drops this connection's ingest
    /// handles (publishing their tails) and answers [`Response::Ok`].
    Bye,
}

/// A server → client frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Generic success (DROP, FLUSH, INGEST, BYE).
    Ok,
    /// CREATE succeeded; carries the new tenant id.
    Created {
        /// The registered tenant id (use in every later request).
        tenant: TenantId,
    },
    /// The ingest chunk exceeded the tenant's admission quota and was
    /// **not** applied.  Never a silent drop: retry the same chunk
    /// after the hint.
    Throttled {
        /// Suggested client back-off before retrying.
        retry_after_micros: u64,
    },
    /// The request failed; `code` is one of [`code`]'s constants.
    Error {
        /// Machine-readable failure class.
        code: u8,
        /// Human-readable detail.
        message: String,
    },
    /// Connectivity answer over the tenant's vertex range.
    Components {
        /// Number of distinct components among `0..vertices`.
        num_components: u64,
        /// Component representative per vertex (`vertices` entries).
        component: Vec<u32>,
    },
    /// Batched reachability answer, one flag per queried pair.
    Reach {
        /// `true` where the pair is connected.
        answers: Vec<bool>,
    },
    /// Fixed per-tenant metrics block (a stable wire subset of
    /// [`crate::metrics::MetricsSnapshot`]).
    Metrics(WireMetrics),
}

/// The per-tenant counters exposed over the wire: enough for a client
/// to verify isolation (per-tenant Theorem 5.2 byte accounting, drop
/// freedom, quota pressure, promptness) without a side channel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireMetrics {
    /// Updates folded into this tenant's sketches.
    pub updates_ingested: u64,
    /// Stream bytes ingested (9 bytes/update — the Theorem 5.2 LHS).
    pub stream_bytes: u64,
    /// Batch bytes this tenant put on the worker wire (TBATCH2 frames).
    pub batch_bytes_sent: u64,
    /// Delta bytes returned to this tenant (TDELTA2 frames).
    pub delta_bytes_received: u64,
    /// Batches dropped for this tenant (must stay 0 in healthy runs).
    pub batches_dropped: u64,
    /// Ingest chunks refused by the admission quota (all answered with
    /// a retry hint — the no-silent-drop contract's visible half).
    pub quota_rejections: u64,
    /// Work items registered but not yet retired on this tenant's
    /// epoch barrier at snapshot time.
    pub queue_depth: u64,
    /// Total query wall-clock microseconds (the promptness signal).
    pub query_us: u64,
}

const OP_CREATE: u8 = 0;
const OP_DROP: u8 = 1;
const OP_INGEST: u8 = 2;
const OP_FLUSH: u8 = 3;
const OP_COMPONENTS: u8 = 4;
const OP_REACH: u8 = 5;
const OP_METRICS: u8 = 6;
const OP_BYE: u8 = 7;

const RESP_OK: u8 = 0;
const RESP_CREATED: u8 = 1;
const RESP_THROTTLED: u8 = 2;
const RESP_ERROR: u8 = 3;
const RESP_COMPONENTS: u8 = 4;
const RESP_REACH: u8 = 5;
const RESP_METRICS: u8 = 6;

fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    if s.len() > MAX_FRAME_ITEMS {
        bail!("string of {} bytes exceeds frame cap", s.len());
    }
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str<R: Read>(r: &mut R) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > MAX_FRAME_ITEMS {
        bail!("string length {n} exceeds frame cap");
    }
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    Ok(String::from_utf8(bytes)?)
}

fn read_tag<R: Read>(r: &mut R) -> Result<u8> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    Ok(tag[0])
}

fn checked_count<R: Read>(r: &mut R, what: &str) -> Result<usize> {
    let n = read_count(r, what)?;
    if n > MAX_FRAME_ITEMS {
        bail!("{what} count {n} exceeds frame cap");
    }
    Ok(n)
}

impl Request {
    /// Serialize onto `w` (flush is the caller's business).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        match self {
            Request::Create {
                name,
                vertices,
                quota_rate,
                quota_burst,
            } => {
                w.write_all(&[OP_CREATE])?;
                write_str(w, name)?;
                w.write_all(&vertices.to_le_bytes())?;
                w.write_all(&quota_rate.to_le_bytes())?;
                w.write_all(&quota_burst.to_le_bytes())?;
            }
            Request::Drop { tenant } => {
                w.write_all(&[OP_DROP])?;
                w.write_all(&tenant.to_le_bytes())?;
            }
            Request::Ingest { tenant, updates } => {
                if updates.len() > MAX_FRAME_ITEMS {
                    bail!("ingest chunk of {} exceeds frame cap", updates.len());
                }
                w.write_all(&[OP_INGEST])?;
                w.write_all(&tenant.to_le_bytes())?;
                w.write_all(&(updates.len() as u32).to_le_bytes())?;
                for u in updates {
                    let kind = match u.kind {
                        UpdateKind::Insert => 0u8,
                        UpdateKind::Delete => 1u8,
                    };
                    w.write_all(&[kind])?;
                    w.write_all(&u.u.to_le_bytes())?;
                    w.write_all(&u.v.to_le_bytes())?;
                }
            }
            Request::Flush { tenant } => {
                w.write_all(&[OP_FLUSH])?;
                w.write_all(&tenant.to_le_bytes())?;
            }
            Request::Components { tenant } => {
                w.write_all(&[OP_COMPONENTS])?;
                w.write_all(&tenant.to_le_bytes())?;
            }
            Request::Reach { tenant, pairs } => {
                if pairs.len() > MAX_FRAME_ITEMS {
                    bail!("reach batch of {} exceeds frame cap", pairs.len());
                }
                w.write_all(&[OP_REACH])?;
                w.write_all(&tenant.to_le_bytes())?;
                w.write_all(&(pairs.len() as u32).to_le_bytes())?;
                for (a, b) in pairs {
                    w.write_all(&a.to_le_bytes())?;
                    w.write_all(&b.to_le_bytes())?;
                }
            }
            Request::Metrics { tenant } => {
                w.write_all(&[OP_METRICS])?;
                w.write_all(&tenant.to_le_bytes())?;
            }
            Request::Bye => w.write_all(&[OP_BYE])?,
        }
        Ok(())
    }

    /// Deserialize one request from `r` (blocking; an EOF before the
    /// tag byte surfaces as the underlying io error).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        match read_tag(r)? {
            OP_CREATE => {
                let name = read_str(r)?;
                let vertices = read_u64(r)?;
                let quota_rate = read_u64(r)?;
                let quota_burst = read_u64(r)?;
                Ok(Request::Create {
                    name,
                    vertices,
                    quota_rate,
                    quota_burst,
                })
            }
            OP_DROP => Ok(Request::Drop {
                tenant: read_u32(r)?,
            }),
            OP_INGEST => {
                let tenant = read_u32(r)?;
                let n = checked_count(r, "ingest entries")?;
                let mut updates = Vec::with_capacity(n);
                for _ in 0..n {
                    let kind = read_tag(r)?;
                    let u = read_u32(r)?;
                    let v = read_u32(r)?;
                    updates.push(match kind {
                        0 => Update::insert(u, v),
                        1 => Update::delete(u, v),
                        other => bail!("unknown update kind {other}"),
                    });
                }
                Ok(Request::Ingest { tenant, updates })
            }
            OP_FLUSH => Ok(Request::Flush {
                tenant: read_u32(r)?,
            }),
            OP_COMPONENTS => Ok(Request::Components {
                tenant: read_u32(r)?,
            }),
            OP_REACH => {
                let tenant = read_u32(r)?;
                let n = checked_count(r, "reach pairs")?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    let a = read_u32(r)?;
                    let b = read_u32(r)?;
                    pairs.push((a, b));
                }
                Ok(Request::Reach { tenant, pairs })
            }
            OP_METRICS => Ok(Request::Metrics {
                tenant: read_u32(r)?,
            }),
            OP_BYE => Ok(Request::Bye),
            other => bail!("unknown request tag {other}"),
        }
    }

    /// This request's size on the wire in bytes (the serving layer's
    /// ingest accounting reuses [`UPDATE_WIRE_BYTES`] per entry, so
    /// stream-byte metering matches what the client actually sent).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Request::Create { name, .. } => 1 + 4 + name.len() as u64 + 8 + 8 + 8,
            Request::Drop { .. }
            | Request::Flush { .. }
            | Request::Components { .. }
            | Request::Metrics { .. } => 1 + 4,
            Request::Ingest { updates, .. } => {
                1 + 4 + 4 + updates.len() as u64 * UPDATE_WIRE_BYTES
            }
            Request::Reach { pairs, .. } => 1 + 4 + 4 + pairs.len() as u64 * 8,
            Request::Bye => 1,
        }
    }
}

impl Response {
    /// Serialize onto `w` (flush is the caller's business).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        match self {
            Response::Ok => w.write_all(&[RESP_OK])?,
            Response::Created { tenant } => {
                w.write_all(&[RESP_CREATED])?;
                w.write_all(&tenant.to_le_bytes())?;
            }
            Response::Throttled { retry_after_micros } => {
                w.write_all(&[RESP_THROTTLED])?;
                w.write_all(&retry_after_micros.to_le_bytes())?;
            }
            Response::Error { code, message } => {
                w.write_all(&[RESP_ERROR, *code])?;
                write_str(w, message)?;
            }
            Response::Components {
                num_components,
                component,
            } => {
                if component.len() > MAX_FRAME_ITEMS {
                    bail!("component map of {} exceeds frame cap", component.len());
                }
                w.write_all(&[RESP_COMPONENTS])?;
                w.write_all(&num_components.to_le_bytes())?;
                w.write_all(&(component.len() as u32).to_le_bytes())?;
                for c in component {
                    w.write_all(&c.to_le_bytes())?;
                }
            }
            Response::Reach { answers } => {
                if answers.len() > MAX_FRAME_ITEMS {
                    bail!("reach answer of {} exceeds frame cap", answers.len());
                }
                w.write_all(&[RESP_REACH])?;
                w.write_all(&(answers.len() as u32).to_le_bytes())?;
                for a in answers {
                    w.write_all(&[u8::from(*a)])?;
                }
            }
            Response::Metrics(m) => {
                w.write_all(&[RESP_METRICS])?;
                for x in [
                    m.updates_ingested,
                    m.stream_bytes,
                    m.batch_bytes_sent,
                    m.delta_bytes_received,
                    m.batches_dropped,
                    m.quota_rejections,
                    m.queue_depth,
                    m.query_us,
                ] {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Deserialize one response from `r` (blocking).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        match read_tag(r)? {
            RESP_OK => Ok(Response::Ok),
            RESP_CREATED => Ok(Response::Created {
                tenant: read_u32(r)?,
            }),
            RESP_THROTTLED => Ok(Response::Throttled {
                retry_after_micros: read_u64(r)?,
            }),
            RESP_ERROR => {
                let code = read_tag(r)?;
                let message = read_str(r)?;
                Ok(Response::Error { code, message })
            }
            RESP_COMPONENTS => {
                let num_components = read_u64(r)?;
                let n = checked_count(r, "component map")?;
                let mut component = Vec::with_capacity(n);
                for _ in 0..n {
                    component.push(read_u32(r)?);
                }
                Ok(Response::Components {
                    num_components,
                    component,
                })
            }
            RESP_REACH => {
                let n = checked_count(r, "reach answers")?;
                let mut answers = Vec::with_capacity(n);
                for _ in 0..n {
                    answers.push(read_tag(r)? != 0);
                }
                Ok(Response::Reach { answers })
            }
            RESP_METRICS => {
                let mut xs = [0u64; 8];
                for x in xs.iter_mut() {
                    *x = read_u64(r)?;
                }
                Ok(Response::Metrics(WireMetrics {
                    updates_ingested: xs[0],
                    stream_bytes: xs[1],
                    batch_bytes_sent: xs[2],
                    delta_bytes_received: xs[3],
                    batches_dropped: xs[4],
                    quota_rejections: xs[5],
                    queue_depth: xs[6],
                    query_us: xs[7],
                }))
            }
            other => bail!("unknown response tag {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_request(req: Request) {
        let mut buf = Vec::new();
        req.write_to(&mut buf).unwrap();
        assert_eq!(
            buf.len() as u64,
            req.wire_bytes(),
            "wire_bytes must equal serialized length for {req:?}"
        );
        let back = Request::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back, req);
    }

    fn round_trip_response(resp: Response) {
        let mut buf = Vec::new();
        resp.write_to(&mut buf).unwrap();
        let back = Response::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn requests_round_trip() {
        round_trip_request(Request::Create {
            name: "tenant-a".into(),
            vertices: 1 << 12,
            quota_rate: 10_000,
            quota_burst: 0,
        });
        round_trip_request(Request::Drop { tenant: 3 });
        round_trip_request(Request::Ingest {
            tenant: 7,
            updates: vec![Update::insert(1, 2), Update::delete(2, 3)],
        });
        round_trip_request(Request::Flush { tenant: 1 });
        round_trip_request(Request::Components { tenant: 2 });
        round_trip_request(Request::Reach {
            tenant: 2,
            pairs: vec![(0, 9), (4, 4)],
        });
        round_trip_request(Request::Metrics { tenant: 9 });
        round_trip_request(Request::Bye);
    }

    #[test]
    fn responses_round_trip() {
        round_trip_response(Response::Ok);
        round_trip_response(Response::Created { tenant: 12 });
        round_trip_response(Response::Throttled {
            retry_after_micros: 1500,
        });
        round_trip_response(Response::Error {
            code: code::UNKNOWN_TENANT,
            message: "tenant 9 is not registered".into(),
        });
        round_trip_response(Response::Components {
            num_components: 2,
            component: vec![0, 0, 2, 2],
        });
        round_trip_response(Response::Reach {
            answers: vec![true, false, true],
        });
        round_trip_response(Response::Metrics(WireMetrics {
            updates_ingested: 10,
            stream_bytes: 90,
            batch_bytes_sent: 400,
            delta_bytes_received: 800,
            batches_dropped: 0,
            quota_rejections: 3,
            queue_depth: 1,
            query_us: 250,
        }));
    }

    #[test]
    fn ingest_entry_is_update_wire_bytes() {
        // the 9-byte (kind, u, v) entry is the same unit the rest of
        // the codebase meters stream bytes in
        let req = Request::Ingest {
            tenant: 0,
            updates: vec![Update::insert(5, 6)],
        };
        assert_eq!(req.wire_bytes(), 1 + 4 + 4 + UPDATE_WIRE_BYTES);
    }

    #[test]
    fn junk_tags_are_rejected() {
        assert!(Request::read_from(&mut [0xFFu8].as_slice()).is_err());
        assert!(Response::read_from(&mut [0xFFu8].as_slice()).is_err());
    }
}
