//! The session-based public API (paper Fig. 2's many-producers /
//! one-merge-path data flow, as an API shape).
//!
//! The previous surface was a single-owner `Coordinator` whose
//! `ingest(&mut self)` serialized the entire front end on one driver
//! thread — exactly the front-end bottleneck GraphZeppelin identifies
//! for sketch-based stream systems, and an artificial one: every stage
//! past the thread-local hypertree levels was already concurrent.  This
//! module replaces it with a **session**:
//!
//! * [`Landscape::builder`] validates configuration up front (typed
//!   [`ConfigError`] instead of silent clamps or panics deep inside the
//!   distributor spawn path) and builds a shared [`Landscape`] session.
//! * [`Landscape::ingest_handle`] spawns any number of independent
//!   [`IngestHandle`]s — each is `Send`, owns its own thread-local
//!   hypertree levels plus a bounded update log, and ingests without
//!   taking a single cross-thread lock on the per-update path.
//! * [`Landscape::query_handle`] gives a cloneable, `Sync`
//!   [`QueryHandle`] answering connectivity / reachability /
//!   k-connectivity queries without `&mut` access to ingestion.
//!
//! ## Consistency contract
//!
//! A query reflects every update that has been *published*: drained
//! from its producer's handle by [`IngestHandle::flush`] (or by
//! dropping the handle, which flushes).  Producers that have not
//! flushed may be partially visible — the paper's query barrier (§5.3)
//! covers the shared pipeline, not other threads' private buffers.
//! [`Landscape::pending_producers`] reports how many handles still
//! hold unpublished updates.
//!
//! The barrier itself is an **epoch cut**, not a quiescence point: a
//! query (or an explicit [`Landscape::cut`] / [`QueryHandle::snapshot`])
//! closes the current epoch and waits only for work registered before
//! the cut, so it returns promptly even while producers keep streaming
//! at full rate.  The guarantee is one-sided: the answer covers *at
//! least* every update published before the cut, and may additionally
//! include updates published after it (the sketch path keeps merging
//! behind the cut; nothing is rolled back).
//!
//! ```no_run
//! use landscape::session::Landscape;
//! use landscape::stream::update::Update;
//!
//! let session = Landscape::builder().vertices(1 << 10).build().unwrap();
//! std::thread::scope(|scope| {
//!     for producer in 0..4u32 {
//!         let mut handle = session.ingest_handle();
//!         scope.spawn(move || {
//!             for i in 0..250u32 {
//!                 handle.ingest(Update::insert(producer * 250 + i, 1000 + i % 24));
//!             }
//!         }); // drop publishes the handle's tail
//!     }
//! });
//! let queries = session.query_handle();
//! println!("{} components", queries.connected_components().num_components());
//! ```

#![deny(missing_docs)]

mod handle;

pub use handle::{IngestHandle, QueryHandle, Snapshot};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::connectivity::boruvka::{boruvka_components, boruvka_components_from};
use crate::connectivity::greedycc::PartialSeed;
use crate::connectivity::kconn::KConnectivity;
use crate::connectivity::SpanningForest;
use crate::coordinator::arena::BatchArena;
use crate::coordinator::query::{QueryEngine, QueryTier};
use crate::coordinator::work_queue::{Cut, EpochBarrier, ShardedWorkQueue};
use crate::coordinator::{
    distributor, BufferKind, CoordinatorConfig, SoloDirectory, TenantId, TenantRuntime, WorkItem,
    WorkerKind, SOLO_TENANT,
};
use crate::gutter::GutterBuffer;
use crate::hypertree::{BatchSink, Hypertree, HypertreeConfig, VertexBatch};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::sketch::params::SketchParams;
use crate::sketch::shard::ShardSpec;
use crate::storage::{Backing, DurabilityLog, SpillBacking, SpillConfig};
use crate::stream::update::Update;

/// Default bounded size of each ingest handle's update log (updates
/// buffered per handle before GreedyCC maintenance is applied under one
/// amortized lock).
pub const DEFAULT_UPDATE_LOG_CAPACITY: usize = 1024;

/// A configuration rejected by [`LandscapeBuilder::build`].
///
/// Every variant names the invalid knob; the old surface either
/// silently clamped these (`distributor_threads = 0` became 1) or
/// panicked deep inside the distributor spawn path (`queue_capacity =
/// 0` tripped an assert in `WorkQueue::new`; an empty remote address
/// list abandoned every shard with metered drops).
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// `vertices` was 0 — an empty graph has no sketch shape.
    ZeroVertices,
    /// `vertices` exceeded `u32::MAX`; endpoints are `u32` on the wire.
    TooManyVertices(u64),
    /// `k` was 0 — at least one sketch copy is needed.
    ZeroK,
    /// `columns` was 0 — sketches need at least one column.
    ZeroColumns,
    /// `alpha` was 0 — leaves would have zero capacity and every update
    /// would recirculate forever.
    ZeroAlpha,
    /// `gamma` was outside `(0, 1]` (or NaN): the γ-fullness flush
    /// policy needs a positive fraction of leaf capacity.
    GammaOutOfRange(f64),
    /// `distributor_threads` was 0 — no thread would ever drain the
    /// work queues.
    ZeroDistributorThreads,
    /// `queue_capacity` was 0 — the bounded shard queues cannot hold a
    /// single batch.
    ZeroQueueCapacity,
    /// `remote_window` was 0 — a remote connection could never have a
    /// batch in flight.
    ZeroRemoteWindow,
    /// `update_log_capacity` was 0 — handles could never buffer an
    /// update.
    ZeroUpdateLogCapacity,
    /// `WorkerKind::Remote` with an empty address list — there is no
    /// worker to connect to.
    NoRemoteWorkerAddrs,
    /// `hybrid_demote_floor` was set while `hybrid_threshold` was 0 —
    /// a demotion floor is meaningless without the hybrid tier.
    HybridFloorWithoutThreshold,
    /// `hybrid_demote_floor` ≥ `hybrid_threshold` — the hysteresis band
    /// would be empty (or inverted) and vertices would oscillate between
    /// tiers on every update at the boundary.
    HybridFloorTooHigh(u32, u32),
    /// `storage_dir` was set together with a nonzero `hybrid_threshold`
    /// — the spill tier keeps every vertex as a fixed-size on-disk
    /// block and cannot host the hybrid tier's variable-size exact
    /// sets.
    SpillWithHybrid,
    /// `resident_budget_bytes` was set without `storage_dir` — a
    /// resident budget only means something when there is somewhere to
    /// spill to.
    BudgetWithoutStorageDir,
    /// `resident_budget_bytes` cannot hold one sketch block per shard
    /// stripe per copy (`(given, minimum)`); below that the LRU would
    /// thrash on every merge.
    ResidentBudgetTooSmall(u64, u64),
    /// Opening the storage tier failed (segment files, WAL, or WAL-tail
    /// replay).  A fresh `build()` refuses a directory that already
    /// holds a WAL — use [`Landscape::recover`] for that.
    StorageIo(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroVertices => write!(f, "vertices must be nonzero"),
            ConfigError::TooManyVertices(v) => {
                write!(f, "vertices = {v} exceeds u32::MAX (wire endpoints are u32)")
            }
            ConfigError::ZeroK => write!(f, "k (sketch copies) must be nonzero"),
            ConfigError::ZeroColumns => write!(f, "columns must be nonzero"),
            ConfigError::ZeroAlpha => write!(f, "alpha (batch-size factor) must be nonzero"),
            ConfigError::GammaOutOfRange(g) => {
                write!(f, "gamma = {g} is outside the valid flush-threshold range (0, 1]")
            }
            ConfigError::ZeroDistributorThreads => {
                write!(f, "distributor_threads must be nonzero")
            }
            ConfigError::ZeroQueueCapacity => write!(f, "queue_capacity must be nonzero"),
            ConfigError::ZeroRemoteWindow => write!(f, "remote_window must be nonzero"),
            ConfigError::ZeroUpdateLogCapacity => {
                write!(f, "update_log_capacity must be nonzero")
            }
            ConfigError::NoRemoteWorkerAddrs => {
                write!(f, "WorkerKind::Remote requires at least one worker address")
            }
            ConfigError::HybridFloorWithoutThreshold => {
                write!(
                    f,
                    "hybrid_demote_floor requires hybrid_threshold to be nonzero"
                )
            }
            ConfigError::HybridFloorTooHigh(floor, threshold) => {
                write!(
                    f,
                    "hybrid_demote_floor = {floor} must stay strictly below \
                     hybrid_threshold = {threshold} (hysteresis band)"
                )
            }
            ConfigError::SpillWithHybrid => {
                write!(
                    f,
                    "storage_dir cannot be combined with hybrid_threshold: the \
                     spill tier stores fixed-size sketch blocks only"
                )
            }
            ConfigError::BudgetWithoutStorageDir => {
                write!(
                    f,
                    "resident_budget_bytes requires storage_dir (nothing to \
                     spill to otherwise)"
                )
            }
            ConfigError::ResidentBudgetTooSmall(given, min) => {
                write!(
                    f,
                    "resident_budget_bytes = {given} cannot hold one sketch \
                     block per shard stripe per copy (minimum {min})"
                )
            }
            ConfigError::StorageIo(msg) => {
                write!(f, "storage tier setup failed: {msg}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Validated, typed construction of a [`Landscape`] session.
///
/// Defaults mirror [`CoordinatorConfig::for_vertices`] (paper §6 /
/// App. E); `vertices` has no default and must be set.
#[derive(Clone, Debug)]
pub struct LandscapeBuilder {
    cfg: CoordinatorConfig,
    update_log_capacity: usize,
    storage_dir: Option<std::path::PathBuf>,
    resident_budget_bytes: Option<u64>,
}

impl Default for LandscapeBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl LandscapeBuilder {
    /// A builder with paper-default knobs and `vertices` unset (0).
    pub fn new() -> Self {
        Self {
            cfg: CoordinatorConfig::for_vertices(0),
            update_log_capacity: DEFAULT_UPDATE_LOG_CAPACITY,
            storage_dir: None,
            resident_budget_bytes: None,
        }
    }

    /// Start from an existing [`CoordinatorConfig`] (migration path).
    pub fn from_config(cfg: CoordinatorConfig) -> Self {
        Self {
            cfg,
            update_log_capacity: DEFAULT_UPDATE_LOG_CAPACITY,
            storage_dir: None,
            resident_budget_bytes: None,
        }
    }

    /// Number of graph vertices (required; must be `1..=u32::MAX`).
    pub fn vertices(mut self, v: u64) -> Self {
        self.cfg.vertices = v;
        self
    }

    /// Seed for the sketch hash functions.
    pub fn graph_seed(mut self, seed: u64) -> Self {
        self.cfg.graph_seed = seed;
        self
    }

    /// k-connectivity copies (1 = plain connectivity).
    pub fn k(mut self, k: u32) -> Self {
        self.cfg.k = k;
        self
    }

    /// Sketch columns per level.
    pub fn columns(mut self, columns: u32) -> Self {
        self.cfg.columns = columns;
        self
    }

    /// Batch-size factor α (a leaf holds α× the delta's size in updates).
    pub fn alpha(mut self, alpha: u32) -> Self {
        self.cfg.alpha = alpha;
        self
    }

    /// Query-flush fullness threshold γ ∈ (0, 1] (paper default 0.04).
    pub fn gamma(mut self, gamma: f64) -> Self {
        self.cfg.gamma = gamma;
        self
    }

    /// Distributor threads (= sketch shards = shard queues).
    pub fn distributor_threads(mut self, n: usize) -> Self {
        self.cfg.distributor_threads = n;
        self
    }

    /// Work-queue capacity in batches, per shard queue.
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.cfg.queue_capacity = n;
        self
    }

    /// Which delta-computation backend the distributor threads use.
    pub fn worker(mut self, worker: WorkerKind) -> Self {
        self.cfg.worker = worker;
        self
    }

    /// In-flight window per remote-worker connection.
    pub fn remote_window(mut self, n: usize) -> Self {
        self.cfg.remote_window = n;
        self
    }

    /// Which update-buffering structure the main node uses.
    pub fn buffer(mut self, buffer: BufferKind) -> Self {
        self.cfg.buffer = buffer;
        self
    }

    /// Enable or disable the GreedyCC query accelerator.
    pub fn greedycc(mut self, enabled: bool) -> Self {
        self.cfg.use_greedycc = enabled;
        self
    }

    /// Bounded per-handle update-log size (updates buffered before
    /// GreedyCC maintenance drains under one amortized lock).
    pub fn update_log_capacity(mut self, n: usize) -> Self {
        self.update_log_capacity = n;
        self
    }

    /// Hybrid vertex-tier promotion threshold: vertices hold a compact
    /// exact neighbor set until it exceeds `t` surviving edges, then
    /// promote to a CAMEO sketch block (0 — the default — disables the
    /// hybrid tier entirely).
    pub fn hybrid_threshold(mut self, t: u32) -> Self {
        self.cfg.hybrid_threshold = t;
        self
    }

    /// Demotion hysteresis floor: a promoted vertex whose tracked
    /// neighbor set shrinks below `f` demotes back to exact.  0 derives
    /// `hybrid_threshold / 2`; any explicit value must stay strictly
    /// below the threshold.
    pub fn hybrid_demote_floor(mut self, f: u32) -> Self {
        self.cfg.hybrid_demote_floor = f;
        self
    }

    /// Back the sketch store with the external-memory spill tier under
    /// `dir`: segment files per copy plus an append-only write-ahead
    /// log, fsync'd at epoch cuts so [`Landscape::flush`] doubles as a
    /// durability point.  A fresh `build()` refuses a directory that
    /// already holds a WAL; reopen such a directory with
    /// [`Landscape::recover`] instead.  Mutually exclusive with the
    /// hybrid tier.  See `docs/STORAGE.md`.
    pub fn storage_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.storage_dir = Some(dir.into());
        self
    }

    /// Bound on in-memory sketch bytes per session when spilling:
    /// each copy's store keeps a bounded LRU set of hot vertex blocks
    /// resident and pages the rest to its segment files.  Unset means
    /// unlimited (durability without spilling).  Requires
    /// [`LandscapeBuilder::storage_dir`]; must hold at least one block
    /// per shard stripe per copy.
    pub fn resident_budget_bytes(mut self, bytes: u64) -> Self {
        self.resident_budget_bytes = Some(bytes);
        self
    }

    /// Check every knob, returning the first violation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let c = &self.cfg;
        if c.vertices == 0 {
            return Err(ConfigError::ZeroVertices);
        }
        if c.vertices > u32::MAX as u64 {
            return Err(ConfigError::TooManyVertices(c.vertices));
        }
        if c.k == 0 {
            return Err(ConfigError::ZeroK);
        }
        if c.columns == 0 {
            return Err(ConfigError::ZeroColumns);
        }
        if c.alpha == 0 {
            return Err(ConfigError::ZeroAlpha);
        }
        if !(c.gamma > 0.0 && c.gamma <= 1.0) {
            return Err(ConfigError::GammaOutOfRange(c.gamma));
        }
        if c.distributor_threads == 0 {
            return Err(ConfigError::ZeroDistributorThreads);
        }
        if c.queue_capacity == 0 {
            return Err(ConfigError::ZeroQueueCapacity);
        }
        if c.remote_window == 0 {
            return Err(ConfigError::ZeroRemoteWindow);
        }
        if self.update_log_capacity == 0 {
            return Err(ConfigError::ZeroUpdateLogCapacity);
        }
        if let WorkerKind::Remote { addrs } = &c.worker {
            if addrs.is_empty() {
                return Err(ConfigError::NoRemoteWorkerAddrs);
            }
        }
        if c.hybrid_threshold == 0 && c.hybrid_demote_floor != 0 {
            return Err(ConfigError::HybridFloorWithoutThreshold);
        }
        if c.hybrid_threshold > 0 && c.hybrid_demote_floor >= c.hybrid_threshold {
            return Err(ConfigError::HybridFloorTooHigh(
                c.hybrid_demote_floor,
                c.hybrid_threshold,
            ));
        }
        if self.storage_dir.is_some() && c.hybrid_threshold > 0 {
            return Err(ConfigError::SpillWithHybrid);
        }
        if self.resident_budget_bytes.is_some() && self.storage_dir.is_none() {
            return Err(ConfigError::BudgetWithoutStorageDir);
        }
        if let Some(budget) = self.resident_budget_bytes {
            // one block per shard stripe per copy, or the LRU thrashes
            // on every merge
            let block_bytes = 8 + c.params().words() as u64 * 8;
            let min = c.k as u64 * c.shard_spec().count() as u64 * block_bytes;
            if budget < min {
                return Err(ConfigError::ResidentBudgetTooSmall(budget, min));
            }
        }
        Ok(())
    }

    /// Validate and build the session (fresh state; refuses a
    /// `storage_dir` that already holds a WAL).
    pub fn build(self) -> Result<Landscape, ConfigError> {
        self.validate()?;
        let storage = self.open_storage(false)?;
        Landscape::spawn(self.cfg, self.update_log_capacity, storage)
    }

    /// Validate and **recover** the session from its `storage_dir`:
    /// reopen the checkpointed segment files, replay the WAL tail past
    /// the last durable cut, and resume.  See [`Landscape::recover`].
    pub fn recover(self) -> Result<Landscape, ConfigError> {
        self.validate()?;
        if self.storage_dir.is_none() {
            return Err(ConfigError::StorageIo(
                "recover requires storage_dir".to_string(),
            ));
        }
        let storage = self.open_storage(true)?;
        Landscape::spawn(self.cfg, self.update_log_capacity, storage)
    }

    /// Open the spill backings (one per copy) and the WAL under
    /// `storage_dir`; `None` when the session is purely resident.
    fn open_storage(&self, recovering: bool) -> Result<Option<StorageRuntime>, ConfigError> {
        let Some(dir) = &self.storage_dir else {
            return Ok(None);
        };
        let io = |e: std::io::Error| ConfigError::StorageIo(e.to_string());
        std::fs::create_dir_all(dir).map_err(io)?;
        let c = &self.cfg;
        let params = c.params();
        let spec = c.shard_spec();
        let k = c.k as usize;
        let per_copy = match self.resident_budget_bytes {
            // unset = unlimited: durability without spilling
            None => u64::MAX,
            Some(b) => b / k as u64,
        };
        let wal_path = dir.join("wal.log");
        let wal = if recovering {
            DurabilityLog::open_append(&wal_path).map_err(io)?
        } else {
            // create_new underneath: an existing WAL means live state —
            // refusing here is what makes accidental clobbering a typed
            // error instead of silent data loss
            DurabilityLog::create(&wal_path).map_err(|e| {
                if e.kind() == std::io::ErrorKind::AlreadyExists {
                    ConfigError::StorageIo(format!(
                        "{} already holds a WAL — use Landscape::recover \
                         to reopen it",
                        dir.display()
                    ))
                } else {
                    io(e)
                }
            })?
        };
        let wal = Arc::new(wal);
        let mut backings = Vec::with_capacity(k);
        for copy in 0..k {
            let scfg = SpillConfig::new(dir.join(format!("copy{copy}")), per_copy);
            let backing =
                SpillBacking::open(params.words(), c.vertices, spec, &scfg, wal.watermark())
                    .map_err(io)?;
            backings.push(Backing::Spill(backing));
        }
        Ok(Some(StorageRuntime {
            backings,
            wal,
            recovering,
        }))
    }
}

/// Opened storage-tier state handed from the builder to
/// [`Landscape::spawn`]: the per-copy backings, the shared WAL, and
/// whether a WAL-tail replay is owed before ingest resumes.
struct StorageRuntime {
    backings: Vec<Backing>,
    wal: Arc<DurabilityLog>,
    recovering: bool,
}

/// Report returned by [`IngestHandle::ingest_all`].
#[derive(Clone, Copy, Debug)]
pub struct IngestReport {
    /// Stream updates ingested by this call.
    pub updates: u64,
    /// Wall-clock seconds spent ingesting.
    pub seconds: f64,
}

impl IngestReport {
    /// Updates per second.
    pub fn rate(&self) -> f64 {
        crate::util::timer::rate(self.updates, self.seconds)
    }
}

/// Update buffer: hypertree or gutter (ablation), behind one interface.
pub(crate) enum Buffer {
    /// The pipeline hypertree (the paper's design).
    Hyper(Arc<Hypertree>),
    /// GraphZeppelin-style gutters (ablation baseline).
    Gutter(Arc<GutterBuffer>),
}

/// Shared sink: every batch is routed to the shard queue of the
/// distributor thread owning its vertex.  Underfull leaves travel the
/// same shard-affine path as `WorkItem::Local` so that *all* sketch
/// writes during ingestion happen on the owning thread — which is what
/// makes the distributors' lock-free exclusive merge sound.
pub(crate) struct QueueSink {
    queue: Arc<ShardedWorkQueue<WorkItem>>,
    spec: ShardSpec,
    /// Which logical graph this sink feeds ([`SOLO_TENANT`] for
    /// single-tenant sessions).  Every work item is tagged with it so
    /// the distributors can resolve the owning tenant's state at merge.
    tenant: TenantId,
    metrics: Arc<Metrics>,
    barrier: Arc<EpochBarrier>,
    /// Batch buffers recycled by the distributors once their work
    /// completes; `local_batch` draws from here instead of allocating a
    /// fresh `Vec` per underfull leaf.
    arena: Arc<BatchArena>,
    /// Meter `batch_bytes_sent` here with the nominal 8+4n accounting.
    /// True for in-process workers (nothing crosses a wire, the nominal
    /// figure *is* the model); false for remote workers, where the
    /// distributor meters the real framing-layer bytes instead.
    meter_batch_bytes: bool,
}

impl QueueSink {
    /// Register the batch with the epoch barrier (minting the ticket
    /// that travels with it to the merge) and push it onto its shard
    /// queue.
    fn enqueue(&self, shard: usize, local: bool, batch: VertexBatch) {
        let (kind, vertex, len) = (
            if local { "local" } else { "distribute" },
            batch.vertex,
            batch.others.len(),
        );
        let ticket = self.barrier.register();
        let item = if local {
            WorkItem::Local(self.tenant, ticket, batch)
        } else {
            WorkItem::Distribute(self.tenant, ticket, batch)
        };
        if let Err(item) = self.queue.push(shard, item) {
            // the shard queue is closed: these updates will never reach
            // a sketch, which silently corrupts every later query —
            // meter and log instead of vanishing (and retire the ticket
            // so no cut waits on work that will never run)
            self.barrier.complete(ticket);
            Metrics::add(&self.metrics.batches_dropped, 1);
            let (WorkItem::Distribute(_, _, batch) | WorkItem::Local(_, _, batch)) = item;
            self.arena.recycle(shard, batch.others);
            crate::log_warn!(
                "session: DROPPED {kind} batch (vertex {vertex}, {len} \
                 updates) on closed shard queue {shard}"
            );
        }
    }
}

impl BatchSink for QueueSink {
    fn shards(&self) -> ShardSpec {
        self.spec
    }

    fn full_batch(&self, shard: usize, batch: VertexBatch) {
        debug_assert_eq!(shard, self.spec.shard_of(batch.vertex));
        Metrics::add(&self.metrics.batches_sent, 1);
        if self.meter_batch_bytes {
            Metrics::add(&self.metrics.batch_bytes_sent, batch.wire_bytes());
        }
        self.enqueue(shard, false, batch);
    }

    fn local_batch(&self, shard: usize, vertex: u32, others: &[u32]) {
        debug_assert_eq!(shard, self.spec.shard_of(vertex));
        // Draw the batch buffer from the per-shard arena instead of
        // allocating: at full ingest rate this path runs once per leaf
        // flush, and the buffer rides the whole pipeline before coming
        // back via `Completion::others`.
        let mut buf = self.arena.acquire(shard);
        buf.extend_from_slice(others);
        self.enqueue(shard, true, VertexBatch { vertex, others: buf });
    }
}

/// Everything the handles share: the engine room behind the session.
pub(crate) struct SessionCore {
    pub(crate) config: CoordinatorConfig,
    pub(crate) params: SketchParams,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) kconn: Arc<KConnectivity>,
    pub(crate) buffer: Buffer,
    pub(crate) sink: Arc<QueueSink>,
    queue: Arc<ShardedWorkQueue<WorkItem>>,
    barrier: Arc<EpochBarrier>,
    pub(crate) query: QueryEngine,
    /// Serializes tiered queries (plan → flush → Borůvka → re-seed is a
    /// read-modify-write of the accelerator state) *and* handle log
    /// drains: a drain landing between a query's seed snapshot and its
    /// re-seed would be wiped by the wholesale `reseed`, so
    /// [`SessionCore::apply_log`] takes this lock too.
    query_serial: Mutex<()>,
    /// Keeps sketch reads torn-write-free now that queries run while
    /// distributors keep merging: each distributor holds the gate
    /// *shared* for the duration of one batch merge (multi-word XOR),
    /// and a query holds it *exclusively* for the duration of its
    /// sketch read.  Taken only after [`SessionCore::wait_for_cut`]
    /// returns, so it never waits on pre-cut work — it just holds
    /// post-cut merges off the store for the O(read) critical section,
    /// guaranteeing every delta is either fully visible or fully
    /// invisible to the read.
    ///
    /// The atomicity is **batch-granular**, not update-granular: an
    /// update enters the buffers once per endpoint, and its two
    /// per-vertex batches can straddle the read, leaving a post-cut
    /// update visible at one endpoint only.  That is sound: a
    /// half-visible entry cannot decode as a fabricated edge (level
    /// checksums reject torn combinations), so at worst it adds bucket
    /// collisions of the same kind any real extra edge adds — which the
    /// multi-level/multi-column sketch tolerates w.h.p. by design —
    /// while every *pre-cut* update is fully merged at both endpoints
    /// before the read begins (that is what `wait_for_cut` waited for).
    merge_gate: Arc<RwLock<()>>,
    /// The write-ahead log when the store spills (`storage_dir` set):
    /// distributors append to it before merging; [`Landscape::flush`]
    /// checkpoints the segments and fsyncs a cut marker through it.
    wal: Option<Arc<DurabilityLog>>,
    pub(crate) update_log_capacity: usize,
    active_handles: AtomicUsize,
    /// Live handles currently holding *unpublished* updates (private
    /// log entries or thread-local hypertree entries).  Maintained by
    /// the handles on the empty↔nonempty edge.
    pub(crate) pending_handles: AtomicUsize,
}

impl SessionCore {
    /// Take a stream cut over the *shared* pipeline (§5.3's query
    /// boundary, as an explicit cut instead of a quiescence point):
    /// force-flush the buffer (γ-full leaves to workers, the rest
    /// locally), then advance the epoch barrier.  Cheap — no waiting
    /// happens here.  The returned [`Cut`] covers every update
    /// *published* before this call; pass it to
    /// [`SessionCore::wait_for_cut`] before reading the sketches.
    ///
    /// Does not — cannot — drain other threads' unflushed ingest
    /// handles; their unpublished tails land in later epochs.
    pub(crate) fn cut_shared(&self) -> Cut {
        match &self.buffer {
            Buffer::Hyper(t) => t.force_flush(self.config.gamma, &*self.sink),
            Buffer::Gutter(g) => g.force_flush(self.config.gamma, &*self.sink),
        }
        let cut = self.barrier.cut();
        Metrics::add(&self.metrics.cuts_taken, 1);
        Metrics::raise(&self.metrics.epoch_current, cut.epoch() + 1);
        cut
    }

    /// Block until every work item registered before `cut` has merged.
    ///
    /// Liveness: bounded by the work in flight at cut time — producers
    /// registering work *after* the cut never extend the wait, so
    /// queries return promptly even under sustained full-rate
    /// multi-producer ingestion (the lull-waiting `wait_idle` design
    /// this replaces could block indefinitely there).
    pub(crate) fn wait_for_cut(&self, cut: Cut) {
        let t0 = Instant::now();
        self.barrier.wait_for(cut);
        Metrics::add(
            &self.metrics.cut_wait_us,
            t0.elapsed().as_micros() as u64,
        );
    }

    /// The cut-then-wait barrier: settle `pinned` if given (snapshot
    /// queries re-wait on their pinned cut — free once retired —
    /// instead of flushing again), else take a fresh cut and wait for
    /// it.
    fn settle(&self, pinned: Option<Cut>) {
        let cut = pinned.unwrap_or_else(|| self.cut_shared());
        self.wait_for_cut(cut);
    }

    /// The tier that would answer a global connectivity query now.
    pub(crate) fn query_plan(&self) -> QueryTier {
        self.query.plan()
    }

    /// Tiered global connectivity query (see `QueryEngine` for the tier
    /// table).
    pub(crate) fn connected_components(&self) -> SpanningForest {
        self.connected_components_at(None)
    }

    /// Tiered global connectivity query over `pinned` (a snapshot's
    /// cut) when given, else over a fresh cut.
    ///
    /// Tier 0 needs no barrier in either mode: GreedyCC learns an
    /// update at its log drain, which happens *after* the update's
    /// sketch publication (see `IngestHandle::publish`), so by the time
    /// a cut is taken every update it covers that tier 0 would answer
    /// from is already in the accelerator.
    pub(crate) fn connected_components_at(&self, pinned: Option<Cut>) -> SpanningForest {
        self.metered_query(|| {
            let _serial = self.query_serial.lock().unwrap();
            if let Some(forest) = self.query.try_greedy() {
                Metrics::add(&self.metrics.queries_greedy, 1);
                return forest;
            }
            if let Some(seed) = self.query.partial_seed() {
                return self.partial_query_locked(seed, pinned);
            }
            self.full_query_locked(pinned)
        })
    }

    /// Meter one query's wall-clock latency into `query_us` (the
    /// per-tenant promptness signal behind the serving layer's
    /// isolation checks), passing the result through.
    fn metered_query<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        Metrics::add(&self.metrics.query_us, t0.elapsed().as_micros() as u64);
        out
    }

    /// Forced tier-2 (cut + full Borůvka) query.
    pub(crate) fn full_connectivity_query(&self) -> SpanningForest {
        self.full_connectivity_query_at(None)
    }

    /// Forced tier-2 query over `pinned` when given, else a fresh cut.
    pub(crate) fn full_connectivity_query_at(&self, pinned: Option<Cut>) -> SpanningForest {
        self.metered_query(|| {
            let _serial = self.query_serial.lock().unwrap();
            self.full_query_locked(pinned)
        })
    }

    /// Batched reachability: tier 0 answers when no queried pair
    /// touches a dirty component; otherwise escalate like a global
    /// query.
    pub(crate) fn reachability(&self, pairs: &[(u32, u32)]) -> Vec<bool> {
        self.reachability_at(pairs, None)
    }

    /// Batched reachability over `pinned` when given, else a fresh cut.
    pub(crate) fn reachability_at(&self, pairs: &[(u32, u32)], pinned: Option<Cut>) -> Vec<bool> {
        self.metered_query(|| {
            let _serial = self.query_serial.lock().unwrap();
            if let Some(answers) = self.query.try_reachability(pairs) {
                Metrics::add(&self.metrics.queries_greedy, 1);
                return answers;
            }
            let forest = if let Some(seed) = self.query.partial_seed() {
                self.partial_query_locked(seed, pinned)
            } else {
                self.full_query_locked(pinned)
            };
            pairs.iter().map(|&(a, b)| forest.connected(a, b)).collect()
        })
    }

    /// k-edge-connectivity: `Some(w)` when the min cut w < k, `None`
    /// meaning "at least k".
    pub(crate) fn k_connectivity(&self) -> Option<u64> {
        self.k_connectivity_at(None)
    }

    /// k-edge-connectivity over `pinned` when given, else a fresh cut.
    pub(crate) fn k_connectivity_at(&self, pinned: Option<Cut>) -> Option<u64> {
        self.metered_query(|| {
            let _serial = self.query_serial.lock().unwrap();
            self.settle(pinned);
            Metrics::add(&self.metrics.queries_full, 1);
            let _read = self.merge_gate.write().unwrap();
            self.kconn.query_capped_connectivity()
        })
    }

    /// Re-seed the accelerator from a freshly computed forest — but
    /// only for fresh-cut queries.
    ///
    /// A fresh-cut query force-flushed and waited just before its
    /// sketch read, so the read covers everything published — a
    /// superset of GreedyCC's knowledge (publish order is
    /// buffers-then-log), and the re-seed can only be ahead, never
    /// lossy.  A *pinned* (snapshot) query gives no such guarantee: an
    /// update published after the snapshot's cut can be in GreedyCC
    /// (its log drained) while its batch still sits unflushed in the
    /// shared tree, invisible to the pinned read — re-seeding would
    /// silently discard it forever and let a later tier-0 query certify
    /// a stale partition.  Snapshot queries therefore leave the
    /// accelerator untouched (dirt persists, costing at most a future
    /// re-escalation — a latency trade, never a wrong answer).
    fn maybe_reseed(&self, pinned: Option<Cut>, forest: &SpanningForest) {
        if pinned.is_none() {
            self.query.reseed(self.params.v, forest);
        }
    }

    /// Tier 1 with `query_serial` already held: settle the cut, then
    /// resolve only the dirty components; clean components ride along
    /// contracted.
    fn partial_query_locked(&self, seed: PartialSeed, pinned: Option<Cut>) -> SpanningForest {
        self.settle(pinned);
        let result = {
            let _read = self.merge_gate.write().unwrap();
            boruvka_components_from(
                &self.kconn.stores()[0],
                seed.dsu,
                seed.forest_edges,
                &seed.dirty_vertices,
            )
        };
        Metrics::add(&self.metrics.queries_partial, 1);
        self.maybe_reseed(pinned, &result.forest);
        result.forest
    }

    /// Tier 2 with `query_serial` already held.
    fn full_query_locked(&self, pinned: Option<Cut>) -> SpanningForest {
        self.settle(pinned);
        let result = {
            let _read = self.merge_gate.write().unwrap();
            boruvka_components(&self.kconn.stores()[0])
        };
        Metrics::add(&self.metrics.queries_full, 1);
        self.maybe_reseed(pinned, &result.forest);
        result.forest
    }

    /// Drain one handle's update log into the query engine.
    ///
    /// Serialized with the query path (`query_serial`): `reseed`
    /// replaces GreedyCC wholesale from the freshly computed forest, so
    /// a drain interleaving between a query's `partial_seed`/`try_greedy`
    /// snapshot and its `reseed` would be silently discarded — and a
    /// later tier-0 query would certify a stale partition.  Drains are
    /// amortized (one per full log), so the lock is off the per-update
    /// hot path; a drain may briefly block behind a running query.
    pub(crate) fn apply_log(&self, updates: &[Update]) {
        let _serial = self.query_serial.lock().unwrap();
        self.query.apply_log(updates);
    }

    /// The durable mark behind [`Landscape::flush`]: checkpoint every
    /// copy's segment files, then append + fsync a cut marker to the
    /// WAL.  Taken under the merge gate's **exclusive** side so no
    /// record can slip in between the checkpoint and the marker — a
    /// record there would be behind the marker (never replayed) yet
    /// absent from the checkpoint, i.e. silently lost.  A no-op for
    /// purely resident sessions.
    pub(crate) fn durable_mark(&self, epoch: u64) {
        let Some(wal) = &self.wal else {
            return;
        };
        let _gate = self.merge_gate.write().unwrap();
        let marked = self
            .kconn
            .checkpoint()
            .and_then(|()| wal.cut_sync(epoch));
        match marked {
            Ok(bytes) => Metrics::add(&self.metrics.wal_bytes, bytes),
            // state stays consistent (the WAL tail just keeps growing
            // past the previous durable cut); surface it loudly
            Err(e) => crate::log_warn!("session: durable cut failed: {e}"),
        }
    }

    /// Refresh the store-derived gauges from sketch-store truth, then
    /// snapshot.  The gauges (tier populations, resident bytes) are
    /// point-in-time facts owned by the stores, not monotone counters —
    /// reading them through here keeps every metrics surface consistent
    /// without a background refresher thread.
    pub(crate) fn metrics_snapshot(&self) -> MetricsSnapshot {
        let (exact, sketched) = self.kconn.tier_counts();
        Metrics::set(&self.metrics.vertices_exact, exact);
        Metrics::set(&self.metrics.vertices_sketched, sketched);
        Metrics::set(
            &self.metrics.store_sketch_bytes,
            self.kconn.sketch_bytes() as u64,
        );
        Metrics::set(
            &self.metrics.store_exact_bytes,
            self.kconn.exact_bytes() as u64,
        );
        Metrics::set(
            &self.metrics.resident_sketch_bytes,
            self.kconn.resident_sketch_bytes(),
        );
        Metrics::set(&self.metrics.block_faults, self.kconn.block_faults());
        Metrics::set(
            &self.metrics.spill_bytes_written,
            self.kconn.spill_bytes_written(),
        );
        Metrics::set(&self.metrics.queue_depth, self.barrier.pending() as u64);
        self.metrics.snapshot()
    }

    pub(crate) fn handle_opened(&self) {
        // lint: allow(relaxed-ordering) — diagnostic gauge of live handles; never used to synchronize teardown
        self.active_handles.fetch_add(1, Ordering::Relaxed);
        Metrics::add(&self.metrics.handles_spawned, 1);
    }

    pub(crate) fn handle_closed(&self) {
        // lint: allow(relaxed-ordering) — diagnostic gauge of live handles; never used to synchronize teardown
        self.active_handles.fetch_sub(1, Ordering::Relaxed);
    }

    /// Live ingest handles over this core (the serving layer refuses to
    /// drop a tenant while any connection still holds one).
    pub(crate) fn live_handles(&self) -> usize {
        // lint: allow(relaxed-ordering) — advisory gauge; the drop path re-checks after settling the barrier
        self.active_handles.load(Ordering::Relaxed)
    }

    /// Work items registered but not yet retired on this core's epoch
    /// barrier — the per-tenant queue-depth gauge.
    pub(crate) fn queue_depth(&self) -> usize {
        self.barrier.pending()
    }

    /// Bundle this core's merge-side state for the distributors'
    /// [`crate::coordinator::TenantDirectory`].
    pub(crate) fn tenant_runtime(&self) -> Arc<TenantRuntime> {
        Arc::new(TenantRuntime {
            kconn: self.kconn.clone(),
            barrier: self.barrier.clone(),
            merge_gate: self.merge_gate.clone(),
            metrics: self.metrics.clone(),
            wal: self.wal.clone(),
        })
    }
}

/// Build one tenant's engine room over the fabric's **shared** shard
/// queues and batch arena: its own sketch stores, epoch barrier, merge
/// gate, metrics, query engine, and update buffer, with every enqueued
/// work item tagged `tenant`.  The fabric (not this function) spawns
/// the distributor threads, installing its registry as the
/// [`crate::coordinator::TenantDirectory`]; tenants are purely resident
/// (no WAL — the fabric validates that).  `config` must already be
/// validated.
pub(crate) fn spawn_tenant_core(
    config: CoordinatorConfig,
    update_log_capacity: usize,
    tenant: TenantId,
    queue: Arc<ShardedWorkQueue<WorkItem>>,
    arena: Arc<BatchArena>,
) -> Arc<SessionCore> {
    let params = config.params();
    let spec = config.shard_spec();
    let metrics = Arc::new(Metrics::new());
    let kconn = Arc::new(KConnectivity::with_shards_hybrid(
        params,
        config.graph_seed,
        config.k,
        spec,
        config.hybrid(),
    ));
    let barrier = Arc::new(EpochBarrier::new());
    let buffer = match config.buffer {
        BufferKind::Hypertree => Buffer::Hyper(Arc::new(Hypertree::new(
            HypertreeConfig::for_vertices(config.vertices, config.leaf_capacity()),
            metrics.clone(),
        ))),
        BufferKind::Gutter => Buffer::Gutter(Arc::new(GutterBuffer::new(
            config.vertices,
            config.leaf_capacity(),
            spec,
            metrics.clone(),
        ))),
    };
    let sink = Arc::new(QueueSink {
        queue: queue.clone(),
        spec,
        tenant,
        metrics: metrics.clone(),
        barrier: barrier.clone(),
        arena,
        // remote fabrics meter the batch leg frame-exactly at submit
        // (TBATCH2); in-process fabrics keep the nominal model here
        meter_batch_bytes: !matches!(config.worker, WorkerKind::Remote { .. }),
    });
    Arc::new(SessionCore {
        query: QueryEngine::new(config.vertices, config.use_greedycc, metrics.clone()),
        params,
        metrics,
        kconn,
        buffer,
        sink,
        queue,
        barrier,
        query_serial: Mutex::new(()),
        merge_gate: Arc::new(RwLock::new(())),
        wal: None,
        update_log_capacity,
        active_handles: AtomicUsize::new(0),
        pending_handles: AtomicUsize::new(0),
        config,
    })
}

/// A shared ingestion + query session over one sketched graph.
///
/// Build with [`Landscape::builder`]; spawn any number of
/// [`IngestHandle`]s (one per producer thread) and [`QueryHandle`]s.
/// Dropping the session closes the shard queues and joins the
/// distributor threads; handles outliving the session take the metered
/// drop path instead of wedging.
pub struct Landscape {
    core: Arc<SessionCore>,
    distributors: Vec<JoinHandle<()>>,
}

impl Landscape {
    /// Start building a session (see [`LandscapeBuilder`]).
    pub fn builder() -> LandscapeBuilder {
        LandscapeBuilder::new()
    }

    /// Validate an existing [`CoordinatorConfig`] and build a session
    /// from it (the migration path from the deprecated `Coordinator`).
    pub fn from_config(config: CoordinatorConfig) -> Result<Self, ConfigError> {
        LandscapeBuilder::from_config(config).build()
    }

    /// Recover a session from its `storage_dir`: reopen the
    /// checkpointed segment files, replay the WAL tail past the last
    /// durable cut (idempotently, via per-block LSNs), and resume
    /// ingest where the durable state left off.  The builder must
    /// carry the same shape knobs (`vertices`, `k`, `columns`,
    /// `graph_seed`, `distributor_threads`) the crashed session had.
    pub fn recover(builder: LandscapeBuilder) -> Result<Self, ConfigError> {
        builder.recover()
    }

    /// Construct the engine room.  `config` has been validated;
    /// `storage` is the opened spill tier when `storage_dir` was set.
    fn spawn(
        config: CoordinatorConfig,
        update_log_capacity: usize,
        storage: Option<StorageRuntime>,
    ) -> Result<Self, ConfigError> {
        let params = config.params();
        let spec = config.shard_spec();
        let metrics = Arc::new(Metrics::new());
        let (kconn, wal, recovering) = match storage {
            Some(rt) => {
                let kconn = Arc::new(KConnectivity::with_shards_storage(
                    params,
                    config.graph_seed,
                    config.k,
                    spec,
                    rt.backings,
                ));
                (kconn, Some(rt.wal), rt.recovering)
            }
            None => {
                let kconn = Arc::new(KConnectivity::with_shards_hybrid(
                    params,
                    config.graph_seed,
                    config.k,
                    spec,
                    config.hybrid(),
                ));
                (kconn, None, false)
            }
        };
        if let (true, Some(wal)) = (recovering, wal.as_ref()) {
            // no distributors are running yet: the stores are privately
            // owned here, so replay needs no gate
            let stats = crate::storage::replay_into(kconn.stores(), wal.path())
                .map_err(|e| ConfigError::StorageIo(format!("WAL replay failed: {e}")))?;
            Metrics::add(&metrics.recoveries, 1);
            crate::log_info!(
                "session: recovered from {} — replayed {}/{} WAL tail records \
                 ({} already persisted{})",
                wal.path().display(),
                stats.replayed,
                stats.tail_records,
                stats.skipped,
                if stats.torn_tail { ", torn tail dropped" } else { "" }
            );
        }
        let queue = Arc::new(ShardedWorkQueue::new(spec.count(), config.queue_capacity));
        let barrier = Arc::new(EpochBarrier::new());
        let arena = Arc::new(BatchArena::new(spec.count()));

        let buffer = match config.buffer {
            BufferKind::Hypertree => Buffer::Hyper(Arc::new(Hypertree::new(
                HypertreeConfig::for_vertices(config.vertices, config.leaf_capacity()),
                metrics.clone(),
            ))),
            BufferKind::Gutter => Buffer::Gutter(Arc::new(GutterBuffer::new(
                config.vertices,
                config.leaf_capacity(),
                spec,
                metrics.clone(),
            ))),
        };

        let sink = Arc::new(QueueSink {
            queue: queue.clone(),
            spec,
            tenant: SOLO_TENANT,
            metrics: metrics.clone(),
            barrier: barrier.clone(),
            arena: arena.clone(),
            meter_batch_bytes: !matches!(config.worker, WorkerKind::Remote { .. }),
        });

        let core = Arc::new(SessionCore {
            query: QueryEngine::new(config.vertices, config.use_greedycc, metrics.clone()),
            params,
            metrics,
            kconn,
            buffer,
            sink,
            queue,
            barrier,
            query_serial: Mutex::new(()),
            merge_gate: Arc::new(RwLock::new(())),
            wal,
            update_log_capacity,
            active_handles: AtomicUsize::new(0),
            pending_handles: AtomicUsize::new(0),
            config,
        });

        if recovering && core.query.enabled() {
            // the GreedyCC accelerator did not survive the crash:
            // re-seed it from the recovered sketches, or tier 0 would
            // confidently certify a fresh all-singleton partition
            let result = boruvka_components(&core.kconn.stores()[0]);
            core.query.reseed(core.params.v, &result.forest);
        }

        // one distributor per shard: thread `shard` is the only writer
        // of sketch shard `shard` during ingestion, so its merges use
        // the lock-free exclusive path.  The loop itself (interleaved
        // submit/drain, out-of-order merge, remote failover) lives in
        // `coordinator::distributor::Distributor::run`.  A solo session
        // installs a single-entry tenant directory aliasing its own
        // state, so the multi-tenant resolution is behaviorally free
        // here.
        let tenants: Arc<dyn crate::coordinator::TenantDirectory> =
            Arc::new(SoloDirectory::new(core.tenant_runtime()));
        let mut distributors = Vec::new();
        for shard in 0..core.config.shard_spec().count() {
            // construction data is Send — the backend itself is built
            // inside the thread (PJRT handles are thread-bound)
            let d = distributor::Distributor {
                shard,
                kind: core.config.worker.clone(),
                params: core.params,
                graph_seed: core.config.graph_seed,
                k: core.config.k,
                window: core.config.remote_window.max(1),
                hybrid_threshold: core.config.hybrid_threshold,
                queue: core.queue.clone(),
                tenants: tenants.clone(),
                metrics: core.metrics.clone(),
                arena: arena.clone(),
                tagged_wire: false,
            };
            distributors.push(std::thread::spawn(move || d.run()));
        }

        Ok(Self { core, distributors })
    }

    /// Spawn an independent ingestion handle (one per producer thread).
    ///
    /// Each handle owns its own thread-local hypertree levels and a
    /// bounded update log, so its per-update path takes no cross-thread
    /// lock; shared group nodes and the shard queues absorb the
    /// cross-thread hand-off in bulk.
    pub fn ingest_handle(&self) -> IngestHandle {
        IngestHandle::new(self.core.clone(), self.core.update_log_capacity)
    }

    /// A cloneable, thread-safe read-side handle for queries.
    pub fn query_handle(&self) -> QueryHandle {
        QueryHandle::new(self.core.clone())
    }

    /// Eager-maintenance handle for the deprecated `Coordinator` shim:
    /// query-engine state and metrics stay current after every ingest,
    /// exactly like the old single-owner surface.
    pub(crate) fn shim_handle(&self) -> IngestHandle {
        IngestHandle::new_eager(self.core.clone())
    }

    /// Take a stream cut and wait for it: on return, every update
    /// *published* before this call has reached a sketch (§5.3's query
    /// barrier).  Producers' unflushed handles are not (and cannot be)
    /// drained here — see the module-level consistency contract.
    ///
    /// The wait is bounded by the work in flight at the cut, not by the
    /// stream: producers that keep publishing during the call land in
    /// later epochs and never extend it.  Equivalent to
    /// `wait_for(cut())`.
    ///
    /// When the session spills (`storage_dir` set) this is also the
    /// **durability point**: after the wait retires the cut, the
    /// segment files are checkpointed and a cut marker is fsync'd
    /// through the WAL, so everything published before this call
    /// survives a crash (see `docs/STORAGE.md`).  Queries take cuts
    /// too, but only `flush()` pays for durability.
    pub fn flush(&self) {
        let cut = self.core.cut_shared();
        let epoch = cut.epoch();
        self.core.wait_for_cut(cut);
        self.core.durable_mark(epoch);
    }

    /// Take a stream cut *without waiting*: force-flush the shared
    /// buffer and advance the epoch, returning a [`Cut`] token covering
    /// every update published before this call.  Pair with
    /// [`Landscape::wait_for`] — or hand the waiting to a query via
    /// [`QueryHandle::snapshot`], which takes its own cut.
    pub fn cut(&self) -> Cut {
        self.core.cut_shared()
    }

    /// Block until every update covered by `cut` has reached a sketch.
    /// Returns immediately if the cut has already retired; work
    /// published after the cut never extends the wait.
    pub fn wait_for(&self, cut: Cut) {
        self.core.wait_for_cut(cut);
    }

    /// Number of live ingest handles still holding unpublished updates
    /// — entries in a private update log awaiting the query engine, or
    /// thread-local hypertree entries awaiting the shared tree.  `0`
    /// means a [`Landscape::flush`] barrier covers every ingested
    /// update.
    pub fn pending_producers(&self) -> usize {
        // lint: allow(relaxed-ordering) — advisory gauge; flush() provides the actual barrier, this only reports
        self.core.pending_handles.load(Ordering::Relaxed)
    }

    /// Snapshot of the session metrics (store-derived gauges — tier
    /// populations and resident bytes — are refreshed from the sketch
    /// stores at this call).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics_snapshot()
    }

    /// The sketch shape parameters.
    pub fn params(&self) -> &SketchParams {
        &self.core.params
    }

    /// The validated configuration this session was built from.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.core.config
    }

    /// Main-node sketch memory in bytes.
    pub fn sketch_bytes(&self) -> usize {
        self.core.kconn.bytes()
    }

    /// Access the underlying sketch copies (benches, tests).
    pub fn kconn(&self) -> &KConnectivity {
        &self.core.kconn
    }
}

impl Drop for Landscape {
    fn drop(&mut self) {
        self.core.queue.close();
        for h in self.distributors.drain(..) {
            let _ = h.join();
        }
        // remote connections are owned by the (now-joined) distributor
        // threads, which ended them with the SHUTDOWN → BYE handshake
        // (or tore them down on failover) before exiting.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::dsu::Dsu;
    use crate::stream::dynamify::Dynamify;
    use crate::stream::erdos::ErdosRenyi;
    use crate::stream::update::Update;
    use crate::stream::{edge_list, VecStream};

    fn assert_send<T: Send>() {}
    fn assert_sync<T: Sync>() {}

    #[test]
    fn handles_cross_threads() {
        assert_send::<IngestHandle>();
        assert_send::<QueryHandle>();
        assert_sync::<QueryHandle>();
        assert_send::<Landscape>();
        assert_sync::<Landscape>();
    }

    #[test]
    fn builder_rejects_zero_vertices() {
        assert_eq!(
            Landscape::builder().vertices(0).build().err(),
            Some(ConfigError::ZeroVertices)
        );
        // unset vertices is the same rejection
        assert_eq!(
            Landscape::builder().build().err(),
            Some(ConfigError::ZeroVertices)
        );
    }

    #[test]
    fn builder_rejects_oversized_vertices() {
        assert_eq!(
            Landscape::builder().vertices(1 << 33).build().err(),
            Some(ConfigError::TooManyVertices(1 << 33))
        );
    }

    #[test]
    fn builder_rejects_zero_k() {
        assert_eq!(
            Landscape::builder().vertices(16).k(0).build().err(),
            Some(ConfigError::ZeroK)
        );
    }

    #[test]
    fn builder_rejects_zero_columns() {
        assert_eq!(
            Landscape::builder().vertices(16).columns(0).build().err(),
            Some(ConfigError::ZeroColumns)
        );
    }

    #[test]
    fn builder_rejects_zero_alpha() {
        assert_eq!(
            Landscape::builder().vertices(16).alpha(0).build().err(),
            Some(ConfigError::ZeroAlpha)
        );
    }

    #[test]
    fn builder_rejects_bad_gamma() {
        for gamma in [0.0, -0.5, 1.5, f64::NAN] {
            let err = Landscape::builder()
                .vertices(16)
                .gamma(gamma)
                .build()
                .err()
                .expect("gamma must be rejected");
            assert!(
                matches!(err, ConfigError::GammaOutOfRange(_)),
                "gamma {gamma}: got {err:?}"
            );
        }
        // the boundary γ = 1.0 is valid (flush only exactly-full leaves)
        assert!(Landscape::builder().vertices(16).gamma(1.0).build().is_ok());
    }

    #[test]
    fn builder_rejects_zero_distributors() {
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .distributor_threads(0)
                .build()
                .err(),
            Some(ConfigError::ZeroDistributorThreads)
        );
    }

    #[test]
    fn builder_rejects_zero_queue_capacity() {
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .queue_capacity(0)
                .build()
                .err(),
            Some(ConfigError::ZeroQueueCapacity)
        );
    }

    #[test]
    fn builder_rejects_zero_remote_window() {
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .remote_window(0)
                .build()
                .err(),
            Some(ConfigError::ZeroRemoteWindow)
        );
    }

    #[test]
    fn builder_rejects_zero_log_capacity() {
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .update_log_capacity(0)
                .build()
                .err(),
            Some(ConfigError::ZeroUpdateLogCapacity)
        );
    }

    #[test]
    fn builder_rejects_empty_remote_addrs() {
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .worker(WorkerKind::Remote { addrs: vec![] })
                .build()
                .err(),
            Some(ConfigError::NoRemoteWorkerAddrs)
        );
    }

    #[test]
    fn builder_rejects_floor_without_threshold() {
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .hybrid_demote_floor(2)
                .build()
                .err(),
            Some(ConfigError::HybridFloorWithoutThreshold)
        );
    }

    #[test]
    fn builder_rejects_floor_at_or_above_threshold() {
        for floor in [8u32, 9] {
            assert_eq!(
                Landscape::builder()
                    .vertices(16)
                    .hybrid_threshold(8)
                    .hybrid_demote_floor(floor)
                    .build()
                    .err(),
                Some(ConfigError::HybridFloorTooHigh(floor, 8))
            );
        }
        // a strict floor is fine, and 0 derives threshold/2
        assert!(Landscape::builder()
            .vertices(16)
            .hybrid_threshold(8)
            .hybrid_demote_floor(7)
            .build()
            .is_ok());
        let session = Landscape::builder()
            .vertices(16)
            .hybrid_threshold(8)
            .build()
            .unwrap();
        assert_eq!(
            session.config().hybrid(),
            Some(crate::sketch::store::HybridConfig {
                threshold: 8,
                floor: 4
            })
        );
    }

    #[test]
    fn config_errors_display_the_offending_knob() {
        let msg = ConfigError::GammaOutOfRange(2.0).to_string();
        assert!(msg.contains("gamma"), "{msg}");
        let msg = ConfigError::NoRemoteWorkerAddrs.to_string();
        assert!(msg.contains("address"), "{msg}");
        let msg = ConfigError::ResidentBudgetTooSmall(8, 4096).to_string();
        assert!(msg.contains("resident_budget_bytes"), "{msg}");
    }

    #[test]
    fn builder_rejects_bad_storage_combos() {
        // all three rejections fire in validate(), before any I/O —
        // the named directory must never be created
        let dir = "/nonexistent/landscape-validate-only";
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .hybrid_threshold(4)
                .storage_dir(dir)
                .build()
                .err(),
            Some(ConfigError::SpillWithHybrid)
        );
        assert_eq!(
            Landscape::builder()
                .vertices(16)
                .resident_budget_bytes(1 << 20)
                .build()
                .err(),
            Some(ConfigError::BudgetWithoutStorageDir)
        );
        let err = Landscape::builder()
            .vertices(16)
            .storage_dir(dir)
            .resident_budget_bytes(8)
            .build()
            .err()
            .expect("a budget below one block per stripe must be rejected");
        assert!(
            matches!(err, ConfigError::ResidentBudgetTooSmall(8, _)),
            "{err:?}"
        );
    }

    fn storage_tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "landscape-session-{}-{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn spill_session_matches_referee_refuses_clobber_and_recovers() {
        let v = 128u64;
        let model = ErdosRenyi::new(v, 0.1, 909);
        let want = ref_partition(v, &edge_list(&model));
        let updates: Vec<Update> = Dynamify::new(model, 3).collect();
        let dir = storage_tmp("spill-roundtrip");
        let budget = 64 * 1024u64;
        let builder = || {
            Landscape::builder()
                .vertices(v)
                .alpha(1)
                .distributor_threads(2)
                .storage_dir(&dir)
                .resident_budget_bytes(budget)
        };

        let session = builder().build().unwrap();
        let mut h = session.ingest_handle();
        for u in &updates {
            h.ingest(*u);
        }
        h.flush();
        session.flush(); // the durable mark
        let forest = session.query_handle().connected_components();
        assert!(same_partition(&forest.component, &want));
        let m = session.metrics();
        assert_eq!(m.batches_dropped, 0);
        assert!(m.wal_bytes > 0, "merges must have been logged");
        assert!(
            m.resident_sketch_bytes <= budget,
            "gauge {} exceeds the budget {budget}",
            m.resident_sketch_bytes
        );
        drop(session);

        // a second fresh build on the same directory must refuse to
        // clobber the live WAL…
        let err = builder().build().err().expect("existing WAL refused");
        assert!(matches!(err, ConfigError::StorageIo(_)), "{err:?}");

        // …while recovery reopens it and answers the same partition
        let recovered = builder().recover().unwrap();
        let rf = recovered.query_handle().connected_components();
        assert!(same_partition(&rf.component, &want));
        assert_eq!(recovered.metrics().recoveries, 1);
        drop(recovered);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn small_session(v: u64) -> Landscape {
        Landscape::builder()
            .vertices(v)
            .alpha(1)
            .distributor_threads(2)
            .update_log_capacity(64)
            .build()
            .unwrap()
    }

    fn ref_partition(v: u64, edges: &[(u32, u32)]) -> Vec<u32> {
        let mut d = Dsu::new(v as usize);
        for &(a, b) in edges {
            d.union(a, b);
        }
        d.component_map()
    }

    fn same_partition(a: &[u32], b: &[u32]) -> bool {
        crate::baseline::Referee::same_partition(a, b)
    }

    /// Split `stream` round-robin over `producers` threads, each with
    /// its own handle, and return the final queried partition.
    fn multi_producer_partition(
        session: &Landscape,
        updates: &[Update],
        producers: usize,
    ) -> SpanningForest {
        std::thread::scope(|scope| {
            for p in 0..producers {
                let mut handle = session.ingest_handle();
                let chunk: Vec<Update> = updates
                    .iter()
                    .copied()
                    .skip(p)
                    .step_by(producers)
                    .collect();
                scope.spawn(move || {
                    for u in chunk {
                        handle.ingest(u);
                    }
                    // handle drop publishes the tail
                });
            }
        });
        assert_eq!(session.pending_producers(), 0);
        session.query_handle().connected_components()
    }

    #[test]
    fn four_producers_match_single_producer_and_referee() {
        // the acceptance scenario: the same stream through 1 and 4
        // handles must produce identical partitions, equal to the DSU
        // referee, with zero dropped batches
        let v = 256u64;
        let model = ErdosRenyi::new(v, 0.1, 4242);
        let want = ref_partition(v, &edge_list(&model));
        let updates: Vec<Update> = Dynamify::new(model, 3).collect();

        let single = small_session(v);
        let sf = multi_producer_partition(&single, &updates, 1);
        assert!(same_partition(&sf.component, &want));
        assert_eq!(single.metrics().batches_dropped, 0);

        let quad = small_session(v);
        let qf = multi_producer_partition(&quad, &updates, 4);
        assert!(same_partition(&qf.component, &sf.component));
        assert!(same_partition(&qf.component, &want));
        let m = quad.metrics();
        assert_eq!(m.batches_dropped, 0);
        assert_eq!(m.handles_spawned, 4);
        assert_eq!(m.updates_ingested, updates.len() as u64);
    }

    #[test]
    fn query_handle_needs_no_mut_and_is_cloneable() {
        let session = small_session(64);
        let mut h = session.ingest_handle();
        h.ingest_all(VecStream::new(
            64,
            vec![
                Update::insert(0, 1),
                Update::insert(1, 2),
                Update::insert(4, 5),
            ],
        ));
        h.flush();
        let q1 = session.query_handle();
        let q2 = q1.clone();
        // queries from two handles, no &mut anywhere
        assert_eq!(q1.reachability(&[(0, 2), (0, 4)]), vec![true, false]);
        assert!(q2.connected_components().connected(4, 5));
        assert_eq!(session.metrics().batches_dropped, 0);
    }

    #[test]
    fn queries_run_while_a_producer_is_still_ingesting() {
        // a query between two ingest phases of a live (unflushed-later)
        // handle must not deadlock and must see the published prefix
        let session = small_session(64);
        let mut h = session.ingest_handle();
        h.ingest(Update::insert(0, 1));
        h.flush();
        let q = session.query_handle();
        assert!(q.connected_components().connected(0, 1));
        // keep ingesting on the same handle afterwards
        h.ingest(Update::insert(1, 2));
        h.flush();
        assert!(q.connected_components().connected(0, 2));
    }

    #[test]
    fn metrics_fold_per_handle_counts_at_drain() {
        let session = Landscape::builder()
            .vertices(64)
            .update_log_capacity(4)
            .build()
            .unwrap();
        let mut h = session.ingest_handle();
        assert_eq!(session.pending_producers(), 0);
        for i in 0..10u32 {
            h.ingest(Update::insert(i, i + 1));
        }
        assert_eq!(session.pending_producers(), 1, "handle holds a tail");
        // 10 updates with a capacity-4 log: 2 automatic drains so far
        let m = session.metrics();
        assert_eq!(m.updates_ingested, 8, "only drained updates are folded");
        assert_eq!(m.log_drains, 2);
        h.flush();
        assert_eq!(session.pending_producers(), 0, "flush publishes the tail");
        let m = session.metrics();
        assert_eq!(m.updates_ingested, 10);
        assert_eq!(m.log_drains, 3);
        assert_eq!(m.stream_bytes, 90);
    }

    #[test]
    fn hybrid_session_matches_referee_and_meters_tiers() {
        // a sparse-ish stream through the full pipeline with the hybrid
        // tier on: answers must match the DSU referee, the gauges must
        // reflect a mixed-tier store, and nothing may drop
        let v = 256u64;
        let model = ErdosRenyi::new(v, 0.04, 71);
        let want = ref_partition(v, &edge_list(&model));
        let updates: Vec<Update> = Dynamify::new(model, 3).collect();
        let session = Landscape::builder()
            .vertices(v)
            .alpha(1)
            .distributor_threads(2)
            .hybrid_threshold(6)
            .build()
            .unwrap();
        let forest = multi_producer_partition(&session, &updates, 2);
        assert!(same_partition(&forest.component, &want));
        let m = session.metrics();
        assert_eq!(m.batches_dropped, 0);
        assert_eq!(
            m.vertices_exact + m.vertices_sketched,
            v,
            "every vertex sits in exactly one tier"
        );
        assert!(
            m.vertices_exact > 0,
            "a p=0.04 stream must leave cold vertices exact"
        );
        assert!(m.promotions >= m.vertices_sketched, "promoted vertices were metered");
        assert!(m.store_sketch_bytes > 0 || m.vertices_sketched == 0);
    }

    #[test]
    fn k_connectivity_via_query_handle() {
        // two K6s joined by 2 edges: min cut 2 < k=3
        let v = 12u64;
        let mut edges = Vec::new();
        for a in 0..6u32 {
            for b in (a + 1)..6 {
                edges.push(Update::insert(a, b));
                edges.push(Update::insert(a + 6, b + 6));
            }
        }
        edges.push(Update::insert(0, 6));
        edges.push(Update::insert(1, 7));
        let session = Landscape::builder()
            .vertices(v)
            .alpha(1)
            .distributor_threads(2)
            .k(3)
            .build()
            .unwrap();
        let mut h = session.ingest_handle();
        h.ingest_all(VecStream::new(v, edges));
        h.flush();
        assert_eq!(session.query_handle().k_connectivity(), Some(2));
    }
}
