//! The session handles: [`IngestHandle`] (write side, one per producer
//! thread), [`QueryHandle`] (read side, cloneable and `Sync`), and
//! [`Snapshot`] (a pinned stream cut the read side can query while
//! producers keep streaming).

use std::sync::atomic::Ordering as AtomicOrdering;
use std::sync::Arc;

use crate::connectivity::SpanningForest;
use crate::coordinator::query::QueryTier;
use crate::coordinator::work_queue::Cut;
use crate::hypertree::LocalIngest;
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::stream::update::{Update, UPDATE_WIRE_BYTES};
use crate::stream::GraphStream;

use super::{Buffer, IngestReport, SessionCore};

/// An independent stream-ingestion handle (`Send`, one per producer
/// thread).
///
/// The per-update path is lock-free from this thread's point of view:
/// updates go into the handle's own thread-local hypertree levels (or
/// the striped gutter, in ablation mode) and into a bounded private
/// update log.  Cross-thread work happens only in amortized bulk — the
/// hypertree's group-node cascades, the shard queues, and one
/// mutex-guarded GreedyCC drain per full log.
///
/// Call [`IngestHandle::flush`] (or drop the handle) to *publish* its
/// buffered tail; queries only reflect published updates.
pub struct IngestHandle {
    core: Arc<SessionCore>,
    /// Thread-local hypertree levels (`None` in gutter mode, which
    /// writes straight to the shared striped buffer).
    local: Option<LocalIngest>,
    /// Bounded private update log; drained into the query engine under
    /// one amortized lock when full, at a flush, or on drop.  Kept
    /// empty when the GreedyCC accelerator is disabled — the drain
    /// would be a no-op, so the push would be pure hot-path overhead.
    log: Vec<Update>,
    log_capacity: usize,
    /// Is the query engine consuming the log at all?
    log_enabled: bool,
    /// Shim mode (deprecated `Coordinator`): apply query maintenance
    /// per update instead of logging, and fold metrics per update, so
    /// the legacy surface's "current after every ingest" contract
    /// holds.  Sound only because the shim is single-owner — no
    /// concurrent query can re-seed between GreedyCC learning an
    /// update and its sketch publication.
    eager: bool,
    /// Updates ingested through this handle over its lifetime.
    ingested: u64,
    /// Updates not yet folded into the shared metrics counters.
    unmetered: u64,
    /// Is this handle currently counted in the session's
    /// `pending_handles` gauge (i.e. `buffered() > 0`)?
    gauge_pending: bool,
}

impl IngestHandle {
    pub(crate) fn new(core: Arc<SessionCore>, log_capacity: usize) -> Self {
        Self::build(core, log_capacity, false)
    }

    /// Shim-mode constructor (see the `eager` field).
    pub(crate) fn new_eager(core: Arc<SessionCore>) -> Self {
        Self::build(core, 1, true)
    }

    fn build(core: Arc<SessionCore>, log_capacity: usize, eager: bool) -> Self {
        core.handle_opened();
        let local = match &core.buffer {
            Buffer::Hyper(t) => Some(t.local()),
            Buffer::Gutter(_) => None,
        };
        let log_enabled = core.query.enabled() && !eager;
        Self {
            core,
            local,
            log: Vec::with_capacity(if log_enabled { log_capacity } else { 0 }),
            log_capacity,
            log_enabled,
            eager,
            ingested: 0,
            unmetered: 0,
            gauge_pending: false,
        }
    }

    /// Ingest one stream update.
    #[inline]
    pub fn ingest(&mut self, update: Update) {
        self.ingested += 1;
        self.unmetered += 1;
        if self.log_enabled {
            self.log.push(update);
        }
        // the sketch path is linear: inserts and deletes are the same
        // XOR, so both endpoints enter the buffer regardless of kind
        match &self.core.buffer {
            Buffer::Hyper(_) => {
                // lint: allow(hot-path-unwrap) — constructor invariant: `local` is Some iff the buffer is Buffer::Hyper
                let local = self.local.as_mut().expect("hypertree local handle");
                local.insert(update.u, update.v, &*self.core.sink);
                local.insert(update.v, update.u, &*self.core.sink);
            }
            Buffer::Gutter(g) => {
                g.insert(update.u, update.v, &*self.core.sink);
                g.insert(update.v, update.u, &*self.core.sink);
            }
        }
        if self.eager {
            // legacy-shim semantics: GreedyCC and the metrics are
            // current after every ingest (two short uncontended locks
            // the session log amortizes away for real producers)
            self.core.apply_log(std::slice::from_ref(&update));
            self.fold_meter();
        } else if self.log_enabled {
            if self.log.len() >= self.log_capacity {
                self.publish();
            }
        } else if self.unmetered >= self.log_capacity as u64 {
            // no log to drain (accelerator off): still fold the ingest
            // counters at the same cadence so metrics don't stall
            // until the next flush
            self.fold_meter();
        }
        self.sync_pending_gauge();
    }

    /// Publish in the only sound order: thread-local hypertree levels
    /// into the shared tree *first*, then the update log into the query
    /// engine.  The reverse would let GreedyCC learn an update whose
    /// sketch entries can still fall outside a concurrent query's cut
    /// — that query's `reseed` would then rebuild GreedyCC from
    /// sketches lacking the update and permanently discard the drained
    /// knowledge, leaving later tier-0 answers stale even after this
    /// handle flushes.  Publishing the buffers first keeps the
    /// invariant "GreedyCC knowledge ⊆ shared-tree content", under
    /// which a re-seed can only ever be *ahead* of the accelerator,
    /// and post-re-seed drains re-apply safely (inserts re-union,
    /// unclassifiable deletes conservatively dirty).
    fn publish(&mut self) {
        if let Some(local) = self.local.as_mut() {
            local.flush(&*self.core.sink);
        }
        self.drain_log();
    }

    /// Ingest an entire stream, returning the throughput report.
    pub fn ingest_all<S: GraphStream>(&mut self, stream: S) -> IngestReport {
        let sw = crate::util::timer::Stopwatch::new();
        let mut n = 0u64;
        for update in stream {
            self.ingest(update);
            n += 1;
        }
        IngestReport {
            updates: n,
            seconds: sw.elapsed_secs(),
        }
    }

    /// Publish everything this handle still buffers: drain the update
    /// log into the query engine and push the thread-local hypertree
    /// levels into the shared group nodes.  After `flush`, a session
    /// query covers every update this handle has ingested, and the
    /// shared metrics include this handle's counters.
    pub fn flush(&mut self) {
        self.publish();
        self.sync_pending_gauge();
    }

    /// Updates ingested through this handle over its lifetime.
    pub fn ingested(&self) -> u64 {
        self.ingested
    }

    /// Entries currently buffered (unpublished) in this handle:
    /// update-log entries awaiting the query engine plus thread-local
    /// hypertree endpoint entries (two per update) awaiting the shared
    /// tree.  `0` means this handle is fully published.
    pub fn buffered(&self) -> usize {
        self.log.len() + self.local.as_ref().map_or(0, |l| l.buffered())
    }

    /// Drain the bounded log: GreedyCC maintenance under one amortized
    /// lock (serialized with the query path — see
    /// `SessionCore::apply_log`), and the per-handle ingest counters
    /// folded into the shared metrics.
    pub(crate) fn drain_log(&mut self) {
        if !self.log.is_empty() {
            self.core.apply_log(&self.log);
            Metrics::add(&self.core.metrics.log_drains, 1);
            self.log.clear();
        }
        self.fold_meter();
    }

    /// Fold this handle's not-yet-published ingest counters into the
    /// shared metrics.
    fn fold_meter(&mut self) {
        if self.unmetered > 0 {
            Metrics::add(&self.core.metrics.updates_ingested, self.unmetered);
            Metrics::add(
                &self.core.metrics.stream_bytes,
                self.unmetered * UPDATE_WIRE_BYTES,
            );
            self.unmetered = 0;
        }
    }

    /// Keep the session's `pending_handles` gauge in step with whether
    /// this handle holds unpublished updates.  One comparison per call;
    /// an atomic only on the empty↔nonempty transition.
    fn sync_pending_gauge(&mut self) {
        let pending = self.buffered() > 0;
        if pending != self.gauge_pending {
            if pending {
                // lint: allow(relaxed-ordering) — advisory pending-producers gauge; flush() is the real barrier
                self.core.pending_handles.fetch_add(1, AtomicOrdering::Relaxed);
            } else {
                // lint: allow(relaxed-ordering) — advisory pending-producers gauge; flush() is the real barrier
                self.core.pending_handles.fetch_sub(1, AtomicOrdering::Relaxed);
            }
            self.gauge_pending = pending;
        }
    }
}

impl Drop for IngestHandle {
    fn drop(&mut self) {
        self.flush();
        self.core.handle_closed();
    }
}

/// The read-side query surface: cloneable, `Sync`, and requiring no
/// `&mut` access to ingestion.
///
/// Queries are serialized against each other inside the session (the
/// tiered plan → cut → Borůvka → re-seed sequence is a
/// read-modify-write of the accelerator), and each escalating query
/// takes **its own stream cut** and waits only for work registered
/// before it — never for pipeline idleness, so queries stay prompt
/// under sustained concurrent ingestion.  Results cover every
/// *published* update — see the module-level consistency contract.
/// [`QueryHandle::snapshot`] pins a cut once and lets several queries
/// share it.
#[derive(Clone)]
pub struct QueryHandle {
    core: Arc<SessionCore>,
}

impl QueryHandle {
    pub(crate) fn new(core: Arc<SessionCore>) -> Self {
        Self { core }
    }

    /// The tier that would answer [`Self::connected_components`] now.
    pub fn query_plan(&self) -> QueryTier {
        self.core.query_plan()
    }

    /// Global connectivity query, answered by the cheapest valid tier:
    ///
    /// * tier 0 — GreedyCC (all components clean): O(V), **no flush**;
    /// * tier 1 — some components dirty: flush + Borůvka warm-started
    ///   from the surviving forest, aggregating only dirty-region
    ///   vertices;
    /// * tier 2 — accelerator disabled: full flush + Borůvka.
    pub fn connected_components(&self) -> SpanningForest {
        self.core.connected_components()
    }

    /// Force the full (flush + Borůvka) query path — tier 2.
    pub fn full_connectivity_query(&self) -> SpanningForest {
        self.core.full_connectivity_query()
    }

    /// Batched reachability (§5.3).  Tier 0 answers when no queried
    /// pair touches a dirty component; otherwise the query escalates
    /// exactly like [`Self::connected_components`].
    pub fn reachability(&self, pairs: &[(u32, u32)]) -> Vec<bool> {
        self.core.reachability(pairs)
    }

    /// k-edge-connectivity query: `Some(w)` when the min cut w < k,
    /// `None` meaning "at least k".
    pub fn k_connectivity(&self) -> Option<u64> {
        self.core.k_connectivity()
    }

    /// Pin a stream cut *now* and return a [`Snapshot`] whose queries
    /// answer over it.
    ///
    /// Taking the snapshot is cheap (a buffer force-flush plus an epoch
    /// advance — no waiting); the first query on it waits for the
    /// pinned cut to retire, bounded by the work that was in flight at
    /// cut time, and later queries find it already retired.  Producers
    /// keep streaming throughout — their post-cut updates land in later
    /// epochs and never delay this snapshot.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            cut: self.core.cut_shared(),
            core: self.core.clone(),
        }
    }

    /// Snapshot of the session metrics (store-derived gauges refreshed
    /// from the sketch stores at this call).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics_snapshot()
    }
}

/// A pinned stream cut: a cheap consistency token whose queries answer
/// over **all updates published before the cut** while producers keep
/// streaming.
///
/// The guarantee is one-sided, exactly like the session's (see the
/// module-level consistency contract): every update published before
/// [`QueryHandle::snapshot`] was called is covered; updates published
/// after it *may* also be visible (sketch merges keep landing behind
/// the cut and are never rolled back).  What the snapshot buys is
/// liveness — the wait is bounded by the in-flight work at cut time,
/// never by how long the producers keep going.
///
/// Clone freely; clones share the same cut.  Queries on a snapshot are
/// serialized with the session's other queries, and never re-seed the
/// tier-0 accelerator (a pinned read may be older than what the
/// accelerator already knows, and must not fold back into live query
/// state) — so snapshots cannot make later queries staler, only the
/// stream can.
#[derive(Clone)]
pub struct Snapshot {
    core: Arc<SessionCore>,
    cut: Cut,
}

impl Snapshot {
    /// The pinned cut token (e.g. to `Landscape::wait_for` it
    /// explicitly, or to correlate with `metrics().epoch_current`).
    pub fn cut(&self) -> Cut {
        self.cut
    }

    /// The epoch this snapshot pins (every update published before the
    /// cut was registered in an epoch ≤ this).
    pub fn epoch(&self) -> u64 {
        self.cut.epoch()
    }

    /// Global connectivity over the pinned cut, answered by the
    /// cheapest valid tier (tier 0 needs no waiting at all; tiers 1–2
    /// wait for the pinned cut instead of taking a new one).
    pub fn connected_components(&self) -> SpanningForest {
        self.core.connected_components_at(Some(self.cut))
    }

    /// Forced tier-2 (full Borůvka) query over the pinned cut.
    pub fn full_connectivity_query(&self) -> SpanningForest {
        self.core.full_connectivity_query_at(Some(self.cut))
    }

    /// Batched reachability over the pinned cut.
    pub fn reachability(&self, pairs: &[(u32, u32)]) -> Vec<bool> {
        self.core.reachability_at(pairs, Some(self.cut))
    }

    /// k-edge-connectivity over the pinned cut: `Some(w)` when the min
    /// cut w < k, `None` meaning "at least k".
    pub fn k_connectivity(&self) -> Option<u64> {
        self.core.k_connectivity_at(Some(self.cut))
    }
}
