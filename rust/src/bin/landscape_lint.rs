//! `landscape-lint` — the project's own invariant lint pass.
//!
//! The pipeline's correctness rests on contracts no general-purpose tool
//! checks: relaxed-atomic merges are sound *only* in the single-writer
//! sketch kernels, the leveled `util::log` facility must not be bypassed
//! with bare `eprintln!`, and the hot-path modules must not hide panics
//! (`unwrap`/`expect`) or stalls (`thread::sleep`) without an explicit,
//! reviewed justification.  This binary walks `rust/src` and enforces
//! those rules mechanically (see `docs/INVARIANTS.md` for the catalog
//! and the companion dynamic detectors).
//!
//! Rules:
//!
//! 1. **relaxed-ordering** — `Ordering::Relaxed` is allowed only in
//!    `sketch/store.rs` (the single-writer XOR kernels).  Everywhere
//!    else each use needs `// lint: allow(relaxed-ordering) — <reason>`
//!    on the same or the preceding line.
//! 2. **eprintln** — `eprintln!` is banned outside `util/log.rs` (the
//!    facility that implements the `log_*!` macros); justify exceptions
//!    with `// lint: allow(eprintln) — <reason>`.
//! 3. **hot-path-unwrap / thread-sleep** — `.unwrap()`, `.expect(` and
//!    `thread::sleep` are banned in the hot-path module trees
//!    (`sketch/`, `coordinator/`, `worker/`, `session/`, `gutter/`,
//!    `hypertree/`, `storage/`) outside `#[cfg(test)]` blocks.  The lock-poisoning
//!    idiom (`.lock()`, `.read()`, `.write()`, `.wait(..)`,
//!    `.wait_timeout(..)` immediately followed by `.unwrap()`) is
//!    exempt: propagating a poisoned lock IS the invariant — a panic
//!    that happened while the lock was held must not be swallowed.
//!    Everything else needs `// lint: allow(hot-path-unwrap) — <reason>`
//!    (or `thread-sleep`).
//! 4. **missing-docs-attr** — the modules CI documents as
//!    `#![deny(missing_docs)]` must actually carry the attribute.
//!
//! An allow directive must carry a reason: `// lint: allow(<tag>)`
//! followed by at least a few words.  Directives are recognized in line
//! comments only (`//`), not block comments.
//!
//! Scope notes: `#[cfg(test)]` blocks are exempt from rules 1–3 (the
//! dynamic detectors, Miri and TSan cover test-only races), and string
//! literals / comments never match a rule pattern (the scanner strips
//! them first).  The tracker assumes the repo convention of a single
//! trailing `#[cfg(test)] mod tests { .. }` per file — an armed
//! `#[cfg(test)]` attribute captures everything from the next opening
//! brace to its matching close.
//!
//! Exit status: 0 when the tree is clean, 1 when any violation is
//! found.  Stdlib-only by design (the `tools/bench_compare` precedent):
//! it must build in the offline workspace and run as a required CI job.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Hot-path module trees for rule 3 (relative to the source root, with
/// trailing slash so `worker/` does not match `workers_util/`).
const HOT_PATH_DIRS: &[&str] = &[
    "sketch/",
    "coordinator/",
    "worker/",
    "session/",
    "gutter/",
    "hypertree/",
    "storage/",
    "serve/",
];

/// Files where `Ordering::Relaxed` is allowed without justification:
/// the single-writer-per-shard XOR merge kernels.
const RELAXED_WHITELIST: &[&str] = &["sketch/store.rs"];

/// Files where `eprintln!` is allowed without justification: the
/// logging facility itself.
const EPRINTLN_WHITELIST: &[&str] = &["util/log.rs"];

/// Files CI relies on carrying `#![deny(missing_docs)]` (the cargo-doc
/// `-D warnings` gate only fires for modules that opt in).  Inner
/// attributes cover child modules, so `sketch/mod.rs` covers the whole
/// `sketch/` subtree and `session/mod.rs` covers `session/handle.rs`.
const MISSING_DOCS_REQUIRED: &[&str] = &[
    "sketch/mod.rs",
    "coordinator/work_queue.rs",
    "session/mod.rs",
    "metrics.rs",
    "storage/mod.rs",
    "serve/mod.rs",
];

/// Receiver methods whose `Result` is the lock-poisoning propagation
/// idiom (see module docs): `.unwrap()`/`.expect(` directly on these is
/// not a rule-3 violation.
const LOCK_FAMILY: &[&str] = &["lock", "read", "write", "wait", "wait_timeout"];

/// Which rule a violation belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Rule {
    RelaxedOrdering,
    Eprintln,
    HotPathUnwrap,
    ThreadSleep,
    MissingDocsAttr,
}

impl Rule {
    /// The rule's display name, also its `lint: allow(..)` tag.
    fn tag(self) -> &'static str {
        match self {
            Rule::RelaxedOrdering => "relaxed-ordering",
            Rule::Eprintln => "eprintln",
            Rule::HotPathUnwrap => "hot-path-unwrap",
            Rule::ThreadSleep => "thread-sleep",
            Rule::MissingDocsAttr => "missing-docs-attr",
        }
    }
}

/// One rule violation at a source location.
#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: Rule,
    message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line,
            self.rule.tag(),
            self.message
        )
    }
}

/// One source line after the scanner pass: executable code with string
/// and comment contents removed, plus any line-comment text.
#[derive(Debug, Default)]
struct ScannedLine {
    code: String,
    comment: String,
}

/// Split `src` into per-line (code, comment) pairs.  String literal
/// contents (plain, byte, raw), char literals, and comment bodies are
/// removed from `code`, so rule patterns never match inside them; line
/// comments are preserved verbatim in `comment` for `lint: allow`
/// detection.  Strings and block comments may span lines.
fn scan_source(src: &str) -> Vec<ScannedLine> {
    #[derive(PartialEq)]
    enum State {
        Normal,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
    }
    let b = src.as_bytes();
    let mut lines = Vec::new();
    let mut cur = ScannedLine::default();
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            if state == State::LineComment {
                state = State::Normal;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                    state = State::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    state = State::BlockComment(1);
                    i += 2;
                } else if c == b'"' {
                    state = State::Str;
                    cur.code.push('"');
                    i += 1;
                } else if c == b'b' && i + 1 < b.len() && b[i + 1] == b'"' {
                    state = State::Str;
                    cur.code.push_str("b\"");
                    i += 2;
                } else if (c == b'r' && i + 1 < b.len())
                    || (c == b'b' && i + 2 < b.len() && b[i + 1] == b'r')
                {
                    // possible raw (byte) string: r"..", r#".."#, br".."
                    let start = if c == b'b' { i + 2 } else { i + 1 };
                    let mut j = start;
                    while j < b.len() && b[j] == b'#' {
                        j += 1;
                    }
                    if j < b.len() && b[j] == b'"' && (c == b'b' || start == i + 1) {
                        state = State::RawStr((j - start) as u32);
                        cur.code.push('"');
                        i = j + 1;
                    } else {
                        cur.code.push(c as char);
                        i += 1;
                    }
                } else if c == b'\'' {
                    // char literal or lifetime tick
                    if i + 1 < b.len() && b[i + 1] == b'\\' {
                        // escaped char literal: skip to the closing quote
                        let mut j = i + 2;
                        if j < b.len() {
                            j += 1; // the escaped character itself
                        }
                        while j < b.len() && b[j] != b'\'' {
                            j += 1;
                        }
                        cur.code.push_str("' '");
                        i = j + 1;
                    } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                        cur.code.push_str("' '");
                        i += 3;
                    } else {
                        // a lifetime ('a, 'static): keep the tick
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    // non-ASCII bytes only occur inside strings/comments
                    // in this codebase; pass ASCII through for matching
                    cur.code.push(c as char);
                    i += 1;
                }
            }
            State::LineComment => {
                // preserve comment bytes (allow directives are ASCII;
                // reasons may contain UTF-8 dashes — keep bytes lossily)
                if c.is_ascii() {
                    cur.comment.push(c as char);
                }
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    state = if depth == 1 {
                        State::Normal
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            State::Str => {
                if c == b'\\' {
                    // skip the escaped character (incl. \" and \\) — but
                    // leave an escaped newline (string continuation) for
                    // the top-level line handling so line numbers stay true
                    i += 1;
                    if i < b.len() && b[i] != b'\n' {
                        i += 1;
                    }
                } else if c == b'"' {
                    state = State::Normal;
                    cur.code.push('"');
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == b'"' {
                    let n = hashes as usize;
                    if b.len() >= i + 1 + n && b[i + 1..i + 1 + n].iter().all(|&h| h == b'#') {
                        state = State::Normal;
                        cur.code.push('"');
                        i += 1 + n;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

/// Does `comment` (or a neighboring line's comment, checked by the
/// caller) carry `lint: allow(<tag>)` with a non-trivial reason?
fn has_allow(comment: &str, tag: &str) -> bool {
    let needle = format!("lint: allow({tag})");
    match comment.find(&needle) {
        None => false,
        Some(pos) => {
            let rest = &comment[pos + needle.len()..];
            // the justification must actually say something
            rest.chars().filter(|c| c.is_alphanumeric()).count() >= 3
        }
    }
}

/// Is the text immediately before an `.unwrap()` / `.expect(` a call to
/// one of the lock-poisoning-family methods?  `prefix` is the squashed
/// (whitespace-free) statement text up to the match.
fn lock_family_receiver(prefix: &str) -> bool {
    let b = prefix.as_bytes();
    if b.last() != Some(&b')') {
        return false;
    }
    // walk back over the balanced argument list to the opening paren
    let mut depth = 0i32;
    let mut i = b.len();
    while i > 0 {
        i -= 1;
        match b[i] {
            b')' => depth += 1,
            b'(' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
    }
    if depth != 0 || i == 0 {
        return false;
    }
    let head = &prefix[..i];
    LOCK_FAMILY
        .iter()
        .any(|m| head.ends_with(&format!(".{m}")))
}

/// Remove all whitespace (for cross-line statement matching).
fn squash(s: &str) -> String {
    s.chars().filter(|c| !c.is_whitespace()).collect()
}

/// Lint one file's source.  `rel` is the path relative to the source
/// root, with forward slashes.
fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    let scanned = scan_source(src);
    let mut viols = Vec::new();

    let in_hot_path = HOT_PATH_DIRS.iter().any(|d| rel.starts_with(d));
    let relaxed_ok = RELAXED_WHITELIST.contains(&rel);
    let eprintln_ok = EPRINTLN_WHITELIST.contains(&rel);

    let mut in_test = false;
    let mut test_armed = false;
    let mut depth = 0i64;

    for (idx, line) in scanned.iter().enumerate() {
        let lineno = idx + 1;
        let code = line.code.as_str();
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;

        if in_test {
            depth += opens - closes;
            if depth <= 0 {
                in_test = false;
            }
            continue;
        }
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            test_armed = true;
        }
        if test_armed && opens > 0 {
            depth = opens - closes;
            test_armed = false;
            if depth > 0 {
                in_test = true;
            }
            continue; // the opening line itself belongs to the test block
        }

        let allowed = |tag: &str| -> bool {
            has_allow(&line.comment, tag)
                || (idx > 0 && has_allow(&scanned[idx - 1].comment, tag))
        };

        if code.contains("Ordering::Relaxed")
            && !relaxed_ok
            && !allowed(Rule::RelaxedOrdering.tag())
        {
            viols.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: Rule::RelaxedOrdering,
                message: "relaxed atomic ordering outside the sketch/store.rs \
                          single-writer kernels; justify with \
                          `// lint: allow(relaxed-ordering) — <reason>`"
                    .to_string(),
            });
        }

        if code.contains("eprintln!") && !eprintln_ok && !allowed(Rule::Eprintln.tag()) {
            viols.push(Violation {
                file: rel.to_string(),
                line: lineno,
                rule: Rule::Eprintln,
                message: "bare eprintln! bypasses the leveled util::log facility; \
                          use log_error!/log_warn!/log_info!/log_debug! or justify \
                          with `// lint: allow(eprintln) — <reason>`"
                    .to_string(),
            });
        }

        if in_hot_path {
            if code.contains("thread::sleep") && !allowed(Rule::ThreadSleep.tag()) {
                viols.push(Violation {
                    file: rel.to_string(),
                    line: lineno,
                    rule: Rule::ThreadSleep,
                    message: "thread::sleep on a hot-path module; use the queue/barrier \
                              condvars, or justify with \
                              `// lint: allow(thread-sleep) — <reason>`"
                        .to_string(),
                });
            }
            // cross-line statement context for chained-call idioms
            let squashed = squash(code);
            let mut prev_ctx = String::new();
            for prev in &scanned[idx.saturating_sub(4)..idx] {
                prev_ctx.push_str(&squash(&prev.code));
            }
            for pat in [".unwrap()", ".expect("] {
                let mut flagged = false;
                let mut search = 0usize;
                while let Some(off) = squashed[search..].find(pat) {
                    let pos = search + off;
                    let mut prefix = prev_ctx.clone();
                    prefix.push_str(&squashed[..pos]);
                    if !lock_family_receiver(&prefix)
                        && !allowed(Rule::HotPathUnwrap.tag())
                        && !flagged
                    {
                        viols.push(Violation {
                            file: rel.to_string(),
                            line: lineno,
                            rule: Rule::HotPathUnwrap,
                            message: format!(
                                "`{pat}` on a hot-path module (panic-on-Err is only \
                                 acceptable for lock poisoning); handle the error, or \
                                 justify with `// lint: allow(hot-path-unwrap) — <reason>`"
                            ),
                        });
                        flagged = true;
                    }
                    search = pos + pat.len();
                }
            }
        }
    }
    viols
}

/// Recursively collect every `.rs` file under `dir`, sorted.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` and check the required
/// `#![deny(missing_docs)]` attributes.  Violations come back sorted by
/// (file, line).
fn lint_root(root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    let mut viols = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path)?;
        viols.extend(lint_file(&rel, &src));
        if MISSING_DOCS_REQUIRED.contains(&rel.as_str())
            && !scan_source(&src)
                .iter()
                .any(|l| l.code.contains("#![deny(missing_docs)]"))
        {
            viols.push(Violation {
                file: rel,
                line: 1,
                rule: Rule::MissingDocsAttr,
                message: "this module is listed in CI as #![deny(missing_docs)] but \
                          does not carry the attribute"
                    .to_string(),
            });
        }
    }
    viols.sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)));
    Ok(viols)
}

fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

fn main() -> ExitCode {
    let mut root = default_root();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    println!("landscape-lint: --root needs a directory argument");
                    return ExitCode::FAILURE;
                }
            },
            "--help" | "-h" => {
                println!(
                    "landscape-lint [--root DIR]\n\
                     Project invariant lint (see docs/INVARIANTS.md).\n\
                     Default root: {}",
                    default_root().display()
                );
                return ExitCode::SUCCESS;
            }
            other => {
                println!("landscape-lint: unknown argument `{other}` (try --help)");
                return ExitCode::FAILURE;
            }
        }
    }
    let viols = match lint_root(&root) {
        Ok(v) => v,
        Err(e) => {
            println!("landscape-lint: cannot read {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if viols.is_empty() {
        println!("landscape-lint: clean ({})", root.display());
        return ExitCode::SUCCESS;
    }
    for v in &viols {
        println!("{v}");
    }
    println!(
        "landscape-lint: {} violation(s) in {} — see docs/INVARIANTS.md for \
         the rules and the `// lint: allow(<tag>) — <reason>` escape hatch",
        viols.len(),
        root.display()
    );
    ExitCode::FAILURE
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("tests")
            .join("lint_fixtures")
            .join(name)
    }

    fn rules(viols: &[Violation]) -> Vec<Rule> {
        viols.iter().map(|v| v.rule).collect()
    }

    // ---- scanner ----

    #[test]
    fn scanner_strips_string_contents() {
        let lines = scan_source("let x = \"Ordering::Relaxed .unwrap()\";\n");
        assert_eq!(lines[0].code, "let x = \"\";");
        assert!(lines[0].comment.is_empty());
    }

    #[test]
    fn scanner_strips_raw_strings_and_keeps_code() {
        let lines = scan_source("let v = Json::parse(r#\"{\"a\": 1}\"#).unwrap();\n");
        assert_eq!(lines[0].code, "let v = Json::parse(\"\").unwrap();");
    }

    #[test]
    fn scanner_handles_multiline_strings() {
        let src = "log_warn!(\n    \"line one {x} \\\n     eprintln! inside\"\n);\n";
        let lines = scan_source(src);
        assert_eq!(lines[1].code.trim(), "\"");
        assert_eq!(lines[2].code.trim(), "\"");
        assert!(!lines.iter().any(|l| l.code.contains("eprintln!")));
    }

    #[test]
    fn scanner_separates_line_comments() {
        let lines = scan_source("foo(); // lint: allow(eprintln) — the reason\n");
        assert_eq!(lines[0].code, "foo(); ");
        assert!(has_allow(&lines[0].comment, "eprintln"));
    }

    #[test]
    fn scanner_handles_char_literals_and_lifetimes() {
        let lines = scan_source("fn f<'a>(c: char) -> bool { c == '{' || c == '\\'' }\n");
        // the brace inside the char literal must not leak into code
        let braces = lines[0].code.matches('{').count();
        assert_eq!(braces, 1, "only the fn body brace: {:?}", lines[0].code);
    }

    #[test]
    fn scanner_strips_block_comments() {
        let lines = scan_source("a(); /* eprintln! \n still comment */ b();\n");
        assert_eq!(lines[0].code, "a(); ");
        assert_eq!(lines[1].code, " b();");
    }

    // ---- rule mechanics on inline sources ----

    #[test]
    fn relaxed_ordering_flagged_outside_whitelist() {
        let src = "fn f(c: &AtomicU64) { c.fetch_add(1, Ordering::Relaxed); }\n";
        assert_eq!(
            rules(&lint_file("coordinator/foo.rs", src)),
            vec![Rule::RelaxedOrdering]
        );
        assert!(lint_file("sketch/store.rs", src).is_empty());
    }

    #[test]
    fn relaxed_ordering_allow_comment_needs_a_reason() {
        let justified = "// lint: allow(relaxed-ordering) — statistics only\n\
                         c.fetch_add(1, Ordering::Relaxed);\n";
        assert!(lint_file("metrics.rs", justified).is_empty());
        let bare = "// lint: allow(relaxed-ordering)\n\
                    c.fetch_add(1, Ordering::Relaxed);\n";
        assert_eq!(rules(&lint_file("metrics.rs", bare)), vec![Rule::RelaxedOrdering]);
    }

    #[test]
    fn lock_poisoning_idiom_is_exempt() {
        let src = "fn f(&self) { let g = self.state.lock().unwrap(); g.run(); }\n";
        assert!(lint_file("coordinator/foo.rs", src).is_empty());
        // chained across lines, condvar wait with nested parens
        let chained = "let (g, _t) = self\n    .cv\n    .wait_timeout(st, Duration::from_millis(50))\n    .unwrap();\n";
        assert!(lint_file("worker/foo.rs", chained).is_empty());
    }

    #[test]
    fn non_lock_unwrap_in_hot_path_is_flagged() {
        let src = "fn f(s: &str) -> u32 { s.parse().unwrap() }\n";
        assert_eq!(
            rules(&lint_file("gutter/foo.rs", src)),
            vec![Rule::HotPathUnwrap]
        );
        // the same code outside the hot-path trees is fine
        assert!(lint_file("analysis/foo.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_blocks_are_exempt() {
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { \"1\".parse::<u32>().unwrap(); std::thread::sleep(d); }\n\
                   }\n";
        assert!(lint_file("session/foo.rs", src).is_empty());
    }

    #[test]
    fn thread_sleep_flagged_in_hot_path_production_code() {
        let src = "fn f() { std::thread::sleep(Duration::from_millis(1)); }\n";
        assert_eq!(
            rules(&lint_file("worker/foo.rs", src)),
            vec![Rule::ThreadSleep]
        );
    }

    // ---- fixture trees (one seeded violation per rule; clean tree) ----

    #[test]
    fn clean_fixture_tree_is_clean() {
        let viols = lint_root(&fixture("clean")).unwrap();
        assert!(viols.is_empty(), "unexpected: {viols:?}");
    }

    #[test]
    fn relaxed_ordering_fixture_is_flagged() {
        let viols = lint_root(&fixture("relaxed_ordering")).unwrap();
        assert_eq!(rules(&viols), vec![Rule::RelaxedOrdering], "{viols:?}");
    }

    #[test]
    fn eprintln_fixture_is_flagged() {
        let viols = lint_root(&fixture("eprintln")).unwrap();
        assert_eq!(rules(&viols), vec![Rule::Eprintln], "{viols:?}");
    }

    #[test]
    fn hot_path_unwrap_fixture_is_flagged() {
        let viols = lint_root(&fixture("hot_path_unwrap")).unwrap();
        assert_eq!(rules(&viols), vec![Rule::HotPathUnwrap], "{viols:?}");
    }

    #[test]
    fn thread_sleep_fixture_is_flagged() {
        let viols = lint_root(&fixture("thread_sleep")).unwrap();
        assert_eq!(rules(&viols), vec![Rule::ThreadSleep], "{viols:?}");
    }

    #[test]
    fn missing_docs_fixture_is_flagged() {
        let viols = lint_root(&fixture("missing_docs")).unwrap();
        assert_eq!(rules(&viols), vec![Rule::MissingDocsAttr], "{viols:?}");
    }

    // ---- the real tree lints clean (the acceptance criterion; also
    // checked at the process level by tests/lint_selftest.rs) ----

    #[test]
    fn real_source_tree_is_clean() {
        let viols = lint_root(&default_root()).unwrap();
        assert!(
            viols.is_empty(),
            "rust/src has lint violations:\n{}",
            viols
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
