// Spike: load /tmp/spike_u64.hlo.txt (u64 xor-fold pallas kernel) and
// verify the numerics match the python reference.
fn main() -> Result<(), Box<dyn std::error::Error>> {
    let path = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/spike_u64.hlo.txt".to_string());
    let client = xla::PjRtClient::cpu()?;
    println!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;

    const GOLDEN: u64 = 0x9E3779B97F4A7C15;
    let input: Vec<u64> = (1..=8u64).map(|i| i.wrapping_mul(GOLDEN)).collect();
    let lit = xla::Literal::vec1(&input);
    let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    let out = result.to_tuple1()?;
    let values = out.to_vec::<u64>()?;
    println!("result={values:?}");
    // reference from spike_u64.py
    assert_eq!(values, vec![12685939312746212621u64]);
    println!("spike OK");
    Ok(())
}
