//! PJRT runtime: loads the AOT-compiled sketch-delta kernels
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them from the worker hot path.  Python is never involved at
//! runtime — the HLO text is compiled by the `xla` crate's bundled XLA
//! (PJRT CPU client) at startup and executed as native code thereafter.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! The artifact [`Manifest`] is plain JSON and always available; the
//! PJRT pieces ([`Runtime`], [`DeltaExecutable`]) depend on the external
//! `xla` crate and are gated behind the non-default `xla` cargo feature
//! so the pure-Rust worker paths build on a bare toolchain.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::sketch::params::{SketchParams, SEED_SCHEME_VERSION};
#[cfg(feature = "xla")]
use crate::sketch::seeds::SketchSeeds;
use crate::util::json::Json;

/// One artifact entry from `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub vertices: u64,
    pub levels: u32,
    pub columns: u32,
    pub rows: u32,
    pub batch: usize,
    pub file: String,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub entries: Vec<ArtifactEntry>,
    dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let version = json
            .get("seed_scheme_version")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow!("manifest missing seed_scheme_version"))?;
        if version != SEED_SCHEME_VERSION {
            bail!(
                "artifact seed scheme v{version} != library v{SEED_SCHEME_VERSION}; \
                 regenerate with `make artifacts`"
            );
        }
        let batch = json
            .get("batch")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest missing batch"))?;
        let mut entries = Vec::new();
        for e in json
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            entries.push(ArtifactEntry {
                vertices: e.get("vertices").and_then(|v| v.as_u64()).unwrap_or(0),
                levels: e.get("levels").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                columns: e.get("columns").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                rows: e.get("rows").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                batch: e.get("batch").and_then(|v| v.as_usize()).unwrap_or(batch),
                file: e
                    .get("file")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            });
        }
        Ok(Self {
            batch,
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Find the artifact whose shape matches `params` (levels, columns,
    /// rows all equal — V values sharing a shape share an artifact).
    pub fn find(&self, params: &SketchParams) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.levels == params.levels && e.columns == params.columns && e.rows == params.rows
        })
    }
}

/// A compiled sketch-delta executable.
#[cfg(feature = "xla")]
pub struct DeltaExecutable {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    params: SketchParams,
}

/// The PJRT client wrapper.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile the delta kernel for `params` from `artifact_dir`.
    pub fn load_delta_executable(
        &self,
        artifact_dir: &Path,
        params: SketchParams,
    ) -> Result<DeltaExecutable> {
        let manifest = Manifest::load(artifact_dir)?;
        let entry = manifest.find(&params).ok_or_else(|| {
            anyhow!(
                "no artifact for shape L{} C{} R{}; add V={} to aot.py --vertices",
                params.levels,
                params.columns,
                params.rows,
                params.v
            )
        })?;
        let path = manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("hlo parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("xla compile: {e:?}"))?;
        Ok(DeltaExecutable {
            exe,
            batch: entry.batch,
            params,
        })
    }
}

#[cfg(feature = "xla")]
impl DeltaExecutable {
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn params(&self) -> &SketchParams {
        &self.params
    }

    /// Compute the (L·C·R·2)-word delta of `indices` under `seeds`.
    ///
    /// Chunks into the compiled batch size, XOR-merging chunk deltas —
    /// exact by linearity, mirroring `python/compile/model.py`.
    pub fn compute_delta(&self, indices: &[u64], seeds: &SketchSeeds) -> Result<Vec<u64>> {
        let words = self.params.words();
        let mut out = vec![0u64; words];
        let dseeds = xla::Literal::vec1(&seeds.dseeds)
            .reshape(&[self.params.levels as i64, self.params.columns as i64])
            .map_err(|e| anyhow!("reshape dseeds: {e:?}"))?;
        let cseeds = xla::Literal::vec1(&seeds.cseeds);

        let mut padded = vec![0u64; self.batch];
        for chunk in indices.chunks(self.batch.max(1)) {
            padded.fill(0);
            padded[..chunk.len()].copy_from_slice(chunk);
            let idx = xla::Literal::vec1(&padded);
            let result = self
                .exe
                .execute::<xla::Literal>(&[idx, dseeds.clone(), cseeds.clone()])
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let delta = result
                .to_tuple1()
                .map_err(|e| anyhow!("to_tuple1: {e:?}"))?
                .to_vec::<u64>()
                .map_err(|e| anyhow!("to_vec: {e:?}"))?;
            debug_assert_eq!(delta.len(), words);
            for (o, d) in out.iter_mut().zip(&delta) {
                *o ^= *d;
            }
        }
        Ok(out)
    }
}

// End-to-end runtime tests (needing `make artifacts`) live in
// tests/xla_parity.rs; unit tests here cover manifest parsing.
#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"))
    }

    #[test]
    fn manifest_parses_and_covers_configs() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            crate::log_info!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.batch >= 8);
        assert!(!m.entries.is_empty());
        // the default artifact set covers V = 2^13
        let p = SketchParams::for_vertices(1 << 13);
        assert!(m.find(&p).is_some(), "no artifact for kron13 shape");
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("manifest"));
    }
}
