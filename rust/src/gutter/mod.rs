//! GraphZeppelin-style "gutter" buffering — the ablation baseline for
//! Fig. 4 / Fig. 16.
//!
//! GraphZeppelin's in-RAM buffering writes each update directly into a
//! per-vertex gutter behind a striped lock: one shared-map access (≈ one
//! cache miss) and one lock acquisition *per update*, versus the
//! pipeline hypertree's bulk cascades.  The interface matches the
//! hypertree's so the coordinator can swap them (`BufferKind::Gutter`).
//!
//! Stripes are aligned to the sketch shard map ([`ShardSpec`]): stripe
//! `s` holds exactly the vertices of sketch shard `s`, so every batch a
//! stripe emits is consumed by the same distributor thread — the
//! baseline keeps its per-update locking cost (that is the point of the
//! ablation) but routes shard-affine like the hypertree does.
//!
//! The storage tier reuses the same write-optimized-buffering idea one
//! level down: [`DeltaGutter`] accumulates XOR deltas for **cold**
//! (non-resident) vertices inside a spill stripe, so a burst of updates
//! to paged-out vertices turns into one large sequential segment write
//! at flush time instead of a random block fault per batch (the
//! GraphZeppelin gutter-tree argument applied to the sketch store
//! itself — see `docs/STORAGE.md`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::hypertree::{BatchSink, VertexBatch};
use crate::metrics::Metrics;
use crate::sketch::shard::ShardSpec;

/// Per-vertex gutters behind shard-aligned striped mutexes.
pub struct GutterBuffer {
    vertices: u64,
    leaf_capacity: usize,
    spec: ShardSpec,
    stripes: Vec<Mutex<Vec<Vec<u32>>>>,
    metrics: Arc<Metrics>,
}

impl GutterBuffer {
    pub fn new(
        vertices: u64,
        leaf_capacity: usize,
        spec: ShardSpec,
        metrics: Arc<Metrics>,
    ) -> Self {
        let stripes = (0..spec.count())
            .map(|s| {
                let size = spec.shard_len(s, vertices);
                Mutex::new((0..size).map(|_| Vec::new()).collect())
            })
            .collect();
        Self {
            vertices,
            leaf_capacity,
            spec,
            stripes,
            metrics,
        }
    }

    /// Insert one (destination, other-endpoint) entry — one lock + one
    /// random gutter access per update (the baseline's bottleneck by
    /// design).
    pub fn insert<S: BatchSink>(&self, dest: u32, other: u32, sink: &S) {
        let stripe = self.spec.shard_of(dest);
        let slot = self.spec.slot_of(dest);
        let mut gutters = self.stripes[stripe].lock().unwrap();
        let gutter = &mut gutters[slot];
        if gutter.capacity() == 0 {
            gutter.reserve_exact(self.leaf_capacity);
        }
        gutter.push(other);
        Metrics::add(&self.metrics.hypertree_moves, 1);
        if gutter.len() >= self.leaf_capacity {
            let full = std::mem::take(gutter);
            drop(gutters);
            sink.full_batch(
                sink.shards().shard_of(dest),
                VertexBatch {
                    vertex: dest,
                    others: full,
                },
            );
        }
    }

    /// Flush everything; leaves ≥ `gamma` ship as batches, rest local —
    /// same hybrid policy as the hypertree so comparisons are fair.
    pub fn force_flush<S: BatchSink>(&self, gamma: f64, sink: &S) {
        let threshold = ((self.leaf_capacity as f64 * gamma).ceil() as usize).max(1);
        let route = sink.shards();
        for (s, stripe) in self.stripes.iter().enumerate() {
            let mut gutters = stripe.lock().unwrap();
            for (i, gutter) in gutters.iter_mut().enumerate() {
                if gutter.is_empty() {
                    continue;
                }
                let vertex = self.spec.vertex_at(s, i);
                if gutter.len() >= threshold {
                    sink.full_batch(
                        route.shard_of(vertex),
                        VertexBatch {
                            vertex,
                            others: std::mem::take(gutter),
                        },
                    );
                } else {
                    sink.local_batch(route.shard_of(vertex), vertex, gutter);
                    gutter.clear();
                }
            }
        }
    }

    pub fn vertices(&self) -> u64 {
        self.vertices
    }

    /// The shard map stripes are aligned to.
    pub fn shards(&self) -> ShardSpec {
        self.spec
    }
}

/// An XOR-accumulating per-vertex delta buffer for the spill tier's
/// cold-vertex write path.
///
/// Because sketch merges are XOR (self-inverse, commutative), deltas
/// destined for a paged-out vertex can be folded together here and
/// applied to the on-disk block later in one read-modify-write — the
/// write-optimized buffering of GraphZeppelin's gutter trees, applied
/// at block granularity.  Every entry is a full `k × words`-long delta
/// for one vertex.
///
/// Not internally synchronized: each [`DeltaGutter`] lives inside one
/// spill-stripe mutex (shard-aligned, like [`GutterBuffer`]'s stripes).
pub struct DeltaGutter {
    words: usize,
    entries: HashMap<u32, Box<[u64]>>,
}

impl DeltaGutter {
    /// A gutter whose entries are `words`-long deltas.
    pub fn new(words: usize) -> Self {
        Self {
            words,
            entries: HashMap::new(),
        }
    }

    /// Fold `delta` into vertex `u`'s accumulated entry (allocating a
    /// zeroed entry on first touch).  `delta` must be `words` long.
    pub fn xor(&mut self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.words);
        let entry = self
            .entries
            .entry(u)
            .or_insert_with(|| vec![0u64; self.words].into_boxed_slice());
        for (e, d) in entry.iter_mut().zip(delta) {
            *e ^= d;
        }
    }

    /// Whether vertex `u` has a buffered delta.
    pub fn contains(&self, u: u32) -> bool {
        self.entries.contains_key(&u)
    }

    /// Borrow vertex `u`'s buffered delta (query paths XOR this over
    /// the on-disk block so reads see un-flushed updates).
    pub fn peek(&self, u: u32) -> Option<&[u64]> {
        self.entries.get(&u).map(|e| &**e)
    }

    /// Remove and return vertex `u`'s buffered delta (used when the
    /// vertex is faulted in: the accumulated delta folds into the now
    /// resident block).
    pub fn take(&mut self, u: u32) -> Option<Box<[u64]>> {
        self.entries.remove(&u)
    }

    /// Drain every entry, sorted by vertex id — ascending ids map to
    /// ascending segment offsets, so the flush becomes one sequential
    /// sweep per segment file.
    pub fn drain_sorted(&mut self) -> Vec<(u32, Box<[u64]>)> {
        let mut out: Vec<(u32, Box<[u64]>)> = self.entries.drain().collect();
        out.sort_unstable_by_key(|(u, _)| *u);
        out
    }

    /// Buffered payload bytes (entry words only, excluding map
    /// overhead) — the flush high-water-mark input.
    pub fn bytes(&self) -> u64 {
        (self.entries.len() * self.words * 8) as u64
    }

    /// Number of vertices with buffered deltas.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the gutter is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop all buffered deltas.
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    #[derive(Default)]
    struct Collect {
        full: StdMutex<Vec<VertexBatch>>,
        local: StdMutex<Vec<(u32, Vec<u32>)>>,
    }

    impl BatchSink for Collect {
        fn full_batch(&self, shard: usize, b: VertexBatch) {
            assert_eq!(shard, 0, "single-shard sink must route to shard 0");
            self.full.lock().unwrap().push(b);
        }
        fn local_batch(&self, shard: usize, v: u32, others: &[u32]) {
            assert_eq!(shard, 0);
            self.local.lock().unwrap().push((v, others.to_vec()));
        }
    }

    #[test]
    fn capacity_triggers_batches() {
        let g = GutterBuffer::new(16, 4, ShardSpec::new(2), Arc::new(Metrics::new()));
        let sink = Collect::default();
        for i in 0..10u32 {
            g.insert(3, i + 1, &sink);
        }
        g.force_flush(1.0, &sink);
        let full = sink.full.lock().unwrap();
        assert_eq!(full.len(), 2);
        assert!(full.iter().all(|b| b.vertex == 3 && b.others.len() == 4));
        assert_eq!(sink.local.lock().unwrap()[0].1.len(), 2);
    }

    #[test]
    fn nothing_lost() {
        let g = GutterBuffer::new(64, 7, ShardSpec::new(4), Arc::new(Metrics::new()));
        let sink = Collect::default();
        for i in 0..1000u32 {
            g.insert(i % 64, i + 1, &sink);
        }
        g.force_flush(0.0, &sink);
        let total: usize = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.others.len())
            .sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn flush_reconstructs_vertices_across_stripes() {
        // vertices 0..V scattered over shard-aligned stripes must come
        // back out under their own ids
        let g = GutterBuffer::new(32, 8, ShardSpec::new(3), Arc::new(Metrics::new()));
        assert_eq!(g.shards().count(), 3);
        let sink = Collect::default();
        for v in 0..32u32 {
            g.insert(v, v + 100, &sink);
        }
        g.force_flush(0.0, &sink);
        let mut seen: Vec<u32> = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| {
                assert_eq!(b.others, vec![b.vertex + 100]);
                b.vertex
            })
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..32).collect::<Vec<u32>>());
    }

    #[test]
    fn threads_contend_but_stay_correct() {
        let g = Arc::new(GutterBuffer::new(
            32,
            8,
            ShardSpec::new(2),
            Arc::new(Metrics::new()),
        ));
        let sink = Arc::new(Collect::default());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let g2 = g.clone();
            let s2 = sink.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2500u64 {
                    g2.insert(((t * 2500 + i) % 32) as u32, (t * 2500 + i + 1) as u32, &*s2);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        g.force_flush(0.0, &*sink);
        let total: usize = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.others.len())
            .sum();
        assert_eq!(total, 10_000);
    }

    #[test]
    fn delta_gutter_folds_and_drains_sorted() {
        let mut g = DeltaGutter::new(3);
        assert!(g.is_empty());
        g.xor(9, &[1, 2, 4]);
        g.xor(3, &[8, 0, 0]);
        g.xor(9, &[1, 2, 0]); // self-inverse: first two words cancel
        assert_eq!(g.len(), 2);
        assert_eq!(g.bytes(), 2 * 3 * 8);
        assert!(g.contains(9) && !g.contains(7));
        assert_eq!(g.peek(9).unwrap(), &[0, 0, 4]);
        let drained = g.drain_sorted();
        assert_eq!(drained[0].0, 3);
        assert_eq!(drained[1].0, 9);
        assert_eq!(&*drained[1].1, &[0, 0, 4]);
        assert!(g.is_empty() && g.bytes() == 0);
    }

    #[test]
    fn delta_gutter_take_removes_the_entry() {
        let mut g = DeltaGutter::new(2);
        g.xor(5, &[7, 7]);
        assert_eq!(&*g.take(5).unwrap(), &[7, 7]);
        assert!(g.take(5).is_none());
        assert!(g.is_empty());
        g.xor(5, &[1, 1]);
        g.clear();
        assert!(g.is_empty());
    }
}
