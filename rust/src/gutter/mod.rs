//! GraphZeppelin-style "gutter" buffering — the ablation baseline for
//! Fig. 4 / Fig. 16.
//!
//! GraphZeppelin's in-RAM buffering writes each update directly into a
//! per-vertex gutter behind a striped lock: one shared-map access (≈ one
//! cache miss) and one lock acquisition *per update*, versus the
//! pipeline hypertree's bulk cascades.  The interface matches the
//! hypertree's so the coordinator can swap them (`BufferKind::Gutter`).

use std::sync::{Arc, Mutex};

use crate::hypertree::{BatchSink, VertexBatch};
use crate::metrics::Metrics;

/// Per-vertex gutters behind striped mutexes.
pub struct GutterBuffer {
    vertices: u64,
    leaf_capacity: usize,
    stripes: Vec<Mutex<Vec<Vec<u32>>>>,
    stripe_size: usize,
    metrics: Arc<Metrics>,
}

impl GutterBuffer {
    pub fn new(
        vertices: u64,
        leaf_capacity: usize,
        num_stripes: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        let stripe_size = crate::util::div_ceil(vertices as usize, num_stripes.max(1));
        let stripes = (0..num_stripes.max(1))
            .map(|s| {
                let start = s * stripe_size;
                let size = stripe_size.min((vertices as usize).saturating_sub(start));
                Mutex::new((0..size).map(|_| Vec::new()).collect())
            })
            .collect();
        Self {
            vertices,
            leaf_capacity,
            stripes,
            stripe_size,
            metrics,
        }
    }

    /// Insert one (destination, other-endpoint) entry — one lock + one
    /// random gutter access per update (the baseline's bottleneck by
    /// design).
    pub fn insert<S: BatchSink>(&self, dest: u32, other: u32, sink: &S) {
        let stripe = dest as usize / self.stripe_size;
        let slot = dest as usize % self.stripe_size;
        let mut gutters = self.stripes[stripe].lock().unwrap();
        let gutter = &mut gutters[slot];
        if gutter.capacity() == 0 {
            gutter.reserve_exact(self.leaf_capacity);
        }
        gutter.push(other);
        self.metrics
            .hypertree_moves
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if gutter.len() >= self.leaf_capacity {
            let full = std::mem::take(gutter);
            sink.full_batch(VertexBatch {
                vertex: dest,
                others: full,
            });
        }
    }

    /// Flush everything; leaves ≥ `gamma` ship as batches, rest local —
    /// same hybrid policy as the hypertree so comparisons are fair.
    pub fn force_flush<S: BatchSink>(&self, gamma: f64, sink: &S) {
        let threshold = ((self.leaf_capacity as f64 * gamma).ceil() as usize).max(1);
        for (s, stripe) in self.stripes.iter().enumerate() {
            let mut gutters = stripe.lock().unwrap();
            for (i, gutter) in gutters.iter_mut().enumerate() {
                if gutter.is_empty() {
                    continue;
                }
                let vertex = (s * self.stripe_size + i) as u32;
                if gutter.len() >= threshold {
                    sink.full_batch(VertexBatch {
                        vertex,
                        others: std::mem::take(gutter),
                    });
                } else {
                    sink.local_batch(vertex, gutter);
                    gutter.clear();
                }
            }
        }
    }

    pub fn vertices(&self) -> u64 {
        self.vertices
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    #[derive(Default)]
    struct Collect {
        full: StdMutex<Vec<VertexBatch>>,
        local: StdMutex<Vec<(u32, Vec<u32>)>>,
    }

    impl BatchSink for Collect {
        fn full_batch(&self, b: VertexBatch) {
            self.full.lock().unwrap().push(b);
        }
        fn local_batch(&self, v: u32, others: &[u32]) {
            self.local.lock().unwrap().push((v, others.to_vec()));
        }
    }

    #[test]
    fn capacity_triggers_batches() {
        let g = GutterBuffer::new(16, 4, 2, Arc::new(Metrics::new()));
        let sink = Collect::default();
        for i in 0..10u32 {
            g.insert(3, i + 1, &sink);
        }
        g.force_flush(1.0, &sink);
        let full = sink.full.lock().unwrap();
        assert_eq!(full.len(), 2);
        assert!(full.iter().all(|b| b.vertex == 3 && b.others.len() == 4));
        assert_eq!(sink.local.lock().unwrap()[0].1.len(), 2);
    }

    #[test]
    fn nothing_lost() {
        let g = GutterBuffer::new(64, 7, 4, Arc::new(Metrics::new()));
        let sink = Collect::default();
        for i in 0..1000u32 {
            g.insert(i % 64, i + 1, &sink);
        }
        g.force_flush(0.0, &sink);
        let total: usize = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.others.len())
            .sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn threads_contend_but_stay_correct() {
        let g = Arc::new(GutterBuffer::new(32, 8, 2, Arc::new(Metrics::new())));
        let sink = Arc::new(Collect::default());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let g2 = g.clone();
            let s2 = sink.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2500u64 {
                    g2.insert(((t * 2500 + i) % 32) as u32, (t * 2500 + i + 1) as u32, &*s2);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        g.force_flush(0.0, &*sink);
        let total: usize = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.others.len())
            .sum();
        assert_eq!(total, 10_000);
    }
}
