//! Seeded property-testing harness.
//!
//! The offline environment vendors no `proptest`, so invariant tests use
//! this small substitute: run a property over many deterministically
//! seeded random cases and report the failing seed for reproduction.
//! There is no shrinking; failures print the case index and seed, which
//! is enough to replay (`Cases::one(seed)`).

use crate::util::rng::Xoshiro256;

/// Runs `n` seeded cases of a property.
pub struct Cases {
    n: usize,
    base_seed: u64,
}

impl Cases {
    /// `n` cases derived from a fixed base seed (deterministic in CI).
    pub fn new(n: usize) -> Self {
        Self { n, base_seed: 0x1A2B3C4D5E6F7788 }
    }

    /// Override the base seed (e.g. to replay a reported failure).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.base_seed = seed;
        self
    }

    /// A single case for failure replay.
    pub fn one(seed: u64) -> Self {
        Self { n: 1, base_seed: seed }
    }

    /// Run `prop` for each case; panics with the case seed on failure.
    pub fn run(self, mut prop: impl FnMut(&mut Xoshiro256)) {
        for i in 0..self.n {
            let seed = self
                .base_seed
                .wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut rng = Xoshiro256::new(seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut rng)
            }));
            if let Err(panic) = result {
                crate::log_error!(
                    "property failed at case {i}/{} — replay with \
                     Cases::one({seed:#x})",
                    self.n
                );
                std::panic::resume_unwind(panic);
            }
        }
    }
}

/// Draw a random simple edge (a < b) over `v` vertices.
pub fn arb_edge(rng: &mut Xoshiro256, v: u64) -> (u32, u32) {
    debug_assert!(v >= 2);
    let a = rng.next_below(v) as u32;
    let mut b = rng.next_below(v) as u32;
    while b == a {
        b = rng.next_below(v) as u32;
    }
    if a < b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Draw a random edge set of size up to `max_edges`.
pub fn arb_edge_set(
    rng: &mut Xoshiro256,
    v: u64,
    max_edges: usize,
) -> Vec<(u32, u32)> {
    let n = rng.next_below(max_edges as u64 + 1) as usize;
    let mut set = std::collections::BTreeSet::new();
    for _ in 0..n {
        set.insert(arb_edge(rng, v));
    }
    set.into_iter().collect()
}

/// The standard base graph for query-under-sustained-load scenarios:
/// `cycles` disjoint cycles of `span` vertices each (vertices
/// `c*span .. (c+1)*span`).  A cycle stays connected when any single
/// chord is added or removed, which is what makes the chord churn of
/// [`churn_chord`] partition-invariant.
pub fn cycle_graph(cycles: u32, span: u32) -> Vec<crate::stream::update::Update> {
    use crate::stream::update::Update;
    let mut base = Vec::with_capacity((cycles * span) as usize);
    for c in 0..cycles {
        let b = c * span;
        for i in 0..span - 1 {
            base.push(Update::insert(b + i, b + i + 1));
        }
        base.push(Update::insert(b, b + span - 1));
    }
    base
}

/// Producer `p`'s churn chord inside the [`cycle_graph`] cycle starting
/// at vertex `base`: `(base+1+p, base+1+p+span/2)`.
///
/// Chord sets are disjoint across producers (each `p` gets its own
/// endpoints), both endpoints lie strictly inside the cycle, and a
/// chord never disconnects anything whether present or absent — so a
/// stream of `insert(chord); delete(chord)` toggles, interleaved
/// arbitrarily across producers and merged in any order, leaves the
/// partition equal to the base graph's at every instant.  Requires
/// `p + 1 < span / 2`.
pub fn churn_chord(base: u32, p: usize, span: u32) -> (u32, u32) {
    debug_assert!((p as u32) + 1 < span / 2, "chord endpoints must stay in-cycle");
    (base + 1 + p as u32, base + 1 + p as u32 + span / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut seen_a = Vec::new();
        Cases::new(5).run(|rng| seen_a.push(rng.next_u64()));
        let mut seen_b = Vec::new();
        Cases::new(5).run(|rng| seen_b.push(rng.next_u64()));
        assert_eq!(seen_a, seen_b);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        let mut i = 0;
        Cases::new(10).run(|_rng| {
            i += 1;
            assert!(i < 5, "intentional failure at case 5");
        });
    }

    #[test]
    fn arb_edge_well_formed() {
        Cases::new(50).run(|rng| {
            let (a, b) = arb_edge(rng, 17);
            assert!(a < b && (b as u64) < 17);
        });
    }

    #[test]
    fn arb_edge_set_unique_and_sorted() {
        Cases::new(20).run(|rng| {
            let edges = arb_edge_set(rng, 32, 40);
            for w in edges.windows(2) {
                assert!(w[0] < w[1]);
            }
        });
    }
}
