//! Minimal JSON parser + writer.
//!
//! The offline environment vendors no serde, so this module provides the
//! small JSON surface the project needs: reading the AOT artifact
//! manifest and the cross-language golden fixtures, and emitting bench
//! results.  It is a strict recursive-descent parser over the JSON we
//! produce ourselves (objects, arrays, strings, integers, floats, bools,
//! null); it is not intended as a general-purpose library.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            // JSON numbers are f64 (lossy beyond 2^53); large u64 values in
            // our fixtures are therefore encoded as decimal *strings*.
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            Json::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            other => Err(format!(
                "expected '{}' at byte {}, got {:?}",
                b as char,
                self.pos.saturating_sub(1),
                other.map(|c| c as char)
            )),
        }
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("bad object separator {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => return Err(format!("bad array separator {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(b) => out.push(b as char),
                None => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Tiny builder for emitting JSON objects (bench results, metrics dumps).
#[derive(Default)]
pub struct JsonWriter {
    fields: Vec<(String, String)>,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn field_str(&mut self, key: &str, value: &str) -> &mut Self {
        self.fields.push((key.into(), format!("\"{}\"", escape(value))));
        self
    }

    pub fn field_u64(&mut self, key: &str, value: u64) -> &mut Self {
        self.fields.push((key.into(), value.to_string()));
        self
    }

    pub fn field_f64(&mut self, key: &str, value: f64) -> &mut Self {
        self.fields.push((key.into(), format!("{value:.6}")));
        self
    }

    pub fn field_raw(&mut self, key: &str, value: String) -> &mut Self {
        self.fields.push((key.into(), value));
        self
    }

    pub fn finish(&self) -> String {
        let body: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{}\": {}", escape(k), v))
            .collect();
        format!("{{{}}}", body.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2").unwrap(), Json::Num(-2.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn u64_roundtrip_via_strings() {
        // big u64s don't fit f64; fixtures use decimal strings
        let big = u64::MAX;
        let v = Json::parse(&format!("{{\"x\": \"{big}\"}}")).unwrap();
        assert_eq!(v.get("x").unwrap().as_u64(), Some(big));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
        assert_eq!(escape("a\nb\"c\""), "a\\nb\\\"c\\\"");
    }

    #[test]
    fn writer_emits_valid_json() {
        let mut w = JsonWriter::new();
        w.field_str("name", "x\"y").field_u64("n", 7).field_f64("t", 0.5);
        let parsed = Json::parse(&w.finish()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("x\"y"));
        assert_eq!(parsed.get("n").unwrap().as_u64(), Some(7));
    }
}
