//! Small shared utilities: deterministic RNG, a minimal JSON
//! reader/writer (no serde in this offline environment), timing helpers,
//! and a seeded property-testing harness used across the test suite.

pub mod json;
pub mod log;
pub mod rng;
pub mod testkit;
pub mod timer;

/// Integer ceil-division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// `ceil(log_base(x))` computed in f64 but guarded against edge cases —
/// used for sketch parameter derivation (must match python/compile/params.py).
pub fn ceil_log(x: f64, base: f64) -> u32 {
    if x <= 1.0 {
        return 0;
    }
    (x.ln() / base.ln()).ceil() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }

    #[test]
    fn ceil_log_matches_integer_expectations() {
        assert_eq!(ceil_log(1.0, 2.0), 0);
        assert_eq!(ceil_log(2.0, 2.0), 1);
        assert_eq!(ceil_log(8192.0, 1.5), 23); // log_{1.5} 2^13, paper App. E
    }
}
