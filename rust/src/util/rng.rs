//! Deterministic xoshiro256** PRNG.
//!
//! The container has no `rand` crate; this is the standard xoshiro256**
//! generator (Blackman & Vigna), seeded via splitmix64 so that a single
//! u64 seed yields a well-mixed state.  Used by stream generators, the
//! property-test kit, and the Monte-Carlo analyses — never by the
//! sketches themselves (those use the explicit hashing contract in
//! [`crate::hashing`]).

use crate::hashing::splitmix64;

/// xoshiro256** generator with deterministic u64 seeding.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via four rounds of splitmix64, per the reference seeding advice.
    pub fn new(seed: u64) -> Self {
        let mut s = [0u64; 4];
        let mut x = seed;
        for slot in &mut s {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            *slot = splitmix64(x);
        }
        // all-zero state is invalid; splitmix64 of distinct inputs cannot
        // produce it, but guard anyway
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            assert!(r.next_below(13) < 13);
        }
    }

    #[test]
    fn next_f64_unit_interval_and_roughly_uniform() {
        let mut r = Xoshiro256::new(9);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
