//! Wall-clock timing helpers shared by the coordinator metrics and the
//! bench harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::new();
    let out = f();
    (out, sw.elapsed_secs())
}

/// Throughput in ops/sec guarded against zero elapsed time.
pub fn rate(ops: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::INFINITY;
    }
    ops as f64 / secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value_and_positive_time() {
        let (v, secs) = timed(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(secs >= 0.0);
    }

    #[test]
    fn rate_handles_zero() {
        assert!(rate(100, 0.0).is_infinite());
        assert!((rate(100, 2.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn restart_resets() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        let first = sw.restart();
        assert!(first.as_millis() >= 2);
        assert!(sw.elapsed() <= first + Duration::from_millis(50));
    }
}
