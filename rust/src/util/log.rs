//! A tiny leveled logging facility gated by the `LANDSCAPE_LOG`
//! environment variable — the offline environment vendors no `log` /
//! `env_logger`, and the production ingest paths must not write to
//! stderr unconditionally.
//!
//! `LANDSCAPE_LOG` accepts `off`, `error`, `warn`, `info` (the default)
//! or `debug`; everything at or above the configured severity prints to
//! stderr with a `landscape[LEVEL]` prefix.  The filter is read once,
//! lazily, on the first log call.
//!
//! Call sites use the crate-root macros, which format lazily — when the
//! level is filtered out, the format arguments are never evaluated into
//! a string:
//!
//! ```
//! landscape::log_warn!("dropped {} batches on shard {}", 3, 1);
//! landscape::log_info!("ingested {} updates", 1_000_000);
//! ```
//!
//! Subsystems that multiplex many contexts over shared machinery (the
//! multi-tenant serving layer, chiefly) can prepend a context tag with
//! the optional `target:` field — the line then reads
//! `landscape[LEVEL][target] ...`, so one interleaved stderr stream
//! stays attributable per tenant/connection:
//!
//! ```
//! landscape::log_info!(target: "serve", "tenant {} created", 3);
//! ```

use std::sync::OnceLock;

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or data-affecting failures (lost batches, dead
    /// backends).  Only `LANDSCAPE_LOG=off` silences these.
    Error = 0,
    /// Recoverable anomalies worth surfacing (failover, requeues,
    /// protocol skew).
    Warn = 1,
    /// Progress and result reporting (the CLI's normal chatter).
    Info = 2,
    /// High-volume diagnostics (per-connection, per-flush detail).
    Debug = 3,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// `None` = everything off.
static FILTER: OnceLock<Option<Level>> = OnceLock::new();

fn parse_filter(raw: Option<&str>) -> Option<Level> {
    match raw.map(|s| s.trim().to_ascii_lowercase()).as_deref() {
        Some("off") | Some("none") | Some("0") => None,
        Some("error") => Some(Level::Error),
        Some("warn") | Some("warning") => Some(Level::Warn),
        Some("debug") | Some("trace") => Some(Level::Debug),
        // `info` explicitly, unset, or unrecognized: the default
        _ => Some(Level::Info),
    }
}

fn filter() -> Option<Level> {
    *FILTER.get_or_init(|| {
        let raw = std::env::var("LANDSCAPE_LOG").ok();
        parse_filter(raw.as_deref())
    })
}

/// Is `level` currently emitted?  Useful to skip expensive diagnostics
/// entirely.
#[inline]
pub fn enabled(level: Level) -> bool {
    matches!(filter(), Some(max) if level <= max)
}

/// Emit one log line (used by the `log_*!` macros; prefer those).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("landscape[{}] {}", level.label(), args);
    }
}

/// Emit one context-tagged log line (used by the `log_*!(target: ...)`
/// macro arms; prefer those).
pub fn log_target(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("landscape[{}][{}] {}", level.label(), target, args);
    }
}

/// Log at [`Level::Error`] severity.  An optional leading
/// `target: <expr>,` prepends a `[target]` context tag.
#[macro_export]
macro_rules! log_error {
    (target: $target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Error) {
            $crate::util::log::log_target(
                $crate::util::log::Level::Error, $target, format_args!($($arg)*));
        }
    };
    ($($arg:tt)*) => {
        // check the filter BEFORE touching the arguments, so filtered
        // sites never evaluate expression operands
        if $crate::util::log::enabled($crate::util::log::Level::Error) {
            $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Warn`] severity.  An optional leading
/// `target: <expr>,` prepends a `[target]` context tag.
#[macro_export]
macro_rules! log_warn {
    (target: $target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            $crate::util::log::log_target(
                $crate::util::log::Level::Warn, $target, format_args!($($arg)*));
        }
    };
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Info`] severity.  An optional leading
/// `target: <expr>,` prepends a `[target]` context tag.
#[macro_export]
macro_rules! log_info {
    (target: $target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::log_target(
                $crate::util::log::Level::Info, $target, format_args!($($arg)*));
        }
    };
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Debug`] severity.  An optional leading
/// `target: <expr>,` prepends a `[target]` context tag.
#[macro_export]
macro_rules! log_debug {
    (target: $target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::log_target(
                $crate::util::log::Level::Debug, $target, format_args!($($arg)*));
        }
    };
    ($($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_parsing() {
        assert_eq!(parse_filter(Some("off")), None);
        assert_eq!(parse_filter(Some("0")), None);
        assert_eq!(parse_filter(Some("error")), Some(Level::Error));
        assert_eq!(parse_filter(Some("WARN")), Some(Level::Warn));
        assert_eq!(parse_filter(Some(" warn ")), Some(Level::Warn));
        assert_eq!(parse_filter(Some("info")), Some(Level::Info));
        assert_eq!(parse_filter(Some("debug")), Some(Level::Debug));
        // unset and junk both default to info
        assert_eq!(parse_filter(None), Some(Level::Info));
        assert_eq!(parse_filter(Some("verbose")), Some(Level::Info));
    }

    #[test]
    fn severity_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn target_arms_expand() {
        // both macro arms must compile against the same call-site shape;
        // expansion is the contract here (output goes to stderr)
        crate::log_debug!("plain {} arm", 1);
        crate::log_debug!(target: "serve", "tagged {} arm", 2);
        let tenant = 7u32;
        crate::log_debug!(target: &format!("tenant-{tenant}"), "dynamic target");
    }
}
