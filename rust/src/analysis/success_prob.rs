//! CameoSketch column success probability (App. H, Table 6).
//!
//! `F(z, d)`: the probability that a CameoSketch column with `d`
//! geometric rows succeeds (some bucket holds exactly one of `z`
//! nonzeros) under full independence:
//!
//! ```text
//! F(a, b) = Σ_{i ∈ [0,a]\{1}} 2^-a · C(a,i) · F(a-i, b-1)  +  a·2^-a
//! F(a, b) = 0 for a ≤ 0 or b ≤ 0
//! ```
//!
//! and the isolated-column variant `F̂(z,d) = F(z,d) − z·2^−z·(1 −
//! F(z−1, d−1))` that excludes the first bucket from the success
//! definition (used in the k-isolated-column argument of Lemma H.4).
//! A Monte-Carlo simulator cross-checks the recurrence against actual
//! CameoSketch columns.

use crate::hashing;

/// Binomial coefficient in f64 (exact for the small a used here).
fn binom(n: u64, k: u64) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut out = 1.0f64;
    for i in 0..k {
        out = out * (n - i) as f64 / (i + 1) as f64;
    }
    out
}

/// The recurrence F(z, d) — memoized.
pub fn success_probability(z: u64, d: u32) -> f64 {
    let mut memo = std::collections::HashMap::new();
    f_rec(z, d as i64, &mut memo)
}

fn f_rec(a: u64, b: i64, memo: &mut std::collections::HashMap<(u64, i64), f64>) -> f64 {
    if a == 0 {
        // zero nonzeros at this level: nothing to find — treat as
        // success only via the a·2^-a term of the parent (i.e. 0 here)
        return 0.0;
    }
    if b <= 0 {
        return 0.0;
    }
    if a == 1 {
        return 1.0; // a single nonzero lands alone in its bucket chain
    }
    if let Some(&v) = memo.get(&(a, b)) {
        return v;
    }
    let pow = 0.5f64.powi(a as i32);
    let mut total = a as f64 * pow; // exactly one lands in this bucket
    for i in 0..=a {
        if i == 1 {
            continue;
        }
        let rest = if a - i == 0 {
            0.0
        } else {
            f_rec(a - i, b - 1, memo)
        };
        total += pow * binom(a, i) * rest;
    }
    let v = total.min(1.0);
    memo.insert((a, b), v);
    v
}

/// F̂(z, d): success excluding the first bucket (App. H).
pub fn isolated_success_probability(z: u64, d: u32) -> f64 {
    if z <= 1 {
        return if z == 1 { 1.0 } else { 0.0 };
    }
    let f = success_probability(z, d);
    let first_only = z as f64 * 0.5f64.powi(z as i32)
        * (1.0 - success_probability(z - 1, d.saturating_sub(1)));
    (f - first_only).max(0.0)
}

/// Monte-Carlo estimate of the same probability using the *real*
/// CameoSketch update rule (geometric depths from hashing, row 0 is the
/// deterministic bucket which the analysis excludes).
pub fn monte_carlo_success(z: u64, rows_excl_det: u32, trials: u32, seed: u64) -> f64 {
    let rows = rows_excl_det as usize;
    let mut success = 0u32;
    for t in 0..trials {
        // counts + last-index per geometric row 1..=rows
        let mut count = vec![0u32; rows + 1];
        let dseed = hashing::splitmix64(seed ^ t as u64);
        for item in 0..z {
            // fresh "index" per item per trial
            let idx = hashing::splitmix64(dseed ^ (item + 1).wrapping_mul(0x9E3779B97F4A7C15));
            let h = hashing::depth_hash(dseed, idx);
            let depth = hashing::bucket_depth(h, rows_excl_det + 2) as usize;
            count[depth.min(rows)] += 1;
        }
        if count[1..].iter().any(|&c| c == 1) {
            success += 1;
        }
    }
    success as f64 / trials as f64
}

/// Reproduce Table 6: lower bound on column success for z = 1..=7 with
/// 10 buckets, full independence.
pub fn table6_rows() -> Vec<(u64, f64)> {
    (1..=7).map(|z| (z, success_probability(z, 10))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 6 values (CameoSketch column, 10 buckets).
    const TABLE6: [(u64, f64); 7] = [
        (1, 1.0),
        (2, 0.666),
        (3, 0.856),
        (4, 0.799),
        (5, 0.813),
        (6, 0.810),
        (7, 0.810),
    ];

    #[test]
    fn recurrence_reproduces_table6() {
        for (z, want) in TABLE6 {
            let got = success_probability(z, 10);
            assert!(
                (got - want).abs() < 0.02,
                "F({z},10) = {got:.3}, paper says {want}"
            );
        }
    }

    #[test]
    fn more_rows_never_hurt() {
        for z in 2..10u64 {
            assert!(success_probability(z, 12) >= success_probability(z, 6) - 1e-12);
        }
    }

    #[test]
    fn isolated_variant_is_lower() {
        for z in 2..8u64 {
            assert!(isolated_success_probability(z, 10) <= success_probability(z, 10));
        }
    }

    #[test]
    fn lemma_h4_bound_holds() {
        // the 2/3 per-column success bound with >= 5 isolated rows
        for z in 2..=7u64 {
            let p = isolated_success_probability(z, 5);
            assert!(p > 0.60, "F̂({z},5) = {p:.3}");
        }
    }

    #[test]
    fn monte_carlo_agrees_with_recurrence() {
        for z in [2u64, 3, 5, 7] {
            let analytic = success_probability(z, 10);
            let mc = monte_carlo_success(z, 10, 40_000, 99);
            assert!(
                (analytic - mc).abs() < 0.02,
                "z={z}: recurrence {analytic:.3} vs MC {mc:.3}"
            );
        }
    }
}
