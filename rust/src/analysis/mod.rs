//! Analytical + measurement substrates backing the paper's evaluation:
//! RAM-bandwidth probes (the objective ingestion standard), the
//! CameoSketch success-probability recurrence (Table 6), the dataset
//! survey synthesizer (Fig. 1/15), and the measured-cost cluster scaling
//! model (Fig. 3 on a single-core container).

pub mod cluster_model;
pub mod rambw;
pub mod success_prob;
pub mod survey;
