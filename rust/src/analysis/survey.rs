//! Dataset-survey synthesizer (Fig. 1 / Fig. 15): the selection-effect
//! scatter of public graph datasets.
//!
//! The paper plots every NetworkRepository + SuiteSparse dataset as
//! (vertex count, density) and observes that essentially all of them fit
//! in 16 GB as adjacency lists — evidence of a tooling-driven selection
//! effect.  Those catalogs are unreachable offline, so we *synthesize* a
//! catalog with the documented qualitative structure (per-category
//! vertex-count ranges and density laws, truncated at the 16 GB
//! frontier with a handful of outliers) and emit the same scatter series
//! plus the frontier line.  See DESIGN.md "Substitutions".

use crate::util::rng::Xoshiro256;

/// One synthesized catalog entry.
#[derive(Clone, Debug)]
pub struct DatasetPoint {
    pub category: &'static str,
    pub vertices: f64,
    pub edges: f64,
}

impl DatasetPoint {
    /// Fraction of possible edges.
    pub fn density(&self) -> f64 {
        let pairs = self.vertices * (self.vertices - 1.0) / 2.0;
        (self.edges / pairs).min(1.0)
    }

    /// Adjacency-list bytes: ~16 B per directed edge entry + vertex array.
    pub fn adjacency_list_bytes(&self) -> f64 {
        self.vertices * 8.0 + self.edges * 2.0 * 8.0
    }
}

/// Category-conditional generators fit to the survey's description.
struct Category {
    name: &'static str,
    count: usize,
    /// log10 vertex-count range
    log_v: (f64, f64),
    /// average-degree law: degree ≈ c·V^gamma (gamma < 1 ⇒ sparser
    /// with scale — the selection effect's signature)
    degree_c: f64,
    degree_gamma: f64,
}

const CATEGORIES: [Category; 5] = [
    Category { name: "biological", count: 600, log_v: (2.0, 6.5), degree_c: 8.0, degree_gamma: 0.12 },
    Category { name: "social", count: 900, log_v: (3.0, 7.8), degree_c: 12.0, degree_gamma: 0.10 },
    Category { name: "web", count: 500, log_v: (4.0, 8.0), degree_c: 10.0, degree_gamma: 0.15 },
    Category { name: "road", count: 400, log_v: (3.5, 7.5), degree_c: 2.5, degree_gamma: 0.02 },
    Category { name: "misc", count: 600, log_v: (2.0, 7.0), degree_c: 6.0, degree_gamma: 0.12 },
];

/// The 16 GB adjacency-list frontier of Fig. 1.
pub const FRONTIER_BYTES: f64 = 16.0 * 1024.0 * 1024.0 * 1024.0;

/// Synthesize the catalog.
pub fn synthesize_catalog(seed: u64) -> Vec<DatasetPoint> {
    let mut rng = Xoshiro256::new(seed);
    let mut out = Vec::new();
    for cat in &CATEGORIES {
        for _ in 0..cat.count {
            let log_v = cat.log_v.0 + rng.next_f64() * (cat.log_v.1 - cat.log_v.0);
            let v = 10f64.powf(log_v);
            // degree law with lognormal-ish noise
            let noise = 2f64.powf(rng.next_f64() * 3.0 - 1.5);
            let degree = cat.degree_c * v.powf(cat.degree_gamma) * noise;
            let edges = (v * degree / 2.0).max(1.0);
            let mut p = DatasetPoint {
                category: cat.name,
                vertices: v,
                edges,
            };
            // the selection effect: datasets over the frontier are
            // resampled down (they "don't get published"), except a few
            // survivors (~0.5%) that mirror the catalogs' rare giants
            if p.adjacency_list_bytes() > FRONTIER_BYTES && !rng.next_bool(0.005) {
                let scale = FRONTIER_BYTES / p.adjacency_list_bytes() * rng.next_f64();
                p.edges = (p.edges * scale).max(1.0);
            }
            out.push(p);
        }
    }
    out
}

/// Summary statistics for EXPERIMENTS.md.
pub struct SurveySummary {
    pub total: usize,
    pub under_frontier: usize,
    pub max_adj_bytes: f64,
}

pub fn summarize(points: &[DatasetPoint]) -> SurveySummary {
    let under = points
        .iter()
        .filter(|p| p.adjacency_list_bytes() <= FRONTIER_BYTES)
        .count();
    SurveySummary {
        total: points.len(),
        under_frontier: under,
        max_adj_bytes: points
            .iter()
            .map(|p| p.adjacency_list_bytes())
            .fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_all_categories() {
        let cat = synthesize_catalog(1);
        assert_eq!(cat.len(), 3000);
        for want in ["biological", "social", "web", "road", "misc"] {
            assert!(cat.iter().any(|p| p.category == want));
        }
    }

    #[test]
    fn selection_effect_holds() {
        // Fig. 1's observation: ~all datasets under the 16 GB frontier
        let cat = synthesize_catalog(2);
        let s = summarize(&cat);
        let frac = s.under_frontier as f64 / s.total as f64;
        assert!(frac > 0.98, "under-frontier fraction {frac}");
        assert!(frac < 1.0, "a few giants should survive");
    }

    #[test]
    fn density_decreases_with_scale() {
        // Fig. 15: larger graphs are sparser in the published record
        let cat = synthesize_catalog(3);
        let small_avg: f64 = {
            let xs: Vec<f64> = cat
                .iter()
                .filter(|p| p.vertices < 1e4)
                .map(|p| p.density())
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let large_avg: f64 = {
            let xs: Vec<f64> = cat
                .iter()
                .filter(|p| p.vertices > 1e6)
                .map(|p| p.density())
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            small_avg > 10.0 * large_avg,
            "small {small_avg:.2e} vs large {large_avg:.2e}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synthesize_catalog(7);
        let b = synthesize_catalog(7);
        assert_eq!(a.len(), b.len());
        assert!((a[0].edges - b[0].edges).abs() < 1e-9);
    }
}
