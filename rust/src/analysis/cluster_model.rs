//! Cluster scaling model (Fig. 3 / Fig. 4's x-axis).
//!
//! The paper measures ingestion rate against 1–40 sixteen-thread worker
//! nodes on AWS.  This container has **one core**, so wall-clock
//! multi-worker scaling cannot be observed directly; instead we measure
//! the real single-thread cost of every pipeline stage (hypertree data
//! movement, worker delta computation, main-node merge) and evaluate the
//! standard pipeline-throughput model
//!
//! ```text
//! rate(W) = 1 / max( main-node seconds/update,
//!                    worker seconds/update / (W · threads) )
//! ```
//!
//! which is exactly the claim structure of §5: worker cost is
//! distributed away (denominator W·t), main-node cost is not — so the
//! curve rises near-linearly until the main-node bound, reproducing
//! Fig. 3's shape.  All inputs are *measured* on this machine, not
//! assumed.  See DESIGN.md "Substitutions".

use std::sync::Arc;

use crate::hypertree::{BatchSink, Hypertree, HypertreeConfig, VertexBatch};
use crate::metrics::Metrics;
use crate::sketch::params::{encode_edge, SketchParams};
use crate::sketch::{CameoSketch, CubeSketch, SketchStore};
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use crate::worker::WorkerSeeds;

/// Measured per-stage costs (seconds per update unless noted).
#[derive(Clone, Copy, Debug)]
pub struct StageCosts {
    /// Main node: hypertree insert + amortized batch packaging, per
    /// stream update (each update is two hypertree entries).
    pub main_per_update: f64,
    /// Main node: delta XOR-merge, per stream update (amortized).
    pub merge_per_update: f64,
    /// Worker: sketch-delta computation, per stream update.
    pub worker_per_update: f64,
    /// Updates per vertex-based batch (for reporting).
    pub updates_per_batch: f64,
}

/// A sink that counts batches but drops them (isolates buffering cost).
struct NullSink;
impl BatchSink for NullSink {
    fn full_batch(&self, _shard: usize, _b: VertexBatch) {}
    fn local_batch(&self, _shard: usize, _v: u32, _o: &[u32]) {}
}

/// Which sketch kernel the "worker" stage uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Cameo,
    Cube,
}

/// Which buffering structure the "main" stage uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferingKind {
    Hypertree,
    Gutter,
}

/// Measure all stage costs for a V-vertex graph with `samples` updates.
pub fn measure_stage_costs(
    v: u64,
    samples: usize,
    kernel: KernelKind,
    buffering: BufferingKind,
) -> StageCosts {
    let params = SketchParams::for_vertices(v);
    let seeds = WorkerSeeds::derive(params, 0xFEED, 1);
    let mut rng = Xoshiro256::new(7);

    // pre-generate a random update workload
    let updates: Vec<(u32, u32)> = (0..samples)
        .map(|_| {
            let a = rng.next_below(v - 1) as u32;
            let b = a + 1 + (rng.next_below(v - 1 - a as u64)) as u32;
            (a, b)
        })
        .collect();

    // --- main-node buffering cost ---
    let main_per_update = match buffering {
        BufferingKind::Hypertree => {
            let tree = Arc::new(Hypertree::new(
                HypertreeConfig::for_vertices(v, params.batch_capacity(2)),
                Arc::new(Metrics::new()),
            ));
            let mut local = tree.local();
            let sink = NullSink;
            let sw = Stopwatch::new();
            for &(a, b) in &updates {
                local.insert(a, b, &sink);
                local.insert(b, a, &sink);
            }
            local.flush(&sink);
            sw.elapsed_secs() / samples as f64
        }
        BufferingKind::Gutter => {
            let g = crate::gutter::GutterBuffer::new(
                v,
                params.batch_capacity(2),
                crate::sketch::shard::ShardSpec::new(64),
                Arc::new(Metrics::new()),
            );
            let sink = NullSink;
            let sw = Stopwatch::new();
            for &(a, b) in &updates {
                g.insert(a, b, &sink);
                g.insert(b, a, &sink);
            }
            sw.elapsed_secs() / samples as f64
        }
    };

    // --- worker delta cost (per update; each update appears in 2
    // batches, so worker work per stream update is 2x per-entry cost) ---
    let batch: Vec<u64> = updates
        .iter()
        .map(|&(a, b)| encode_edge(a, b, v))
        .collect();
    let sw = Stopwatch::new();
    let delta = match kernel {
        KernelKind::Cameo => CameoSketch::delta_of_batch(&params, &seeds.per_copy[0], &batch),
        KernelKind::Cube => CubeSketch::delta_of_batch(&params, &seeds.per_copy[0], &batch),
    };
    let worker_per_update = 2.0 * sw.elapsed_secs() / samples as f64;

    // --- merge cost (per update, amortized over a batch) ---
    let store = SketchStore::new(params, 0xFEED);
    let batch_cap = params.batch_capacity(2) as f64;
    let merges = 64;
    let sw = Stopwatch::new();
    for _ in 0..merges {
        store.merge_delta(0, &delta);
    }
    // one merge per batch of `batch_cap` updates, two batches per update
    let merge_per_update = 2.0 * (sw.elapsed_secs() / merges as f64) / batch_cap;

    StageCosts {
        main_per_update,
        merge_per_update,
        worker_per_update,
        updates_per_batch: batch_cap,
    }
}

impl StageCosts {
    /// Predicted ingestion rate (updates/sec) with `workers` nodes of
    /// `threads` worker threads each, and `main_threads` ingest threads
    /// on the main node (the paper's main node is a 36-core c5n; the
    /// hypertree's thread-local levels parallelize ingestion).
    pub fn predict_rate_full(&self, workers: u32, threads: u32, main_threads: u32) -> f64 {
        let main =
            self.main_per_update / main_threads.max(1) as f64 + self.merge_per_update;
        let distributed = self.worker_per_update / (workers as f64 * threads as f64);
        1.0 / main.max(distributed)
    }

    /// Single-ingest-thread variant (this container's real topology).
    pub fn predict_rate(&self, workers: u32, threads: u32) -> f64 {
        self.predict_rate_full(workers, threads, 1)
    }

    /// Worker count at which the main node becomes the bottleneck.
    pub fn saturation_workers_full(&self, threads: u32, main_threads: u32) -> u32 {
        let main =
            self.main_per_update / main_threads.max(1) as f64 + self.merge_per_update;
        (self.worker_per_update / (main * threads as f64)).ceil() as u32
    }

    /// Single-ingest-thread variant.
    pub fn saturation_workers(&self, threads: u32) -> u32 {
        self.saturation_workers_full(threads, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> StageCosts {
        measure_stage_costs(1 << 10, 40_000, KernelKind::Cameo, BufferingKind::Hypertree)
    }

    #[test]
    fn stage_costs_are_positive_and_ordered() {
        let c = costs();
        assert!(c.main_per_update > 0.0);
        assert!(c.worker_per_update > 0.0);
        assert!(c.merge_per_update >= 0.0);
        // the whole premise: worker (hashing) cost dominates main-node
        // (data movement) cost per update
        assert!(
            c.worker_per_update > 2.0 * c.main_per_update,
            "worker {:.1}ns vs main {:.1}ns",
            c.worker_per_update * 1e9,
            c.main_per_update * 1e9
        );
    }

    #[test]
    fn scaling_curve_shape_matches_fig3() {
        let c = costs();
        let r1 = c.predict_rate(1, 16);
        let r10 = c.predict_rate(10, 16);
        let r40 = c.predict_rate(40, 16);
        let r400 = c.predict_rate(400, 16);
        assert!(r10 > 2.0 * r1 || r10 == r40, "near-linear early scaling");
        assert!(r40 >= r10);
        // saturation: beyond the main-node bound more workers don't help
        assert!(r400 <= r40 * 1.01);
    }

    #[test]
    fn cube_kernel_costs_more_than_cameo() {
        let cameo = measure_stage_costs(1 << 10, 30_000, KernelKind::Cameo, BufferingKind::Hypertree);
        let cube = measure_stage_costs(1 << 10, 30_000, KernelKind::Cube, BufferingKind::Hypertree);
        assert!(
            cube.worker_per_update > cameo.worker_per_update,
            "cube {:.1}ns <= cameo {:.1}ns",
            cube.worker_per_update * 1e9,
            cameo.worker_per_update * 1e9
        );
    }

    #[test]
    fn saturation_point_is_finite() {
        let c = costs();
        let sat = c.saturation_workers(16);
        assert!(sat >= 1 && sat < 10_000);
    }
}
