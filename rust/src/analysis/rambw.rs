//! RAM bandwidth measurement — the paper's objective performance
//! standard (§1.1, §7.2).
//!
//! *Sequential* write bandwidth bounds any stream-processing system (the
//! data-acquisition cost: the input must at least be written to memory);
//! *random-access* write bandwidth is what an adjacency-matrix bit-flip
//! pays.  Landscape's headline claim is ingestion within 4× of the
//! former and faster than the latter.

use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;

/// Result of one bandwidth probe.
#[derive(Clone, Copy, Debug)]
pub struct Bandwidth {
    pub bytes: u64,
    pub seconds: f64,
}

impl Bandwidth {
    pub fn gib_per_sec(&self) -> f64 {
        self.bytes as f64 / self.seconds.max(1e-12) / (1u64 << 30) as f64
    }

    /// Equivalent 9-byte-update ingestion rate (updates/sec).
    pub fn updates_per_sec(&self) -> f64 {
        self.bytes as f64 / 9.0 / self.seconds.max(1e-12)
    }
}

/// Sequential write bandwidth: stream 8-byte words through `buf_words`
/// of memory `passes` times.
pub fn sequential_write(buf_words: usize, passes: usize) -> Bandwidth {
    let mut buf = vec![0u64; buf_words];
    let sw = Stopwatch::new();
    let mut x = 0x9E3779B97F4A7C15u64;
    for _ in 0..passes {
        for w in buf.iter_mut() {
            *w = x;
            x = x.wrapping_add(0x9E3779B97F4A7C15);
        }
    }
    let secs = sw.elapsed_secs();
    std::hint::black_box(&buf);
    Bandwidth {
        bytes: (buf_words * 8 * passes) as u64,
        seconds: secs,
    }
}

/// Random-access write bandwidth: `writes` single-word writes at
/// pseudo-random offsets in a buffer big enough to defeat caches.
pub fn random_write(buf_words: usize, writes: usize) -> Bandwidth {
    let mut buf = vec![0u64; buf_words];
    let mut rng = Xoshiro256::new(42);
    // pre-generate offsets so RNG cost stays out of the timed loop
    let offsets: Vec<usize> = (0..writes)
        .map(|_| rng.next_below(buf_words as u64) as usize)
        .collect();
    let sw = Stopwatch::new();
    for (i, &o) in offsets.iter().enumerate() {
        buf[o] = i as u64;
    }
    let secs = sw.elapsed_secs();
    std::hint::black_box(&buf);
    Bandwidth {
        bytes: (writes * 8) as u64,
        seconds: secs,
    }
}

/// Default probe sizes: 64 MiB buffer (past L3 on any machine here).
pub fn measure_defaults() -> (Bandwidth, Bandwidth) {
    let words = (64usize << 20) / 8;
    (sequential_write(words, 4), random_write(words, 4 << 20))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_beats_random() {
        // the fundamental asymmetry the paper's comparison rests on
        let words = (16usize << 20) / 8;
        let seq = sequential_write(words, 2);
        let rnd = random_write(words, 1 << 20);
        assert!(
            seq.gib_per_sec() > rnd.gib_per_sec(),
            "seq {:.2} GiB/s vs random {:.2} GiB/s",
            seq.gib_per_sec(),
            rnd.gib_per_sec()
        );
    }

    #[test]
    fn rates_are_positive_and_sane() {
        let b = sequential_write(1 << 20, 1);
        assert!(b.gib_per_sec() > 0.05, "{} GiB/s", b.gib_per_sec());
        assert!(b.updates_per_sec() > 1e6);
    }
}
