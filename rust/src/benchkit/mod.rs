//! Minimal benchmarking harness.
//!
//! The offline environment vendors no criterion, so the bench targets
//! (`benches/*.rs`, `harness = false`) use this instead: warmup +
//! repeated timed runs with median/mean/min/stddev, plus an aligned
//! table printer and CSV emission for the figure/table harnesses.

use crate::util::timer::Stopwatch;

/// Summary statistics over per-iteration seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            stddev: var.sqrt(),
        }
    }

    /// ops/sec at the median.
    pub fn rate(&self, ops_per_iter: u64) -> f64 {
        ops_per_iter as f64 / self.median.max(1e-12)
    }
}

/// Run `f` for `warmup` + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let sw = Stopwatch::new();
        f();
        samples.push(sw.elapsed_secs());
    }
    Stats::from_samples(samples)
}

/// Human-friendly rate formatting (e.g. "12.3 M/s").
pub fn fmt_rate(rate: f64) -> String {
    if rate >= 1e9 {
        format!("{:.2} G/s", rate / 1e9)
    } else if rate >= 1e6 {
        format!("{:.2} M/s", rate / 1e6)
    } else if rate >= 1e3 {
        format!("{:.2} K/s", rate / 1e3)
    } else {
        format!("{rate:.2} /s")
    }
}

/// Human-friendly byte formatting.
pub fn fmt_bytes(bytes: f64) -> String {
    if bytes >= (1u64 << 30) as f64 {
        format!("{:.2} GiB", bytes / (1u64 << 30) as f64)
    } else if bytes >= (1u64 << 20) as f64 {
        format!("{:.2} MiB", bytes / (1u64 << 20) as f64)
    } else if bytes >= 1024.0 {
        format!("{:.2} KiB", bytes / 1024.0)
    } else {
        format!("{bytes:.0} B")
    }
}

/// Command-line options shared by the bench targets (`harness = false`
/// binaries see their own argv): `--json PATH` additionally writes the
/// results table as JSON — the committed-trajectory format that
/// `tools/bench_compare` diffs against `BENCH_micro.json` (see
/// docs/PERFORMANCE.md) — and `--quick` shrinks inputs and iteration
/// counts for the CI bench-smoke job.
#[derive(Clone, Debug, Default)]
pub struct BenchArgs {
    /// Write the results table as JSON to this path after the run.
    pub json: Option<std::path::PathBuf>,
    /// CI smoke mode: fewer warmup/timed iterations, smaller inputs.
    pub quick: bool,
}

impl BenchArgs {
    /// Parse `std::env::args()`.  Unrecognized arguments are ignored so
    /// the flags coexist with whatever cargo's bench harness forwards.
    pub fn parse() -> Self {
        let mut out = Self::default();
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--json" => out.json = args.next().map(std::path::PathBuf::from),
                "--quick" => out.quick = true,
                _ => {}
            }
        }
        out
    }

    /// `(warmup, iters)` for full runs; quick mode drops warmup and caps
    /// timed iterations at 2 so the smoke job finishes in seconds.
    pub fn scale(&self, warmup: usize, iters: usize) -> (usize, usize) {
        if self.quick {
            (0, iters.min(2))
        } else {
            (warmup, iters)
        }
    }
}

/// An aligned results table that also serializes to CSV — every bench
/// target prints one of these so table regeneration is copy-pasteable.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Aligned text rendering (stderr-friendly).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (stdout-friendly; the figure data format).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// JSON rendering: `{"title", "headers", "rows": [{header: cell}]}`.
    /// Cells stay the same strings as the CSV — consumers parse numeric
    /// columns themselves (`tools/bench_compare` reads `ns_per_op`), so
    /// adding a column never breaks the committed-baseline diff.
    pub fn to_json(&self) -> String {
        let esc = crate::util::json::escape;
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"title\": \"{}\",\n", esc(&self.title)));
        out.push_str("  \"headers\": [");
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| format!("\"{}\"", esc(h)))
                .collect::<Vec<_>>()
                .join(", "),
        );
        out.push_str("],\n  \"rows\": [\n");
        for (i, row) in self.rows.iter().enumerate() {
            let fields = self
                .headers
                .iter()
                .zip(row)
                .map(|(h, c)| format!("\"{}\": \"{}\"", esc(h), esc(c)))
                .collect::<Vec<_>>()
                .join(", ");
            let sep = if i + 1 < self.rows.len() { "," } else { "" };
            out.push_str(&format!("    {{{fields}}}{sep}\n"));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write [`Table::to_json`] to `path`, creating parent directories.
    pub fn emit_json(&self, path: &std::path::Path) {
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Err(e) = std::fs::write(path, self.to_json()) {
            crate::log_warn!("benchkit: writing {} failed: {e}", path.display());
        }
    }

    /// Print text to stderr, CSV to stdout, and optionally save CSV.
    pub fn emit(&self, csv_path: Option<&std::path::Path>) {
        crate::log_info!("{}", self.render());
        println!("{}", self.to_csv());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            let _ = std::fs::write(p, self.to_csv());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![0.2, 0.1, 0.3]);
        assert_eq!(s.iters, 3);
        assert!((s.median - 0.2).abs() < 1e-12);
        assert!((s.min - 0.1).abs() < 1e-12);
        assert!((s.mean - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_round_trips_csv() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["2".into(), "y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,x\n2,y\n");
        assert!(t.render().contains("t"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_rate(2_500_000.0), "2.50 M/s");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn table_json_round_trips_through_the_parser() {
        let mut t = Table::new("micro", &["path", "ns_per_op"]);
        t.row(vec!["merge \"q\"".into(), "1.5".into()]);
        t.row(vec!["cameo".into(), "2.0".into()]);
        let parsed = crate::util::json::Json::parse(&t.to_json()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str().unwrap(), "micro");
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("path").unwrap().as_str().unwrap(),
            "merge \"q\""
        );
        assert_eq!(rows[1].get("ns_per_op").unwrap().as_str().unwrap(), "2.0");
    }

    #[test]
    fn quick_mode_caps_iterations() {
        let full = BenchArgs::default();
        assert_eq!(full.scale(3, 20), (3, 20));
        let quick = BenchArgs {
            quick: true,
            ..Default::default()
        };
        assert_eq!(quick.scale(3, 20), (0, 2));
        assert_eq!(quick.scale(3, 1), (0, 1));
    }
}
