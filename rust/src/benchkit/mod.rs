//! Minimal benchmarking harness.
//!
//! The offline environment vendors no criterion, so the bench targets
//! (`benches/*.rs`, `harness = false`) use this instead: warmup +
//! repeated timed runs with median/mean/min/stddev, plus an aligned
//! table printer and CSV emission for the figure/table harnesses.

use crate::util::timer::Stopwatch;

/// Summary statistics over per-iteration seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            stddev: var.sqrt(),
        }
    }

    /// ops/sec at the median.
    pub fn rate(&self, ops_per_iter: u64) -> f64 {
        ops_per_iter as f64 / self.median.max(1e-12)
    }
}

/// Run `f` for `warmup` + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let sw = Stopwatch::new();
        f();
        samples.push(sw.elapsed_secs());
    }
    Stats::from_samples(samples)
}

/// Human-friendly rate formatting (e.g. "12.3 M/s").
pub fn fmt_rate(rate: f64) -> String {
    if rate >= 1e9 {
        format!("{:.2} G/s", rate / 1e9)
    } else if rate >= 1e6 {
        format!("{:.2} M/s", rate / 1e6)
    } else if rate >= 1e3 {
        format!("{:.2} K/s", rate / 1e3)
    } else {
        format!("{rate:.2} /s")
    }
}

/// Human-friendly byte formatting.
pub fn fmt_bytes(bytes: f64) -> String {
    if bytes >= (1u64 << 30) as f64 {
        format!("{:.2} GiB", bytes / (1u64 << 30) as f64)
    } else if bytes >= (1u64 << 20) as f64 {
        format!("{:.2} MiB", bytes / (1u64 << 20) as f64)
    } else if bytes >= 1024.0 {
        format!("{:.2} KiB", bytes / 1024.0)
    } else {
        format!("{bytes:.0} B")
    }
}

/// An aligned results table that also serializes to CSV — every bench
/// target prints one of these so table regeneration is copy-pasteable.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Aligned text rendering (stderr-friendly).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (stdout-friendly; the figure data format).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Print text to stderr, CSV to stdout, and optionally save CSV.
    pub fn emit(&self, csv_path: Option<&std::path::Path>) {
        crate::log_info!("{}", self.render());
        println!("{}", self.to_csv());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            let _ = std::fs::write(p, self.to_csv());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![0.2, 0.1, 0.3]);
        assert_eq!(s.iters, 3);
        assert!((s.median - 0.2).abs() < 1e-12);
        assert!((s.min - 0.1).abs() < 1e-12);
        assert!((s.mean - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_round_trips_csv() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["2".into(), "y".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,x\n2,y\n");
        assert!(t.render().contains("t"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_rate(2_500_000.0), "2.50 M/s");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }
}
