//! Remote workers over TCP: the server loop run by `landscape worker`,
//! and the coordinator-side client backends.
//!
//! Workers are stateless (paper §6): the HELLO handshake carries the
//! graph config, after which the server answers batch frames with delta
//! frames computed by a [`NativeWorker`].  One connection serves one
//! coordinator distributor thread; a server accepts many connections.
//!
//! Two client backends speak the `net` protocol:
//!
//! * [`RemoteWorker`] — lockstep v1: one BATCH in flight, the caller
//!   blocks on every round trip.  Kept as the latency-coupled baseline
//!   the pipelined path is measured against.
//! * [`PipelinedRemote`] — v2: the connection is split into a writer
//!   half (owned by the submitting thread) and a reader thread; up to
//!   `window` sequence-tagged batches ride the wire at once, bursts are
//!   coalesced into MULTIBATCH frames, and DELTA2 completions are
//!   consumed **out of order**.  On connection death every
//!   unacknowledged batch is recoverable for requeueing to a surviving
//!   worker ([`crate::worker::SubmitBackend::take_unacked`]).

use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::TenantId;
use crate::net::{
    delta2_wire_bytes, encode_batch2_into, encode_multibatch_header_into, encode_seq_batch_into,
    encode_tbatch2_into, exact_delta2_wire_bytes, tdelta2_wire_bytes, Message,
};
use crate::sketch::params::SketchParams;
use crate::worker::{
    Completion, DeltaFlavor, NativeWorker, PendingBatch, SubmitBackend, WorkerBackend, WorkerSeeds,
};

/// Coordinator-side backend that forwards batches to a remote worker,
/// one blocking round trip at a time (protocol v1).
pub struct RemoteWorker {
    conn: Mutex<RemoteConn>,
    /// Bytes sent/received over this connection (metered at the framing
    /// layer; feeds the Theorem 5.2 validation).
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
}

struct RemoteConn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl RemoteWorker {
    /// Connect and perform the HELLO handshake.
    pub fn connect(
        addr: &str,
        params: SketchParams,
        graph_seed: u64,
        k: u32,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        let hello = Message::Hello {
            vertices: params.v,
            columns: params.columns,
            graph_seed,
            k,
            // the lockstep v1 baseline never negotiates the hybrid tier
            threshold: 0,
        };
        let sent = hello.write_to(&mut writer)?;
        let worker = Self {
            conn: Mutex::new(RemoteConn { reader, writer }),
            bytes_sent: AtomicU64::new(sent),
            bytes_received: AtomicU64::new(0),
        };
        Ok(worker)
    }

    /// Politely shut the connection down.
    pub fn shutdown(&self) {
        if let Ok(mut conn) = self.conn.lock() {
            let _ = Message::Shutdown.write_to(&mut conn.writer);
        }
    }
}

impl WorkerBackend for RemoteWorker {
    fn process(&self, vertex: u32, others: &[u32], out: &mut Vec<u64>) -> Result<()> {
        let mut conn = self.conn.lock().unwrap();
        let batch = Message::Batch {
            vertex,
            others: others.to_vec(),
        };
        let sent = batch.write_to(&mut conn.writer)?;
        // lint: allow(relaxed-ordering) — wire-byte meter (Theorem 5.2 accounting), no synchronization role
        self.bytes_sent.fetch_add(sent, Ordering::Relaxed);
        match Message::read_from(&mut conn.reader)? {
            Message::Delta {
                vertex: rv,
                delta,
            } => {
                if rv != vertex {
                    bail!("delta for wrong vertex: sent {vertex}, got {rv}");
                }
                let wire = Message::Delta {
                    vertex: rv,
                    delta: Vec::new(),
                }
                .wire_bytes()
                    + delta.len() as u64 * 8;
                // lint: allow(relaxed-ordering) — wire-byte meter (Theorem 5.2 accounting), no synchronization role
                self.bytes_received.fetch_add(wire, Ordering::Relaxed);
                out.extend_from_slice(&delta);
                Ok(())
            }
            other => Err(anyhow!("expected DELTA, got {other:?}")),
        }
    }

    fn name(&self) -> &'static str {
        "remote-tcp"
    }
}

/// Reader-thread / writer-half shared state of a [`PipelinedRemote`].
struct PipeShared {
    state: Mutex<PipeState>,
    cv: Condvar,
    dead: AtomicBool,
    bytes_received: AtomicU64,
}

#[derive(Default)]
struct PipeState {
    /// On the wire, unacknowledged: seq → the batch, for requeueing.
    pending: HashMap<u64, PendingBatch>,
    /// Deltas received but not yet drained by the owner.
    completed: VecDeque<Completion>,
    /// The server acknowledged our SHUTDOWN with BYE.
    saw_bye: bool,
}

impl PipeShared {
    fn mark_dead(&self) {
        self.dead.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }
}

/// Pipelined v2 client: a window of sequence-tagged batches in flight,
/// out-of-order DELTA2 completion, MULTIBATCH coalescing, and exact
/// framing-layer byte accounting.
pub struct PipelinedRemote {
    shared: Arc<PipeShared>,
    writer: BufWriter<TcpStream>,
    /// Raw handle used to break the reader out of a blocking read.
    sock: TcpStream,
    /// Submitted but not yet framed onto the wire (coalescing buffer).
    write_buf: Vec<PendingBatch>,
    /// Reusable scatter buffer: each flush pre-serializes the whole
    /// BATCH2/MULTIBATCH frame here from *borrowed* batches, so frame
    /// assembly never clones a payload and the wire sees one write.
    frame_buf: Vec<u8>,
    window: usize,
    bytes_sent: u64,
    /// Tenant-tagged wire mode: frame every batch as a standalone
    /// TBATCH2 (never MULTIBATCH-coalesced) so each frame's bytes are
    /// attributable to exactly one tenant — the per-tenant Theorem 5.2
    /// meter sums `tbatch2_wire_bytes` per submitted batch and must
    /// reconcile exactly against the framing layer.
    tagged: bool,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl PipelinedRemote {
    /// Connect, perform the HELLO handshake, and start the reader half.
    /// `window` is the maximum number of batches in flight (≥ 1).
    pub fn connect(
        addr: &str,
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        window: usize,
    ) -> Result<Self> {
        Self::connect_hybrid(addr, params, graph_seed, k, window, 0)
    }

    /// Like [`Self::connect`], negotiating the hybrid vertex tier: the
    /// HELLO carries `threshold`, and the server answers batches whose
    /// parity-reduced survivor count is at most `threshold` with compact
    /// EXACTDELTA2 frames instead of full sketch deltas (0 disables).
    pub fn connect_hybrid(
        addr: &str,
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        window: usize,
        threshold: u32,
    ) -> Result<Self> {
        Self::connect_inner(addr, params, graph_seed, k, window, threshold, false)
    }

    /// Like [`Self::connect`], but in **tenant-tagged** wire mode for the
    /// multi-tenant fabric: every batch goes out as a standalone TBATCH2
    /// frame carrying its tenant id, and the server echoes the id on each
    /// TDELTA2 reply.  Tagged mode never coalesces into MULTIBATCH — a
    /// shared frame's bytes would not be attributable to one tenant — and
    /// never negotiates the hybrid tier (the fabric is sketch-only).
    pub fn connect_tagged(
        addr: &str,
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        window: usize,
    ) -> Result<Self> {
        Self::connect_inner(addr, params, graph_seed, k, window, 0, true)
    }

    fn connect_inner(
        addr: &str,
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        window: usize,
        threshold: u32,
        tagged: bool,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader_stream = stream.try_clone()?;
        let sock = stream.try_clone()?;
        let mut writer = BufWriter::new(stream);
        let hello = Message::Hello {
            vertices: params.v,
            columns: params.columns,
            graph_seed,
            k,
            threshold,
        };
        let bytes_sent = hello.write_to(&mut writer)?;
        let shared = Arc::new(PipeShared {
            state: Mutex::new(PipeState::default()),
            cv: Condvar::new(),
            dead: AtomicBool::new(false),
            bytes_received: AtomicU64::new(0),
        });
        let shared2 = shared.clone();
        let reader = std::thread::spawn(move || {
            reader_loop(&shared2, BufReader::new(reader_stream));
        });
        Ok(Self {
            shared,
            writer,
            sock,
            write_buf: Vec::new(),
            frame_buf: Vec::new(),
            window: window.max(1),
            bytes_sent,
            tagged,
            reader: Some(reader),
        })
    }

    /// Exact bytes written at the framing layer (HELLO + batch frames +
    /// SHUTDOWN).
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// Exact bytes received at the framing layer (DELTA2 frames + BYE).
    pub fn bytes_received(&self) -> u64 {
        // lint: allow(relaxed-ordering) — wire-byte meter read; reconciled exactly at shutdown, stale reads fine
        self.shared.bytes_received.load(Ordering::Relaxed)
    }

    /// Configured in-flight window.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Batches occupying the window: buffered + on the wire.
    fn window_occupancy(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        self.write_buf.len() + st.pending.len()
    }

    /// Wait (bounded) for the reader to make progress: a completion
    /// arriving, BYE, or death.
    fn wait_for_progress(&self) -> Result<()> {
        if self.shared.is_dead() {
            bail!("remote worker connection is dead");
        }
        let st = self.shared.state.lock().unwrap();
        if st.pending.is_empty() {
            return Ok(());
        }
        let _ = self
            .shared
            .cv
            .wait_timeout(st, Duration::from_millis(50))
            .unwrap();
        if self.shared.is_dead() {
            bail!("remote worker connection is dead");
        }
        Ok(())
    }

    fn join_reader(&mut self) {
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

impl SubmitBackend for PipelinedRemote {
    fn submit(&mut self, batch: PendingBatch) -> Result<()> {
        // backpressure: never let more than `window` batches occupy the
        // buffer + wire.  The reader thread frees window slots as DELTA2
        // frames arrive, independent of this thread, so waiting here
        // cannot deadlock.
        while self.window_occupancy() >= self.window {
            if let Err(e) = self.flush_submits().and_then(|()| self.wait_for_progress()) {
                // retain the batch so take_unacked() can requeue it
                self.write_buf.push(batch);
                return Err(e);
            }
        }
        if self.shared.is_dead() {
            self.write_buf.push(batch);
            bail!("remote worker connection is dead");
        }
        self.write_buf.push(batch);
        Ok(())
    }

    fn flush_submits(&mut self) -> Result<()> {
        if self.write_buf.is_empty() {
            return Ok(());
        }
        if self.shared.is_dead() {
            bail!("remote worker connection is dead");
        }
        // pre-serialize the whole frame into the reusable scatter buffer
        // from *borrowed* batches — no payload clone, no Message
        // construction, no per-batch re-encoding.  The encoders are
        // byte-identical to the Message framing (asserted in net's
        // `scatter_encoders_match_message_framing`), so the byte meter
        // below stays exact.
        self.frame_buf.clear();
        if self.tagged {
            // one standalone TBATCH2 frame per batch, still assembled
            // into a single scatter buffer → one write.  Per-tenant byte
            // attribution needs per-batch frames; the cost is one tag
            // byte per batch over MULTIBATCH coalescing.
            for b in &self.write_buf {
                encode_tbatch2_into(&mut self.frame_buf, b.tenant, b.token, b.vertex, &b.others);
            }
        } else if self.write_buf.len() == 1 {
            let b = &self.write_buf[0];
            encode_batch2_into(&mut self.frame_buf, b.token, b.vertex, &b.others);
        } else {
            encode_multibatch_header_into(&mut self.frame_buf, self.write_buf.len() as u32);
            for b in &self.write_buf {
                encode_seq_batch_into(&mut self.frame_buf, b.token, b.vertex, &b.others);
            }
        }
        // register as on-the-wire *before* writing: a torn write leaves
        // every batch in the unacknowledged set for requeueing.  The
        // batches move (not clone) into the pending map — the frame was
        // already serialized above.
        {
            let mut st = self.shared.state.lock().unwrap();
            for b in self.write_buf.drain(..) {
                st.pending.insert(b.token, b);
            }
        }
        match self
            .writer
            .write_all(&self.frame_buf)
            .and_then(|()| self.writer.flush())
        {
            Ok(()) => {
                self.bytes_sent += self.frame_buf.len() as u64;
                Ok(())
            }
            Err(e) => {
                self.shared.mark_dead();
                Err(e.into())
            }
        }
    }

    fn drain(&mut self, out: &mut Vec<Completion>, block: bool) -> Result<()> {
        // a blocking drain is about to wait on replies, so everything
        // buffered must reach the wire first (their deltas are what we
        // would be waiting for).  A non-blocking drain leaves the buffer
        // growing so bursts coalesce into MULTIBATCH frames — the window
        // check in submit() bounds how long that lasts.  A flush failure
        // marks the backend dead, which is reported below once
        // already-received completions have been handed out.
        if block && !self.write_buf.is_empty() {
            let _ = self.flush_submits();
        }
        let mut st = self.shared.state.lock().unwrap();
        if block && st.completed.is_empty() && !st.pending.is_empty() && !self.shared.is_dead() {
            let (g, _timeout) = self
                .shared
                .cv
                .wait_timeout(st, Duration::from_millis(1))
                .unwrap();
            st = g;
        }
        let got_any = !st.completed.is_empty();
        out.extend(st.completed.drain(..));
        drop(st);
        if !got_any && self.shared.is_dead() {
            bail!("remote worker connection is dead");
        }
        Ok(())
    }

    fn in_flight(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        self.write_buf.len() + st.pending.len() + st.completed.len()
    }

    fn wire_occupancy(&self) -> usize {
        self.window_occupancy()
    }

    fn wire_bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn dead(&self) -> bool {
        self.shared.is_dead()
    }

    fn take_unacked(&mut self) -> Vec<PendingBatch> {
        let mut unacked: Vec<PendingBatch> = self.write_buf.drain(..).collect();
        {
            let mut st = self.shared.state.lock().unwrap();
            unacked.extend(st.pending.drain().map(|(_, b)| b));
        }
        unacked.sort_by_key(|b| b.token);
        unacked
    }

    fn finish(&mut self) -> Result<()> {
        self.flush_submits()?;
        // drain the wire before the close handshake
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            {
                let st = self.shared.state.lock().unwrap();
                if self.write_buf.len() + st.pending.len() == 0 {
                    break;
                }
                if !self.shared.is_dead() && Instant::now() < deadline {
                    let _ = self
                        .shared
                        .cv
                        .wait_timeout(st, Duration::from_millis(10))
                        .unwrap();
                    continue;
                }
            }
            bail!("connection died or timed out with batches still in flight");
        }
        // SHUTDOWN → BYE close handshake: the BYE proves the server saw
        // and answered everything we sent
        self.bytes_sent += Message::Shutdown.write_to(&mut self.writer)?;
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let st = self.shared.state.lock().unwrap();
            if st.saw_bye || self.shared.is_dead() || Instant::now() >= deadline {
                break;
            }
            let _ = self
                .shared
                .cv
                .wait_timeout(st, Duration::from_millis(10))
                .unwrap();
        }
        // break the reader out of its blocking read before joining —
        // without this a peer that never sends BYE (or a writer-side
        // death the reader hasn't noticed) would hang the join despite
        // the deadline above.  Harmless after a clean BYE: the
        // connection is ending either way.
        let _ = self.sock.shutdown(std::net::Shutdown::Both);
        self.join_reader();
        Ok(())
    }

    fn name(&self) -> &'static str {
        "remote-tcp-pipelined"
    }
}

impl Drop for PipelinedRemote {
    fn drop(&mut self) {
        self.shared.mark_dead();
        let _ = self.sock.shutdown(std::net::Shutdown::Both);
        self.join_reader();
    }
}

/// Match one completion frame against the pending map and publish it.
/// Returns `false` when the frame is unanswerable (wrong vertex, wrong
/// tenant echo, or unknown seq) and the connection must be marked dead.
/// `echo_tenant` is the tenant id a TDELTA2 frame carried (`None` for
/// untagged frames): it must match the submitted batch's tenant, or the
/// reply would be merged into the wrong logical graph.
fn complete_frame(
    shared: &PipeShared,
    seq: u64,
    vertex: u32,
    delta: Vec<u64>,
    wire: u64,
    exact: bool,
    echo_tenant: Option<TenantId>,
) -> bool {
    let mut st = shared.state.lock().unwrap();
    match st.pending.remove(&seq) {
        Some(b) if echo_tenant.is_some_and(|t| t != b.tenant) => {
            crate::log_warn!(
                "remote: delta seq {seq} echoed wrong tenant (sent {}, got {})",
                b.tenant,
                echo_tenant.unwrap_or_default()
            );
            // keep the batch requeueable
            st.pending.insert(seq, b);
            drop(st);
            shared.mark_dead();
            false
        }
        Some(b) if b.vertex == vertex => {
            st.completed.push_back(Completion {
                tenant: b.tenant,
                token: seq,
                ticket: b.ticket,
                vertex,
                delta,
                wire_bytes: wire,
                exact,
                // hand the batch buffer back for arena
                // recycling once the delta merges
                others: b.others,
            });
            drop(st);
            // lint: allow(relaxed-ordering) — wire-byte meter (Theorem 5.2 accounting), no synchronization role
            shared.bytes_received.fetch_add(wire, Ordering::Relaxed);
            shared.cv.notify_all();
            true
        }
        Some(b) => {
            crate::log_warn!(
                "remote: delta seq {seq} for wrong vertex (sent {}, got \
                 {vertex})",
                b.vertex
            );
            // keep the batch requeueable
            st.pending.insert(seq, b);
            drop(st);
            shared.mark_dead();
            false
        }
        None => {
            crate::log_warn!("remote: delta for unknown seq {seq}");
            drop(st);
            shared.mark_dead();
            false
        }
    }
}

/// The reader half: turns DELTA2/EXACTDELTA2 frames into completions
/// until BYE, an error frame, or connection death.
fn reader_loop(shared: &PipeShared, mut reader: BufReader<TcpStream>) {
    loop {
        match Message::read_from(&mut reader) {
            Ok(Message::Delta2 { seq, vertex, delta }) => {
                let wire = delta2_wire_bytes(delta.len());
                if !complete_frame(shared, seq, vertex, delta, wire, false, None) {
                    return;
                }
            }
            Ok(Message::TDelta2 {
                tenant,
                seq,
                vertex,
                delta,
            }) => {
                // tagged completion: the tenant echo is verified against
                // the submitted batch so a confused server can never get
                // a delta merged into the wrong logical graph
                let wire = tdelta2_wire_bytes(delta.len());
                if !complete_frame(shared, seq, vertex, delta, wire, false, Some(tenant)) {
                    return;
                }
            }
            Ok(Message::ExactDelta2 {
                seq,
                vertex,
                indices,
            }) => {
                // cold-vertex completion: `delta` carries raw edge
                // indices, not sketch words (the distributor dispatches
                // on `exact`)
                let wire = exact_delta2_wire_bytes(indices.len());
                if !complete_frame(shared, seq, vertex, indices, wire, true, None) {
                    return;
                }
            }
            Ok(Message::Bye) => {
                let bye = Message::Bye.wire_bytes();
                // lint: allow(relaxed-ordering) — wire-byte meter (Theorem 5.2 accounting), no synchronization role
                shared.bytes_received.fetch_add(bye, Ordering::Relaxed);
                shared.state.lock().unwrap().saw_bye = true;
                shared.cv.notify_all();
                return;
            }
            Ok(Message::Error { code, reason }) => {
                crate::log_warn!("remote: worker reported error {code}: {reason}");
                shared.mark_dead();
                return;
            }
            Ok(other) => {
                crate::log_warn!("remote: unexpected frame {other:?}");
                shared.mark_dead();
                return;
            }
            Err(_) => {
                // connection closed (cleanly after BYE the loop already
                // returned, so this is a death)
                shared.mark_dead();
                return;
            }
        }
    }
}

/// Server-side knobs (latency injection and failure injection are used
/// by benches/tests; production servers run the defaults).
#[derive(Clone, Debug, Default)]
pub struct ServeOptions {
    /// Injected per-frame reply latency: each delta is held this long
    /// before hitting the wire.  Replies are delayed on a dedicated
    /// sender thread, so latency does **not** cap server throughput —
    /// exactly the regime where pipelining beats lockstep.
    pub reply_latency: Duration,
    /// Failure injection: after this many batches have been answered,
    /// the next data frame makes the connection drop abruptly (no BYE),
    /// simulating a worker crash with batches in flight.
    pub fail_after_batches: Option<u64>,
}

/// Worker server: accept connections, answer batches until SHUTDOWN.
pub struct WorkerServer {
    listener: TcpListener,
    opts: ServeOptions,
}

impl WorkerServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Self> {
        Self::bind_with(addr, ServeOptions::default())
    }

    /// Bind with explicit [`ServeOptions`].
    pub fn bind_with(addr: &str, opts: ServeOptions) -> Result<Self> {
        Ok(Self {
            listener: TcpListener::bind(addr)?,
            opts,
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve `max_connections` then return (use `usize::MAX` to run
    /// forever).  Each connection is handled on its own thread; a client
    /// disconnecting mid-stream — or a failed accept — is logged and
    /// served around, never treated as a server error.
    pub fn serve(&self, max_connections: usize) -> Result<()> {
        let mut served = 0;
        let mut accept_failures = 0u32;
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => {
                    accept_failures = 0;
                    s
                }
                // a client vanishing between SYN and accept is transient:
                // log-and-continue.  A *persistently* failing accept (fd
                // exhaustion) must not become a hot error loop, so back
                // off briefly and give up after a bounded run of them.
                Err(e) => {
                    accept_failures += 1;
                    crate::log_warn!("worker: accept failed ({accept_failures} in a row): {e}");
                    if accept_failures >= 64 {
                        for h in handles.drain(..) {
                            let _ = h.join();
                        }
                        return Err(e.into());
                    }
                    // lint: allow(thread-sleep) — accept-failure backoff on the server control path, never on ingest; bounded at 64 tries
                    std::thread::sleep(Duration::from_millis(10));
                    continue;
                }
            };
            // low-latency replies on the server side too — without this
            // the kernel nagles small DELTA frames behind the previous
            // reply's ACK
            if let Err(e) = stream.set_nodelay(true) {
                crate::log_debug!("worker: TCP_NODELAY failed (continuing): {e}");
            }
            let opts = self.opts.clone();
            handles.push(std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream, opts) {
                    crate::log_warn!("worker connection error: {e:#}");
                }
            }));
            served += 1;
            if served >= max_connections {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// A reply frame queued for the sender thread, due no earlier than the
/// attached instant.
type QueuedReply = (Option<Instant>, Message);

fn handle_connection(stream: TcpStream, opts: ServeOptions) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer = BufWriter::new(stream);

    // handshake: first frame must be HELLO.  The negotiated threshold
    // makes this worker answer small parity-reduced batches with
    // EXACTDELTA2 frames (threshold 0 = classic sketch-only behavior).
    let backend: Box<dyn WorkerBackend> = match Message::read_from(&mut reader)? {
        Message::Hello {
            vertices,
            columns,
            graph_seed,
            k,
            threshold,
        } => {
            let params = SketchParams::with_columns(vertices, columns);
            Box::new(NativeWorker::with_threshold(
                WorkerSeeds::derive(params, graph_seed, k),
                threshold,
            ))
        }
        other => bail!("expected HELLO, got {other:?}"),
    };

    // all replies go through a dedicated sender thread so an injected
    // latency delays each frame without serializing computation behind
    // it, and so v2 batch computation never blocks on TCP backpressure
    let (tx, rx) = mpsc::channel::<QueuedReply>();
    let sender = std::thread::spawn(move || sender_loop(writer, rx));
    let due = |latency: Duration| {
        if latency.is_zero() {
            None
        } else {
            Some(Instant::now() + latency)
        }
    };

    let mut answered = 0u64;
    let mut protocol_err: Option<String> = None;
    let mut out = Vec::new();
    loop {
        let msg = match Message::read_from(&mut reader) {
            Ok(m) => m,
            // a client disconnecting mid-stream is a normal way for a
            // connection to end (coordinator died, failover kicked in):
            // log-and-continue serving other connections, not an error
            Err(e) => {
                crate::log_warn!("worker: client disconnected mid-stream ({e}); closing");
                break;
            }
        };
        let is_data = matches!(
            msg,
            Message::Batch { .. }
                | Message::Batch2 { .. }
                | Message::TBatch2 { .. }
                | Message::MultiBatch { .. }
        );
        let crash_now = opts.fail_after_batches.is_some_and(|limit| answered >= limit);
        if is_data && crash_now {
            // injected crash: drop the connection with this frame's
            // batches unanswered (no BYE)
            crate::log_info!("worker: injected crash after {answered} answered batches");
            break;
        }
        match msg {
            Message::Batch { vertex, others } => {
                out.clear();
                backend.process(vertex, &others, &mut out)?;
                let reply = Message::Delta {
                    vertex,
                    delta: out.clone(),
                };
                if tx.send((due(opts.reply_latency), reply)).is_err() {
                    break;
                }
                answered += 1;
            }
            Message::Batch2 {
                seq,
                vertex,
                others,
            } => {
                out.clear();
                let reply = match backend.process_delta(vertex, &others, &mut out)? {
                    DeltaFlavor::Sketch => Message::Delta2 {
                        seq,
                        vertex,
                        delta: out.clone(),
                    },
                    DeltaFlavor::Exact => Message::ExactDelta2 {
                        seq,
                        vertex,
                        indices: out.clone(),
                    },
                };
                if tx.send((due(opts.reply_latency), reply)).is_err() {
                    break;
                }
                answered += 1;
            }
            Message::TBatch2 {
                tenant,
                seq,
                vertex,
                others,
            } => {
                // tenant-tagged batch: the id is opaque to the worker
                // (all tenants share the fabric's seeds, so the
                // computation is tenant-independent) and is echoed back
                // verbatim so the coordinator can route the delta.
                // Tagged mode never negotiates the hybrid tier, so the
                // reply is always a full sketch delta.
                out.clear();
                backend.process(vertex, &others, &mut out)?;
                let reply = Message::TDelta2 {
                    tenant,
                    seq,
                    vertex,
                    delta: out.clone(),
                };
                if tx.send((due(opts.reply_latency), reply)).is_err() {
                    break;
                }
                answered += 1;
            }
            Message::MultiBatch { batches } => {
                // compute every delta, then queue the replies in REVERSE
                // order: a deliberate, deterministic out-of-order
                // completion exercise for pipelined clients (XOR merges
                // commute, so order must not matter)
                let mut replies = Vec::with_capacity(batches.len());
                for b in &batches {
                    out.clear();
                    let reply = match backend.process_delta(b.vertex, &b.others, &mut out)? {
                        DeltaFlavor::Sketch => Message::Delta2 {
                            seq: b.seq,
                            vertex: b.vertex,
                            delta: out.clone(),
                        },
                        DeltaFlavor::Exact => Message::ExactDelta2 {
                            seq: b.seq,
                            vertex: b.vertex,
                            indices: out.clone(),
                        },
                    };
                    replies.push(reply);
                }
                answered += replies.len() as u64;
                let when = due(opts.reply_latency);
                for r in replies.into_iter().rev() {
                    if tx.send((when, r)).is_err() {
                        break;
                    }
                }
            }
            Message::Shutdown => {
                // clean close: BYE after every queued delta has flushed
                let _ = tx.send((None, Message::Bye));
                break;
            }
            other => {
                let reason = format!("unexpected frame {other:?}");
                let _ = tx.send((
                    None,
                    Message::Error {
                        code: 1,
                        reason: reason.clone(),
                    },
                ));
                protocol_err = Some(reason);
                break;
            }
        }
    }
    drop(tx);
    let _ = sender.join();
    if let Some(reason) = protocol_err {
        bail!("{reason}");
    }
    Ok(())
}

/// Writes queued replies in order, holding each until its due time.
fn sender_loop(mut writer: BufWriter<TcpStream>, rx: mpsc::Receiver<QueuedReply>) {
    while let Ok((due, msg)) = rx.recv() {
        if let Some(t) = due {
            let now = Instant::now();
            if t > now {
                // lint: allow(thread-sleep) — deliberate injected-latency test rig (--latency-ms) holding a reply until its due time
                std::thread::sleep(t - now);
            }
        }
        if msg.write_to(&mut writer).is_err() {
            // the client went away mid-reply: drain and exit quietly
            while rx.recv().is_ok() {}
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::work_queue::{EpochBarrier, Ticket};
    use crate::net::SeqBatch;
    use crate::sketch::params::encode_edge;
    use crate::sketch::seeds::SketchSeeds;
    use crate::sketch::CameoSketch;

    /// A throwaway epoch ticket: the transport carries tickets opaquely,
    /// so standalone backend tests mint them from one process-lived
    /// barrier that is never dropped — tickets here are intentionally
    /// never completed, which the barrier's debug leaked-ticket detector
    /// would (correctly) flag on drop.
    fn ticket() -> Ticket {
        use std::sync::OnceLock;
        static BARRIER: OnceLock<EpochBarrier> = OnceLock::new();
        BARRIER.get_or_init(EpochBarrier::new).register()
    }

    #[test]
    fn remote_worker_round_trip_matches_native() {
        let params = SketchParams::for_vertices(64);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let remote = RemoteWorker::connect(&addr, params, 42, 1).unwrap();
        let mut got = Vec::new();
        remote.process(0, &[1, 3], &mut got).unwrap();
        remote.shutdown();
        server_thread.join().unwrap().unwrap();

        let seeds = SketchSeeds::derive(&params, 42);
        let idx = vec![encode_edge(0, 1, 64), encode_edge(0, 3, 64)];
        let want = CameoSketch::delta_of_batch(&params, &seeds, &idx);
        assert_eq!(got, want, "remote delta must be bit-identical to local");
        assert!(remote.bytes_sent.load(Ordering::Relaxed) > 0);
        assert!(remote.bytes_received.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn remote_worker_k_copies() {
        let params = SketchParams::for_vertices(32);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let remote = RemoteWorker::connect(&addr, params, 7, 3).unwrap();
        let mut got = Vec::new();
        remote.process(1, &[2], &mut got).unwrap();
        remote.shutdown();
        server_thread.join().unwrap().unwrap();
        assert_eq!(got.len(), 3 * params.words());
    }

    fn native_delta(params: SketchParams, seed: u64, k: u32, v: u32, others: &[u32]) -> Vec<u64> {
        let w = NativeWorker::new(WorkerSeeds::derive(params, seed, k));
        let mut out = Vec::new();
        w.process(v, others, &mut out).unwrap();
        out
    }

    #[test]
    fn pipelined_round_trip_matches_native_out_of_order() {
        let params = SketchParams::for_vertices(64);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let mut p = PipelinedRemote::connect(&addr, params, 42, 1, 8).unwrap();
        let batches = [(1u64, 0u32, vec![1u32, 3]), (2, 5, vec![6]), (3, 9, vec![2, 4])];
        for (token, vertex, others) in &batches {
            p.submit(PendingBatch {
                tenant: 0,
                token: *token,
                ticket: ticket(),
                vertex: *vertex,
                others: others.clone(),
            })
            .unwrap();
        }
        // one coalesced MULTIBATCH frame; the server replies in reverse
        p.flush_submits().unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < batches.len() && Instant::now() < deadline {
            p.drain(&mut got, true).unwrap();
        }
        assert_eq!(got.len(), 3);
        let tokens: Vec<u64> = got.iter().map(|c| c.token).collect();
        assert_eq!(tokens, vec![3, 2, 1], "server must reply in reverse order");
        for c in &got {
            let (_, vertex, others) = batches.iter().find(|b| b.0 == c.token).unwrap();
            assert_eq!(c.vertex, *vertex);
            assert_eq!(c.delta, native_delta(params, 42, 1, *vertex, others));
            assert_eq!(
                &c.others, others,
                "the batch buffer rides back with its completion"
            );
        }
        assert_eq!(p.in_flight(), 0);
        p.finish().unwrap();
        server_thread.join().unwrap().unwrap();
    }

    #[test]
    fn pipelined_meters_exact_wire_bytes() {
        let params = SketchParams::for_vertices(64);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let mut p = PipelinedRemote::connect(&addr, params, 7, 2, 16).unwrap();
        let b1 = PendingBatch {
            tenant: 0,
            token: 1,
            ticket: ticket(),
            vertex: 0,
            others: vec![1, 2, 3],
        };
        let b2 = PendingBatch {
            tenant: 0,
            token: 2,
            ticket: ticket(),
            vertex: 4,
            others: vec![5],
        };
        p.submit(b1.clone()).unwrap();
        p.submit(b2.clone()).unwrap();
        p.flush_submits().unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < 2 && Instant::now() < deadline {
            p.drain(&mut got, true).unwrap();
        }
        p.finish().unwrap();
        server_thread.join().unwrap().unwrap();

        let hello = Message::Hello {
            vertices: params.v,
            columns: params.columns,
            graph_seed: 7,
            k: 2,
            threshold: 0,
        };
        let multi = Message::MultiBatch {
            batches: vec![
                SeqBatch {
                    seq: 1,
                    vertex: 0,
                    others: b1.others.clone(),
                },
                SeqBatch {
                    seq: 2,
                    vertex: 4,
                    others: b2.others.clone(),
                },
            ],
        };
        assert_eq!(
            p.bytes_sent(),
            hello.wire_bytes() + multi.wire_bytes() + Message::Shutdown.wire_bytes()
        );
        let words = 2 * params.words();
        assert_eq!(
            p.bytes_received(),
            2 * delta2_wire_bytes(words) + Message::Bye.wire_bytes()
        );
        for c in &got {
            assert_eq!(c.wire_bytes, delta2_wire_bytes(words));
        }
    }

    /// With a negotiated threshold the server answers small batches with
    /// EXACTDELTA2 (raw indices, `exact: true`) and big batches with
    /// DELTA2 (sketch words), and the byte meter reflects the compact
    /// frames exactly.
    #[test]
    fn pipelined_hybrid_mixes_exact_and_sketch_completions() {
        let params = SketchParams::for_vertices(64);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let mut p = PipelinedRemote::connect_hybrid(&addr, params, 42, 1, 8, 2).unwrap();
        // batch 1: 2 survivors ≤ threshold 2 → exact; batch 2: 5 > 2 → sketch
        p.submit(PendingBatch {
            tenant: 0,
            token: 1,
            ticket: ticket(),
            vertex: 0,
            others: vec![3, 1],
        })
        .unwrap();
        p.flush_submits().unwrap();
        p.submit(PendingBatch {
            tenant: 0,
            token: 2,
            ticket: ticket(),
            vertex: 7,
            others: vec![1, 2, 3, 4, 5],
        })
        .unwrap();
        p.flush_submits().unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < 2 && Instant::now() < deadline {
            p.drain(&mut got, true).unwrap();
        }
        p.finish().unwrap();
        server_thread.join().unwrap().unwrap();

        assert_eq!(got.len(), 2);
        got.sort_by_key(|c| c.token);
        let exact = &got[0];
        assert!(exact.exact, "small batch must come back as an exact delta");
        assert_eq!(
            exact.delta,
            vec![encode_edge(0, 1, 64), encode_edge(0, 3, 64)],
            "exact completions carry sorted edge indices"
        );
        assert_eq!(exact.wire_bytes, exact_delta2_wire_bytes(2));
        let sketch = &got[1];
        assert!(!sketch.exact, "big batch must fall back to a sketch delta");
        assert_eq!(
            sketch.delta,
            native_delta(params, 42, 1, 7, &[1, 2, 3, 4, 5])
        );
        assert_eq!(sketch.wire_bytes, delta2_wire_bytes(params.words()));
    }

    /// In tagged mode every batch rides a standalone TBATCH2 frame and
    /// comes back as a TDELTA2 echoing the tenant id; deltas are
    /// bit-identical to the untagged path (workers are tenant-oblivious)
    /// and the byte meter reflects the tagged frames exactly — the
    /// property that makes per-tenant Theorem 5.2 accounting possible.
    #[test]
    fn tagged_round_trip_echoes_tenants_and_meters_exact_bytes() {
        use crate::net::tbatch2_wire_bytes;
        let params = SketchParams::for_vertices(64);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let mut p = PipelinedRemote::connect_tagged(&addr, params, 42, 2, 8).unwrap();
        let batches = [
            (3u32, 1u64, 0u32, vec![1u32, 3]),
            (7, 2, 5, vec![6]),
            (3, 3, 9, vec![2, 4]),
        ];
        for (tenant, token, vertex, others) in &batches {
            p.submit(PendingBatch {
                tenant: *tenant,
                token: *token,
                ticket: ticket(),
                vertex: *vertex,
                others: others.clone(),
            })
            .unwrap();
        }
        p.flush_submits().unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < batches.len() && Instant::now() < deadline {
            p.drain(&mut got, true).unwrap();
        }
        assert_eq!(got.len(), 3);
        for c in &got {
            let (tenant, _, vertex, others) =
                batches.iter().find(|b| b.1 == c.token).unwrap();
            assert_eq!(c.tenant, *tenant, "TDELTA2 must echo the tenant id");
            assert_eq!(c.vertex, *vertex);
            assert!(!c.exact, "tagged mode is sketch-only");
            assert_eq!(
                c.delta,
                native_delta(params, 42, 2, *vertex, others),
                "tenant tagging must not perturb the computation"
            );
            assert_eq!(c.wire_bytes, tdelta2_wire_bytes(c.delta.len()));
        }
        p.finish().unwrap();
        server_thread.join().unwrap().unwrap();

        let hello = Message::Hello {
            vertices: params.v,
            columns: params.columns,
            graph_seed: 42,
            k: 2,
            threshold: 0,
        };
        let batch_bytes: u64 = batches
            .iter()
            .map(|(_, _, _, others)| tbatch2_wire_bytes(others.len()))
            .sum();
        assert_eq!(
            p.bytes_sent(),
            hello.wire_bytes() + batch_bytes + Message::Shutdown.wire_bytes(),
            "per-batch TBATCH2 byte helper must reconcile with the framing layer"
        );
        let words = 2 * params.words();
        assert_eq!(
            p.bytes_received(),
            3 * tdelta2_wire_bytes(words) + Message::Bye.wire_bytes()
        );
    }

    #[test]
    fn crashed_server_leaves_unacked_batches_recoverable() {
        let params = SketchParams::for_vertices(64);
        let opts = ServeOptions {
            fail_after_batches: Some(1),
            ..Default::default()
        };
        let server = WorkerServer::bind_with("127.0.0.1:0", opts).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let mut p = PipelinedRemote::connect(&addr, params, 42, 1, 8).unwrap();
        // first batch is answered; the second triggers the crash
        p.submit(PendingBatch {
            tenant: 0,
            token: 1,
            ticket: ticket(),
            vertex: 0,
            others: vec![1],
        })
        .unwrap();
        p.flush_submits().unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.is_empty() && Instant::now() < deadline {
            p.drain(&mut got, true).unwrap();
        }
        assert_eq!(got.len(), 1);

        let crash_ticket = ticket();
        p.submit(PendingBatch {
            tenant: 0,
            token: 2,
            ticket: crash_ticket,
            vertex: 3,
            others: vec![4, 5],
        })
        .unwrap();
        let _ = p.flush_submits();
        // the crash surfaces as a dead backend on drain
        let deadline = Instant::now() + Duration::from_secs(5);
        let died = loop {
            match p.drain(&mut got, true) {
                Err(_) => break true,
                Ok(()) if Instant::now() >= deadline => break false,
                Ok(()) => {}
            }
        };
        assert!(died, "crash must surface as a drain error");
        assert!(p.dead());
        let unacked = p.take_unacked();
        assert_eq!(unacked.len(), 1);
        assert_eq!(unacked[0].token, 2);
        assert_eq!(unacked[0].others, vec![4, 5]);
        assert_eq!(
            unacked[0].ticket, crash_ticket,
            "a recovered batch must keep its original epoch ticket"
        );
        server_thread.join().unwrap().unwrap();
    }

    #[test]
    fn pipelined_beats_lockstep_under_injected_latency() {
        // the acceptance experiment in miniature: per-reply latency of
        // 5ms, 12 batches.  Lockstep pays 12 serial round trips (≥ 60ms
        // by construction); a window of 8 overlaps them.
        let params = SketchParams::for_vertices(64);
        let latency = Duration::from_millis(5);
        let n = 12u64;
        let opts = ServeOptions {
            reply_latency: latency,
            ..Default::default()
        };
        let server = WorkerServer::bind_with("127.0.0.1:0", opts).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(2));

        let lockstep = RemoteWorker::connect(&addr, params, 42, 1).unwrap();
        let t0 = Instant::now();
        let mut out = Vec::new();
        for i in 0..n {
            out.clear();
            lockstep.process(i as u32, &[i as u32 + 1], &mut out).unwrap();
        }
        let lockstep_secs = t0.elapsed().as_secs_f64();
        lockstep.shutdown();

        let mut p = PipelinedRemote::connect(&addr, params, 42, 1, 8).unwrap();
        let t0 = Instant::now();
        let mut done = 0u64;
        let mut comps = Vec::new();
        for i in 0..n {
            p.submit(PendingBatch {
                tenant: 0,
                token: i + 1,
                ticket: ticket(),
                vertex: i as u32,
                others: vec![i as u32 + 1],
            })
            .unwrap();
            p.drain(&mut comps, false).unwrap();
            done += comps.drain(..).len() as u64;
        }
        p.flush_submits().unwrap();
        while done < n {
            p.drain(&mut comps, true).unwrap();
            done += comps.drain(..).len() as u64;
        }
        let pipelined_secs = t0.elapsed().as_secs_f64();
        p.finish().unwrap();
        server_thread.join().unwrap().unwrap();

        assert!(
            pipelined_secs * 2.0 < lockstep_secs,
            "pipelined ({pipelined_secs:.3}s) must be at least 2x faster than \
             lockstep ({lockstep_secs:.3}s) under {latency:?} reply latency"
        );
    }
}
