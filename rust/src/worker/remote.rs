//! Remote workers over TCP: the server loop run by `landscape worker`,
//! and the coordinator-side client backend.
//!
//! Workers are stateless (paper §6): the HELLO handshake carries the
//! graph config, after which the server answers BATCH frames with DELTA
//! frames computed by a [`NativeWorker`].  One connection serves one
//! coordinator distributor thread; a server accepts many connections.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::net::Message;
use crate::sketch::params::SketchParams;
use crate::worker::{NativeWorker, WorkerBackend, WorkerSeeds};

/// Coordinator-side backend that forwards batches to a remote worker.
pub struct RemoteWorker {
    conn: Mutex<RemoteConn>,
    /// Bytes sent/received over this connection (metered at the framing
    /// layer; feeds the Theorem 5.2 validation).
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
}

struct RemoteConn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl RemoteWorker {
    /// Connect and perform the HELLO handshake.
    pub fn connect(
        addr: &str,
        params: SketchParams,
        graph_seed: u64,
        k: u32,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        let hello = Message::Hello {
            vertices: params.v,
            columns: params.columns,
            graph_seed,
            k,
        };
        let sent = hello.write_to(&mut writer)?;
        let worker = Self {
            conn: Mutex::new(RemoteConn { reader, writer }),
            bytes_sent: AtomicU64::new(sent),
            bytes_received: AtomicU64::new(0),
        };
        Ok(worker)
    }

    /// Politely shut the connection down.
    pub fn shutdown(&self) {
        if let Ok(mut conn) = self.conn.lock() {
            let _ = Message::Shutdown.write_to(&mut conn.writer);
        }
    }
}

impl WorkerBackend for RemoteWorker {
    fn process(&self, vertex: u32, others: &[u32], out: &mut Vec<u64>) -> Result<()> {
        let mut conn = self.conn.lock().unwrap();
        let batch = Message::Batch {
            vertex,
            others: others.to_vec(),
        };
        let sent = batch.write_to(&mut conn.writer)?;
        self.bytes_sent.fetch_add(sent, Ordering::Relaxed);
        match Message::read_from(&mut conn.reader)? {
            Message::Delta {
                vertex: rv,
                delta,
            } => {
                if rv != vertex {
                    bail!("delta for wrong vertex: sent {vertex}, got {rv}");
                }
                self.bytes_received.fetch_add(
                    Message::Delta {
                        vertex: rv,
                        delta: Vec::new(),
                    }
                    .wire_bytes()
                        + delta.len() as u64 * 8,
                    Ordering::Relaxed,
                );
                out.extend_from_slice(&delta);
                Ok(())
            }
            other => Err(anyhow!("expected DELTA, got {other:?}")),
        }
    }

    fn name(&self) -> &'static str {
        "remote-tcp"
    }
}

/// Worker server: accept connections, answer batches until SHUTDOWN.
pub struct WorkerServer {
    listener: TcpListener,
}

impl WorkerServer {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Self> {
        Ok(Self {
            listener: TcpListener::bind(addr)?,
        })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve `max_connections` then return (use `usize::MAX` to run
    /// forever).  Each connection is handled on its own thread.
    pub fn serve(&self, max_connections: usize) -> Result<()> {
        let mut served = 0;
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            let stream = stream?;
            handles.push(std::thread::spawn(move || {
                if let Err(e) = handle_connection(stream) {
                    eprintln!("worker connection error: {e:#}");
                }
            }));
            served += 1;
            if served >= max_connections {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_connection(stream: TcpStream) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    // handshake: first frame must be HELLO
    let backend: Box<dyn WorkerBackend> = match Message::read_from(&mut reader)? {
        Message::Hello {
            vertices,
            columns,
            graph_seed,
            k,
        } => {
            let params = SketchParams::with_columns(vertices, columns);
            Box::new(NativeWorker::new(WorkerSeeds::derive(params, graph_seed, k)))
        }
        other => bail!("expected HELLO, got {other:?}"),
    };

    let mut out = Vec::new();
    loop {
        match Message::read_from(&mut reader) {
            Ok(Message::Batch { vertex, others }) => {
                out.clear();
                backend.process(vertex, &others, &mut out)?;
                Message::Delta {
                    vertex,
                    delta: out.clone(),
                }
                .write_to(&mut writer)?;
            }
            Ok(Message::Shutdown) | Err(_) => return Ok(()),
            Ok(other) => bail!("unexpected frame {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::encode_edge;
    use crate::sketch::CameoSketch;
    use crate::sketch::seeds::SketchSeeds;

    #[test]
    fn remote_worker_round_trip_matches_native() {
        let params = SketchParams::for_vertices(64);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let remote = RemoteWorker::connect(&addr, params, 42, 1).unwrap();
        let mut got = Vec::new();
        remote.process(0, &[1, 3], &mut got).unwrap();
        remote.shutdown();
        server_thread.join().unwrap().unwrap();

        let seeds = SketchSeeds::derive(&params, 42);
        let idx = vec![encode_edge(0, 1, 64), encode_edge(0, 3, 64)];
        let want = CameoSketch::delta_of_batch(&params, &seeds, &idx);
        assert_eq!(got, want, "remote delta must be bit-identical to local");
        assert!(remote.bytes_sent.load(Ordering::Relaxed) > 0);
        assert!(remote.bytes_received.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn remote_worker_k_copies() {
        let params = SketchParams::for_vertices(32);
        let server = WorkerServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve(1));

        let remote = RemoteWorker::connect(&addr, params, 7, 3).unwrap();
        let mut got = Vec::new();
        remote.process(1, &[2], &mut got).unwrap();
        remote.shutdown();
        server_thread.join().unwrap().unwrap();
        assert_eq!(got.len(), 3 * params.words());
    }
}
