//! Worker backends — the stateless distributed workers of §5.2.
//!
//! A worker receives a vertex-based batch of edge indices and returns a
//! sketch delta for each of the k sketch copies, concatenated.  Workers
//! hold no graph state (only the seed material), which is what lets the
//! paper run them on 2 GB nodes and lets us swap implementations:
//!
//! * [`NativeWorker`] — the Rust CameoSketch kernel (the perf path).
//! * [`XlaWorker`] — executes the AOT Pallas artifact via PJRT (the
//!   three-layer composition path; bit-identical to native; needs the
//!   non-default `xla` cargo feature).
//! * [`CubeWorker`] — CubeSketch updates (Fig. 4 / Fig. 16 ablation).
//! * [`RemoteWorker`] — a TCP client speaking the lockstep v1 `net`
//!   protocol to a `landscape worker` server process.
//!
//! On top of the synchronous [`WorkerBackend::process`], the
//! [`SubmitBackend`] trait exposes a **submit/drain completion API**:
//! a distributor submits sequence-tagged batches without waiting and
//! later drains [`Completion`]s, possibly out of submission order.
//! In-process backends complete inline ([`InlineSubmit`]); the remote
//! backend ([`remote::PipelinedRemote`]) keeps a window of batches in
//! flight on the wire and completes as DELTA2 frames arrive.

pub mod remote;

use anyhow::Result;

use crate::coordinator::work_queue::Ticket;
use crate::coordinator::TenantId;
use crate::sketch::params::{encode_edge, SketchParams};
use crate::sketch::seeds::SketchSeeds;
use crate::sketch::{CameoSketch, CubeSketch};

/// A sketch-delta computation backend.
///
/// `process` must append `k × params.words()` u64 words to `out` — one
/// delta per sketch copy, in copy order.
///
/// Deliberately *not* `Send + Sync`: the XLA backend wraps PJRT handles
/// that must stay on the thread that created them, so the coordinator
/// constructs one backend per distributor thread, inside that thread.
pub trait WorkerBackend {
    /// `others` are the non-`vertex` endpoints of the batched updates;
    /// the worker reconstructs each edge index as
    /// `encode_edge(vertex, other)` — the encode cost is part of the
    /// work being distributed away.
    fn process(&self, vertex: u32, others: &[u32], out: &mut Vec<u64>) -> Result<()>;

    /// Like [`Self::process`], but may answer with an **exact-set**
    /// delta when the backend was constructed with a hybrid threshold:
    /// `out` then holds the batch's odd-parity edge indices (one list,
    /// copy-independent — the same indices are valid for every sketch
    /// copy) instead of k concatenated sketch deltas.  The default
    /// implementation always produces sketch deltas, so backends
    /// without an exact path (cube, xla) stay correct: the store
    /// force-promotes a cold vertex that receives a sketch delta.
    fn process_delta(
        &self,
        vertex: u32,
        others: &[u32],
        out: &mut Vec<u64>,
    ) -> Result<DeltaFlavor> {
        self.process(vertex, others, out)?;
        Ok(DeltaFlavor::Sketch)
    }

    /// Human-readable backend name (for logs / bench output).
    fn name(&self) -> &'static str;
}

/// Which representation a worker's reply uses (see
/// [`WorkerBackend::process_delta`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaFlavor {
    /// `out` holds `k × params.words()` XOR-merge-ready sketch words.
    Sketch,
    /// `out` holds the batch's odd-parity encoded edge indices.
    Exact,
}

/// A batch handed to a [`SubmitBackend`], tagged with the distributor's
/// completion token (which doubles as the wire sequence number) and the
/// epoch-barrier ticket minted when the batch was enqueued.
///
/// The ticket is opaque to backends: they carry it from submission to
/// completion unchanged, so however late or out of order a batch
/// completes — including after a failover resubmission to a different
/// worker — it retires against the epoch it was *registered* in, which
/// is what keeps query cuts sound.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PendingBatch {
    /// Logical graph this batch belongs to
    /// ([`crate::coordinator::SOLO_TENANT`] for single-tenant sessions).
    /// Backends carry it unchanged from submission to completion so the
    /// distributor can resolve the owning tenant's runtime at merge time.
    pub tenant: TenantId,
    pub token: u64,
    pub ticket: Ticket,
    pub vertex: u32,
    pub others: Vec<u32>,
}

/// A finished batch: the k concatenated sketch deltas for the batch
/// submitted under `token`, echoing the submitted batch's epoch ticket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Completion {
    /// Echo of the submitted batch's tenant id (see [`PendingBatch`]).
    pub tenant: TenantId,
    pub token: u64,
    pub ticket: Ticket,
    pub vertex: u32,
    pub delta: Vec<u64>,
    /// Exact bytes of the DELTA frame this completion arrived in
    /// (0 for in-process backends — no network traffic to meter).
    pub wire_bytes: u64,
    /// `true` when `delta` is an exact-set index list
    /// ([`DeltaFlavor::Exact`]) rather than k sketch deltas.
    pub exact: bool,
    /// The submitted batch's endpoint buffer, handed back so the
    /// distributor can recycle it into the
    /// [`crate::coordinator::arena::BatchArena`] once the delta has
    /// merged.  Backends move it from the [`PendingBatch`] (inline at
    /// submission; the pipelined reader when the DELTA2 arrives) — the
    /// payload is never cloned to make the round trip.
    pub others: Vec<u32>,
}

/// The pipelined counterpart of [`WorkerBackend`]: batches are
/// *submitted* (possibly buffered/coalesced, possibly blocking for
/// window backpressure) and *drained* as out-of-order [`Completion`]s.
///
/// Error contract: a failed `submit`/`drain` with [`SubmitBackend::dead`]
/// returning `true` means the backend is permanently gone (e.g. the TCP
/// connection died) and every batch it still holds is recoverable via
/// [`SubmitBackend::take_unacked`] for requeueing elsewhere.  A failed
/// `submit` with `dead() == false` is a per-batch computation error: the
/// batch is lost, the backend stays usable.
pub trait SubmitBackend {
    /// Queue one batch.  May block while the in-flight window is full
    /// (backpressure).  On `Err` with `dead()`, the batch is retained in
    /// the unacknowledged set.
    fn submit(&mut self, batch: PendingBatch) -> Result<()>;

    /// Push any buffered submissions onto the wire (MULTIBATCH
    /// coalescing).  No-op for inline backends.
    fn flush_submits(&mut self) -> Result<()> {
        Ok(())
    }

    /// Move available completions into `out`.  With `block`, waits
    /// briefly for at least one completion when some are in flight.
    /// `Err` only when the backend is dead *and* nothing is drainable.
    fn drain(&mut self, out: &mut Vec<Completion>, block: bool) -> Result<()>;

    /// Batches submitted but not yet drained as completions.
    fn in_flight(&self) -> usize;

    /// Batches actually occupying the transmission window (buffered or
    /// on the wire, excluding completions awaiting drain) — the gauge
    /// behind `remote_in_flight_peak`.
    fn wire_occupancy(&self) -> usize {
        self.in_flight()
    }

    /// Total bytes this backend has actually written to the wire
    /// (HELLO + batch frames + SHUTDOWN), byte-exact at the framing
    /// layer.  0 for in-process backends, which send nothing — the
    /// coordinator uses the difference between successive readings to
    /// meter the remote batch leg against real serialized bytes.
    fn wire_bytes_sent(&self) -> u64 {
        0
    }

    /// Whether the backend has permanently failed.
    fn dead(&self) -> bool {
        false
    }

    /// On a dead backend: every submitted-but-unacknowledged batch, in
    /// token order, ready for resubmission to a surviving backend.
    fn take_unacked(&mut self) -> Vec<PendingBatch> {
        Vec::new()
    }

    /// Graceful close once everything has drained (SHUTDOWN/BYE
    /// handshake for the remote backend).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }

    /// Human-readable backend name (for logs / bench output).
    fn name(&self) -> &'static str;
}

/// Adapts any synchronous [`WorkerBackend`] to the submit/drain API by
/// completing every batch inline at submission time.
pub struct InlineSubmit {
    backend: Box<dyn WorkerBackend>,
    ready: Vec<Completion>,
}

impl InlineSubmit {
    pub fn new(backend: Box<dyn WorkerBackend>) -> Self {
        Self {
            backend,
            ready: Vec::new(),
        }
    }
}

impl SubmitBackend for InlineSubmit {
    fn submit(&mut self, batch: PendingBatch) -> Result<()> {
        let mut delta = Vec::new();
        let flavor = self
            .backend
            .process_delta(batch.vertex, &batch.others, &mut delta)?;
        self.ready.push(Completion {
            tenant: batch.tenant,
            token: batch.token,
            ticket: batch.ticket,
            vertex: batch.vertex,
            delta,
            wire_bytes: 0,
            exact: flavor == DeltaFlavor::Exact,
            others: batch.others,
        });
        Ok(())
    }

    fn drain(&mut self, out: &mut Vec<Completion>, _block: bool) -> Result<()> {
        out.append(&mut self.ready);
        Ok(())
    }

    fn in_flight(&self) -> usize {
        self.ready.len()
    }

    fn name(&self) -> &'static str {
        self.backend.name()
    }
}

/// Reconstruct edge indices from a (vertex, others) batch.
pub fn batch_indices(vertex: u32, others: &[u32], v: u64, out: &mut Vec<u64>) {
    out.clear();
    out.reserve(others.len());
    for &o in others {
        out.push(encode_edge(vertex, o, v));
    }
}

/// Seed material shared by all backends: one [`SketchSeeds`] per copy.
#[derive(Clone, Debug)]
pub struct WorkerSeeds {
    pub params: SketchParams,
    pub per_copy: Vec<SketchSeeds>,
}

impl WorkerSeeds {
    pub fn derive(params: SketchParams, graph_seed: u64, k: u32) -> Self {
        let per_copy = (0..k)
            .map(|c| SketchSeeds::derive(&params, SketchSeeds::copy_seed(graph_seed, c)))
            .collect();
        Self { params, per_copy }
    }

    pub fn k(&self) -> u32 {
        self.per_copy.len() as u32
    }
}

/// Native Rust CameoSketch worker.
pub struct NativeWorker {
    seeds: WorkerSeeds,
    /// Hybrid handshake threshold: batches whose odd-parity index count
    /// is ≤ this answer with an exact-set delta (0 = sketch always).
    threshold: u32,
    scratch: std::cell::RefCell<Vec<u64>>,
}

impl NativeWorker {
    pub fn new(seeds: WorkerSeeds) -> Self {
        Self::with_threshold(seeds, 0)
    }

    /// A native worker speaking the hybrid protocol: batches whose
    /// odd-parity index count is ≤ `threshold` are answered with an
    /// exact-set delta instead of k sketch deltas (0 disables).
    pub fn with_threshold(seeds: WorkerSeeds, threshold: u32) -> Self {
        Self {
            seeds,
            threshold,
            scratch: std::cell::RefCell::new(Vec::new()),
        }
    }
}

impl WorkerBackend for NativeWorker {
    fn process(&self, vertex: u32, others: &[u32], out: &mut Vec<u64>) -> Result<()> {
        let words = self.seeds.params.words();
        let mut idx = self.scratch.borrow_mut();
        batch_indices(vertex, others, self.seeds.params.v, &mut idx);
        for seeds in &self.seeds.per_copy {
            let start = out.len();
            out.resize(start + words, 0);
            CameoSketch::delta_of_batch_into(
                &mut out[start..],
                &self.seeds.params,
                seeds,
                &idx,
            );
        }
        Ok(())
    }

    fn process_delta(
        &self,
        vertex: u32,
        others: &[u32],
        out: &mut Vec<u64>,
    ) -> Result<DeltaFlavor> {
        if self.threshold == 0 {
            self.process(vertex, others, out)?;
            return Ok(DeltaFlavor::Sketch);
        }
        let words = self.seeds.params.words();
        let mut idx = self.scratch.borrow_mut();
        batch_indices(vertex, others, self.seeds.params.v, &mut idx);
        // parity-reduce: an index toggled an even number of times is a
        // no-op under XOR and drops out of both flavors identically
        idx.sort_unstable();
        let mut keep = 0usize;
        let mut i = 0usize;
        while i < idx.len() {
            let mut run = 1usize;
            while i + run < idx.len() && idx[i + run] == idx[i] {
                run += 1;
            }
            if run % 2 == 1 {
                idx[keep] = idx[i];
                keep += 1;
            }
            i += run;
        }
        idx.truncate(keep);
        if keep <= self.threshold as usize {
            out.extend_from_slice(&idx);
            return Ok(DeltaFlavor::Exact);
        }
        for seeds in &self.seeds.per_copy {
            let start = out.len();
            out.resize(start + words, 0);
            CameoSketch::delta_of_batch_into(
                &mut out[start..],
                &self.seeds.params,
                seeds,
                &idx,
            );
        }
        Ok(DeltaFlavor::Sketch)
    }

    fn name(&self) -> &'static str {
        "native-cameo"
    }
}

/// CubeSketch worker — the GraphZeppelin-mode ablation backend.
pub struct CubeWorker {
    seeds: WorkerSeeds,
}

impl CubeWorker {
    pub fn new(seeds: WorkerSeeds) -> Self {
        Self { seeds }
    }
}

impl WorkerBackend for CubeWorker {
    fn process(&self, vertex: u32, others: &[u32], out: &mut Vec<u64>) -> Result<()> {
        let mut idx = Vec::new();
        batch_indices(vertex, others, self.seeds.params.v, &mut idx);
        for seeds in &self.seeds.per_copy {
            let delta = CubeSketch::delta_of_batch(&self.seeds.params, seeds, &idx);
            out.extend_from_slice(&delta);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "cube-ablation"
    }
}

/// XLA worker: the AOT-compiled Pallas kernel via PJRT.
#[cfg(feature = "xla")]
pub struct XlaWorker {
    seeds: WorkerSeeds,
    exe: crate::runtime::DeltaExecutable,
}

#[cfg(feature = "xla")]
impl XlaWorker {
    /// Load the artifact matching `seeds.params` from `artifact_dir`.
    pub fn load(artifact_dir: &std::path::Path, seeds: WorkerSeeds) -> Result<Self> {
        let rt = crate::runtime::Runtime::cpu()?;
        let exe = rt.load_delta_executable(artifact_dir, seeds.params)?;
        Ok(Self { seeds, exe })
    }
}

#[cfg(feature = "xla")]
impl WorkerBackend for XlaWorker {
    fn process(&self, vertex: u32, others: &[u32], out: &mut Vec<u64>) -> Result<()> {
        let mut idx = Vec::new();
        batch_indices(vertex, others, self.seeds.params.v, &mut idx);
        for seeds in &self.seeds.per_copy {
            let delta = self.exe.compute_delta(&idx, seeds)?;
            out.extend_from_slice(&delta);
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "xla-pallas"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::encode_edge;

    fn seeds(v: u64, k: u32) -> WorkerSeeds {
        WorkerSeeds::derive(SketchParams::for_vertices(v), 42, k)
    }

    #[test]
    fn native_worker_emits_k_deltas() {
        let s = seeds(64, 3);
        let words = s.params.words();
        let w = NativeWorker::new(s);
        let mut out = Vec::new();
        w.process(0, &[1, 2], &mut out).unwrap();
        assert_eq!(out.len(), 3 * words);
        // copies use different seeds, so deltas differ
        assert_ne!(out[..words], out[words..2 * words]);
    }

    #[test]
    fn native_matches_direct_kernel() {
        let s = seeds(64, 1);
        let params = s.params;
        let direct = CameoSketch::delta_of_batch(
            &params,
            &s.per_copy[0],
            &[encode_edge(3, 4, 64)],
        );
        let w = NativeWorker::new(s);
        let mut out = Vec::new();
        w.process(3, &[4], &mut out).unwrap();
        assert_eq!(out, direct);
    }

    #[test]
    fn inline_submit_completes_at_submission() {
        let s = seeds(64, 2);
        let words = s.params.words();
        let barrier = crate::coordinator::work_queue::EpochBarrier::new();
        let ticket = barrier.register();
        let mut b = InlineSubmit::new(Box::new(NativeWorker::new(s.clone())));
        b.submit(PendingBatch {
            tenant: crate::coordinator::SOLO_TENANT,
            token: 7,
            ticket,
            vertex: 0,
            others: vec![1, 2],
        })
        .unwrap();
        assert_eq!(b.in_flight(), 1);
        let mut out = Vec::new();
        b.drain(&mut out, true).unwrap();
        assert_eq!(b.in_flight(), 0);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, 7);
        assert_eq!(
            out[0].tenant,
            crate::coordinator::SOLO_TENANT,
            "completions echo the tenant id"
        );
        assert_eq!(out[0].ticket, ticket, "completions echo the epoch ticket");
        assert_eq!(out[0].wire_bytes, 0, "inline backends meter no network");
        assert!(!out[0].exact, "threshold-0 native stays sketch-flavored");
        assert_eq!(
            out[0].others,
            vec![1, 2],
            "the batch buffer rides back for arena recycling"
        );
        assert_eq!(out[0].delta.len(), 2 * words);
        let native = NativeWorker::new(s);
        let mut want = Vec::new();
        native.process(0, &[1, 2], &mut want).unwrap();
        assert_eq!(out[0].delta, want);
        assert!(!b.dead());
        assert!(b.take_unacked().is_empty());
        b.finish().unwrap();
        // retire the ticket the drained completion carried, as the
        // distributor would (the barrier's debug leak detector panics on
        // drop otherwise)
        barrier.complete(out[0].ticket);
    }

    #[test]
    fn native_with_threshold_returns_exact_for_small_batches() {
        let s = seeds(64, 2);
        let w = NativeWorker::with_threshold(s, 4);
        let mut out = Vec::new();
        // 5 raw entries, but `2` toggles twice and cancels → 3 survivors
        let flavor = w.process_delta(0, &[1, 2, 2, 3, 9], &mut out).unwrap();
        assert_eq!(flavor, DeltaFlavor::Exact);
        let want: Vec<u64> = [1u32, 3, 9]
            .iter()
            .map(|&o| encode_edge(0, o, 64))
            .collect();
        assert_eq!(out, want);
    }

    #[test]
    fn native_with_threshold_falls_back_to_sketch_for_big_batches() {
        let s = seeds(64, 2);
        let words = s.params.words();
        let plain = NativeWorker::new(s.clone());
        let w = NativeWorker::with_threshold(s, 2);
        let others: Vec<u32> = (1..9).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        assert_eq!(
            w.process_delta(0, &others, &mut a).unwrap(),
            DeltaFlavor::Sketch
        );
        plain.process(0, &others, &mut b).unwrap();
        assert_eq!(a.len(), 2 * words);
        assert_eq!(a, b, "sketch fallback is bit-identical to the plain path");
    }

    #[test]
    fn cube_worker_differs_from_native_below_row0() {
        let s = seeds(64, 1);
        let native = NativeWorker::new(s.clone());
        let cube = CubeWorker::new(s);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        native.process(0, &[1], &mut a).unwrap();
        cube.process(0, &[1], &mut b).unwrap();
        assert_ne!(a, b, "cube writes extra rows");
    }
}
