//! Figure harnesses: Fig. 1 (survey), Fig. 3 (scaling), Fig. 4
//! (ablation), Fig. 5 (query bursts), Fig. 16 (single-machine).

use crate::analysis::cluster_model::{measure_stage_costs, BufferingKind, KernelKind};
use crate::analysis::{rambw, survey};
use crate::benchkit::Table;
use crate::coordinator::CoordinatorConfig;
use crate::session::Landscape;
use crate::stream::datasets;
use crate::stream::EdgeModel;
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;

/// Fig. 1 / Fig. 15: the dataset-survey selection effect.  Emits the
/// scatter series (one row per synthesized dataset) and prints the
/// frontier summary.
pub fn fig1_survey() -> Table {
    let catalog = survey::synthesize_catalog(0x5EED);
    let summary = survey::summarize(&catalog);
    crate::log_info!(
        "survey: {}/{} datasets under the 16 GB adjacency-list frontier \
         (max {:.1} GiB)",
        summary.under_frontier,
        summary.total,
        summary.max_adj_bytes / (1u64 << 30) as f64
    );
    let mut t = Table::new(
        "Fig 1 — dataset survey (synthesized; see DESIGN.md Substitutions)",
        &["category", "vertices", "edges", "density", "adj_list_gib"],
    );
    for p in &catalog {
        t.row(vec![
            p.category.to_string(),
            format!("{:.0}", p.vertices),
            format!("{:.0}", p.edges),
            format!("{:.3e}", p.density()),
            format!("{:.4}", p.adjacency_list_bytes() / (1u64 << 30) as f64),
        ]);
    }
    t
}

/// Fig. 3: ingestion rate vs distributed workers, against RAM-bandwidth
/// bounds.  Stage costs are *measured* single-thread; the worker axis
/// uses the pipeline model (this box has one core — see DESIGN.md).
pub fn fig3_scaling(quick: bool) -> Table {
    let name = if quick { "kron10" } else { "kron12" };
    let d = datasets::by_name(name).unwrap();
    let v = d.model.num_vertices();
    let samples = if quick { 100_000 } else { 400_000 };

    let costs = measure_stage_costs(v, samples, KernelKind::Cameo, BufferingKind::Hypertree);
    let (seq, rnd) = rambw::measure_defaults();
    crate::log_info!(
        "measured: main {:.0} ns/u, worker {:.0} ns/u, merge {:.1} ns/u; \
         RAM seq {:.2} GiB/s ({:.0} Mu/s), random {:.2} GiB/s ({:.0} Mu/s)",
        costs.main_per_update * 1e9,
        costs.worker_per_update * 1e9,
        costs.merge_per_update * 1e9,
        seq.gib_per_sec(),
        seq.updates_per_sec() / 1e6,
        rnd.gib_per_sec(),
        rnd.updates_per_sec() / 1e6,
    );

    let mut t = Table::new(
        "Fig 3 — ingestion rate vs workers (measured costs + pipeline model)",
        &[
            "workers",
            "threads_total",
            "rate_updates_per_sec",
            "seq_ram_updates_per_sec",
            "random_ram_updates_per_sec",
        ],
    );
    // the paper's main node is a 36-core c5n.18xlarge; its hypertree
    // ingest parallelizes across those cores, which is what lets worker
    // scaling run to 40 nodes before the main-node bound bites
    let main_threads = 36;
    for workers in [1u32, 2, 4, 8, 12, 16, 20, 24, 28, 32, 36, 40] {
        let rate = costs.predict_rate_full(workers, 16, main_threads);
        t.row(vec![
            workers.to_string(),
            (workers * 16).to_string(),
            format!("{:.0}", rate),
            format!("{:.0}", seq.updates_per_sec()),
            format!("{:.0}", rnd.updates_per_sec()),
        ]);
    }
    let sat = costs.saturation_workers_full(16, main_threads);
    crate::log_info!(
        "saturation at ~{} workers (36 main threads); speedup(40w vs 1w) = {:.1}x",
        sat,
        costs.predict_rate_full(40, 16, main_threads)
            / costs.predict_rate_full(1, 16, main_threads)
    );
    t
}

/// Fig. 4: the ablation — CameoSketch and the pipeline hypertree are
/// both required for scaling.  Three configurations over the worker
/// axis, from measured stage costs.
pub fn fig4_ablation(quick: bool) -> Table {
    let name = if quick { "kron10" } else { "kron12" };
    let d = datasets::by_name(name).unwrap();
    let v = d.model.num_vertices();
    let samples = if quick { 80_000 } else { 300_000 };

    let configs = [
        ("cube+gutter (GraphZeppelin)", KernelKind::Cube, BufferingKind::Gutter),
        ("cameo+gutter", KernelKind::Cameo, BufferingKind::Gutter),
        ("cameo+hypertree (Landscape)", KernelKind::Cameo, BufferingKind::Hypertree),
    ];
    let mut t = Table::new(
        "Fig 4 — ablation: sketch kernel x buffering",
        &["config", "workers", "rate_updates_per_sec"],
    );
    for (label, kernel, buffering) in configs {
        let costs = measure_stage_costs(v, samples, kernel, buffering);
        crate::log_info!(
            "{label}: main {:.0} ns/u, worker {:.0} ns/u",
            costs.main_per_update * 1e9,
            costs.worker_per_update * 1e9
        );
        // the hypertree's thread-local levels parallelize across the
        // main node's cores; the gutter's striped locks contend and its
        // random per-update accesses serialize (GraphZeppelin "fails to
        // scale beyond 80 threads", App. F.4) — model its main stage as
        // non-scaling
        let main_threads = if buffering == BufferingKind::Hypertree { 36 } else { 1 };
        for workers in [1u32, 2, 4, 8, 16, 24, 32, 40] {
            t.row(vec![
                label.to_string(),
                workers.to_string(),
                format!(
                    "{:.0}",
                    costs.predict_rate_full(workers, 16, main_threads)
                ),
            ]);
        }
    }
    t
}

/// Fig. 5: query-burst latency — the first query in a burst pays the
/// flush + Borůvka cost; subsequent queries hit GreedyCC.
pub fn fig5_query_bursts(quick: bool) -> Table {
    let name = if quick { "kron10" } else { "kron11" };
    let d = datasets::by_name(name).unwrap();
    let v = d.model.num_vertices();
    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.alpha = 1;
    let session = Landscape::from_config(cfg).unwrap();
    let mut ingest = session.ingest_handle();
    let queries = session.query_handle();

    let mut t = Table::new(
        "Fig 5 — query latency within bursts (seconds)",
        &["burst", "query_in_burst", "kind", "latency_secs"],
    );

    let mut stream = d.stream();
    let burst_gap = if quick { 400_000 } else { 2_000_000 };
    let mut rng = Xoshiro256::new(3);
    'outer: for burst in 0..4u32 {
        // ingest a chunk of stream
        for _ in 0..burst_gap {
            match stream.next() {
                Some(u) => ingest.ingest(u),
                None => {
                    if burst == 0 {
                        // stream too short for even one burst: still query
                    }
                    if burst > 0 {
                        break 'outer;
                    }
                    break;
                }
            }
        }
        // publish this producer's tail so the burst sees the full prefix
        ingest.flush();
        // burst of 5 queries: 1 forced-full + 4 accelerated
        for q in 0..5u32 {
            let pairs: Vec<(u32, u32)> = (0..64)
                .map(|_| {
                    let a = rng.next_below(v) as u32;
                    let b = rng.next_below(v) as u32;
                    (a, b)
                })
                .collect();
            let sw = Stopwatch::new();
            let kind = if q == 0 {
                queries.full_connectivity_query();
                "global(full)"
            } else if q % 2 == 1 {
                queries.connected_components();
                "global(greedy)"
            } else {
                queries.reachability(&pairs);
                "reachability(greedy)"
            };
            t.row(vec![
                burst.to_string(),
                q.to_string(),
                kind.to_string(),
                format!("{:.6}", sw.elapsed_secs()),
            ]);
        }
    }
    t
}

/// Fig. 16: single-machine Landscape vs GraphZeppelin-mode, thread
/// sweep via the measured-cost model plus a real measured 1-thread run.
pub fn fig16_single_machine(quick: bool) -> Table {
    let name = if quick { "kron10" } else { "kron11" };
    let d = datasets::by_name(name).unwrap();
    let v = d.model.num_vertices();
    let samples = if quick { 80_000 } else { 300_000 };

    let landscape =
        measure_stage_costs(v, samples, KernelKind::Cameo, BufferingKind::Hypertree);
    let zeppelin = measure_stage_costs(v, samples, KernelKind::Cube, BufferingKind::Gutter);

    let mut t = Table::new(
        "Fig 16 — single-machine scaling (measured costs + model)",
        &["system", "threads", "rate_updates_per_sec"],
    );
    for threads in [1u32, 2, 4, 8, 16, 32, 64, 96, 128, 192] {
        // single machine: main-node work shares the same threads as
        // delta computation — model as 1 worker with `threads` threads
        // where the main stage parallelizes up to 4 ingest threads
        let ls_main = landscape.main_per_update / (threads.min(4) as f64)
            + landscape.merge_per_update;
        let ls = 1.0 / ls_main.max(landscape.worker_per_update / threads as f64);
        let gz_main =
            zeppelin.main_per_update + zeppelin.merge_per_update; // gutter is contention-bound
        let gz = 1.0 / gz_main.max(zeppelin.worker_per_update / threads as f64);
        t.row(vec![
            "landscape".to_string(),
            threads.to_string(),
            format!("{:.0}", ls),
        ]);
        t.row(vec![
            "graphzeppelin-mode".to_string(),
            threads.to_string(),
            format!("{:.0}", gz),
        ]);
    }
    t
}

/// Measured end-to-end single-core ingestion on a real coordinator —
/// used by Fig. 3/16 narration and EXPERIMENTS.md.
pub fn measured_ingestion_rate(dataset: &str, max_updates: u64) -> (u64, f64) {
    let d = datasets::by_name(dataset).expect("unknown dataset");
    let mut cfg = CoordinatorConfig::for_vertices(d.model.num_vertices());
    cfg.alpha = 2;
    cfg.use_greedycc = false;
    let session = Landscape::from_config(cfg).unwrap();
    let mut ingest = session.ingest_handle();
    let sw = Stopwatch::new();
    let mut n = 0u64;
    for u in d.stream() {
        ingest.ingest(u);
        n += 1;
        if n >= max_updates {
            break;
        }
    }
    ingest.flush();
    session.flush(); // rate counts until sketches are current
    (n, sw.elapsed_secs())
}
