//! Experiment harnesses — one per table/figure in the paper's
//! evaluation (see DESIGN.md "Per-experiment index").  Each returns a
//! [`Table`] whose CSV regenerates the figure's data series; the
//! `landscape bench <exp>` CLI and the `benches/` targets both call in
//! here.

pub mod figures;
pub mod tables;

pub use figures::*;
pub use tables::*;

use crate::benchkit::Table;

/// Where CSV outputs land.
pub fn results_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/results"))
}

/// Emit a table to stderr/stdout and `results/<name>.csv`.
pub fn emit(table: &Table, name: &str) {
    table.emit(Some(&results_dir().join(format!("{name}.csv"))));
}

/// Run an experiment by its CLI name.  Returns false if unknown.
pub fn run_by_name(name: &str, quick: bool) -> bool {
    match name {
        "fig1" => emit(&figures::fig1_survey(), "fig1_survey"),
        "fig3" => emit(&figures::fig3_scaling(quick), "fig3_scaling"),
        "fig4" => emit(&figures::fig4_ablation(quick), "fig4_ablation"),
        "fig5" => emit(&figures::fig5_query_bursts(quick), "fig5_query_bursts"),
        "fig16" => emit(&figures::fig16_single_machine(quick), "fig16_single_machine"),
        "table2" => emit(&tables::table2_datasets(quick), "table2_datasets"),
        "table3" => emit(&tables::table3_ingestion(quick), "table3_ingestion"),
        "table4" => emit(&tables::table4_kconn(quick), "table4_kconn"),
        "table5" => emit(&tables::table5_kconn_all(quick), "table5_kconn_all"),
        "table6" => emit(&tables::table6_success_prob(), "table6_success_prob"),
        "correctness" => emit(&tables::correctness(quick), "correctness"),
        "all" => {
            for exp in [
                "fig1", "table2", "table6", "fig3", "fig4", "fig5", "table3", "table4",
                "fig16", "correctness",
            ] {
                crate::log_info!("### running {exp} ###");
                run_by_name(exp, quick);
            }
        }
        _ => return false,
    }
    true
}

/// Names accepted by [`run_by_name`].
pub const EXPERIMENTS: &[&str] = &[
    "fig1", "fig3", "fig4", "fig5", "fig16", "table2", "table3", "table4", "table5",
    "table6", "correctness", "all",
];
