//! Table harnesses: Table 2 (datasets), Table 3 (ingestion + comm),
//! Table 4/5 (k-connectivity), Table 6 (success probability), and the
//! App. F.2 correctness experiment.

use crate::analysis::success_prob;
use crate::baseline::Referee;
use crate::benchkit::{fmt_bytes, fmt_rate, Table};
use crate::coordinator::CoordinatorConfig;
use crate::session::Landscape;
use crate::stream::datasets::{self, Dataset};
use crate::stream::{count_edges, EdgeModel};
use crate::util::timer::Stopwatch;

/// Table 2: the dataset inventory (scaled stand-ins; exact edge counts
/// for small models, expected counts for the rest).
pub fn table2_datasets(quick: bool) -> Table {
    let mut t = Table::new(
        "Table 2 — datasets (scaled; see DESIGN.md)",
        &["name", "stands_for", "vertices", "edges", "stream_updates"],
    );
    let names = if quick {
        datasets::quick_names()
    } else {
        datasets::all_names()
    };
    for name in names {
        let d = datasets::by_name(name).unwrap();
        let v = d.model.num_vertices();
        // exact count affordable below ~2^24 candidate pairs
        let edges = if v * v <= (1 << 24) {
            count_edges(&d.model) as f64
        } else {
            d.model.expected_edges()
        };
        t.row(vec![
            d.name.to_string(),
            d.paper_name.to_string(),
            v.to_string(),
            format!("{edges:.3e}"),
            format!("{:.3e}", edges * d.repeats as f64),
        ]);
    }
    t
}

/// One measured coordinator run over (a prefix of) a dataset stream.
pub struct RunResult {
    pub updates: u64,
    pub seconds: f64,
    pub comm_factor: f64,
    pub sketch_bytes: usize,
    pub query_secs: f64,
    pub network_bytes: u64,
}

/// Drive a full ingest + final query run.
pub fn run_dataset(d: &Dataset, k: u32, max_updates: u64) -> RunResult {
    let mut cfg = CoordinatorConfig::for_vertices(d.model.num_vertices());
    cfg.k = k;
    cfg.alpha = 1;
    cfg.use_greedycc = false; // measure the sketch path, as the paper does
    let session = Landscape::from_config(cfg).unwrap();
    let mut ingest = session.ingest_handle();
    let queries = session.query_handle();

    let sw = Stopwatch::new();
    let mut n = 0u64;
    for u in d.stream() {
        ingest.ingest(u);
        n += 1;
        if n >= max_updates {
            break;
        }
    }
    // the paper's metric: wall clock until all updates are *applied to
    // the sketches*, i.e. including the drain of in-flight batches
    ingest.flush();
    session.flush();
    let ingest_secs = sw.elapsed_secs();

    let qsw = Stopwatch::new();
    if k == 1 {
        let _ = queries.full_connectivity_query();
    } else {
        let _ = queries.k_connectivity();
    }
    let query_secs = qsw.elapsed_secs();

    let m = session.metrics();
    RunResult {
        updates: n,
        seconds: ingest_secs,
        comm_factor: m.communication_factor(),
        sketch_bytes: session.sketch_bytes(),
        query_secs,
        network_bytes: m.network_bytes(),
    }
}

/// Table 3: ingestion rate + communication factor per dataset
/// (single-core measured; the paper's 640-thread rates scale per Fig. 3).
pub fn table3_ingestion(quick: bool) -> Table {
    let names = if quick {
        datasets::quick_names()
    } else {
        datasets::all_names()
    };
    let cap = if quick { 2_000_000 } else { 20_000_000 };
    let mut t = Table::new(
        "Table 3 — ingestion rate and communication factor (measured)",
        &[
            "dataset",
            "updates",
            "rate_updates_per_sec",
            "comm_factor",
            "sketch_bytes",
        ],
    );
    for name in names {
        let d = datasets::by_name(name).unwrap();
        let r = run_dataset(&d, 1, cap);
        crate::log_info!(
            "{name}: {} updates at {} (comm {:.2}x, sketch {})",
            r.updates,
            fmt_rate(r.updates as f64 / r.seconds),
            r.comm_factor,
            fmt_bytes(r.sketch_bytes as f64),
        );
        t.row(vec![
            name.to_string(),
            r.updates.to_string(),
            format!("{:.0}", r.updates as f64 / r.seconds),
            format!("{:.3}", r.comm_factor),
            r.sketch_bytes.to_string(),
        ]);
    }
    t
}

/// Table 4: k-connectivity scaling in k on one kron dataset.
pub fn table4_kconn(quick: bool) -> Table {
    let name = if quick { "kron10" } else { "kron11" };
    let d = datasets::by_name(name).unwrap();
    let cap = if quick { 1_500_000 } else { 8_000_000 };
    let mut t = Table::new(
        "Table 4 — k-connectivity vs k (measured)",
        &[
            "k",
            "rate_updates_per_sec",
            "sketch_bytes",
            "query_secs",
            "network_bytes",
        ],
    );
    for k in [1u32, 2, 4, 8] {
        let r = run_dataset(&d, k, cap);
        crate::log_info!(
            "k={k}: rate {}, sketch {}, query {:.3}s, net {}",
            fmt_rate(r.updates as f64 / r.seconds),
            fmt_bytes(r.sketch_bytes as f64),
            r.query_secs,
            fmt_bytes(r.network_bytes as f64),
        );
        t.row(vec![
            k.to_string(),
            format!("{:.0}", r.updates as f64 / r.seconds),
            r.sketch_bytes.to_string(),
            format!("{:.4}", r.query_secs),
            r.network_bytes.to_string(),
        ]);
    }
    t
}

/// Table 5: k-connectivity across datasets.
pub fn table5_kconn_all(quick: bool) -> Table {
    let names = if quick {
        &["kron10", "gnutella", "googleplus"][..]
    } else {
        datasets::quick_names()
    };
    let cap = if quick { 1_000_000 } else { 4_000_000 };
    let mut t = Table::new(
        "Table 5 — k-connectivity across datasets (measured)",
        &["dataset", "k", "rate_updates_per_sec", "sketch_bytes", "query_secs"],
    );
    for name in names {
        for k in [1u32, 2, 4] {
            let d = datasets::by_name(name).unwrap();
            let r = run_dataset(&d, k, cap);
            t.row(vec![
                name.to_string(),
                k.to_string(),
                format!("{:.0}", r.updates as f64 / r.seconds),
                r.sketch_bytes.to_string(),
                format!("{:.4}", r.query_secs),
            ]);
        }
    }
    t
}

/// Table 6: CameoSketch column success probability — analytic recurrence
/// + Monte-Carlo cross-check with the real update rule.
pub fn table6_success_prob() -> Table {
    let mut t = Table::new(
        "Table 6 — CameoSketch column success probability (10 buckets)",
        &["nonzeros", "recurrence_F", "monte_carlo"],
    );
    for (z, f) in success_prob::table6_rows() {
        let mc = success_prob::monte_carlo_success(z, 10, 60_000, 0xCAFE);
        t.row(vec![
            z.to_string(),
            format!("{f:.4}"),
            format!("{mc:.4}"),
        ]);
    }
    t
}

/// App. F.2 correctness: the sketched spanning forest must induce the
/// exact component partition, across repeated randomized trials.
pub fn correctness(quick: bool) -> Table {
    let trials = if quick { 10 } else { 100 };
    let names = ["kron10", "gnutella-small", "erdos11"];
    let mut t = Table::new(
        "App F.2 — correctness trials (sketch partition vs exact referee)",
        &["dataset", "trials", "failures"],
    );
    for name in names {
        let mut failures = 0;
        for trial in 0..trials {
            // smaller stand-ins so many trials stay fast
            let (v, model): (u64, Box<dyn EdgeModel>) = match name {
                "kron10" => (
                    1 << 10,
                    Box::new(crate::stream::kron::Kronecker::paper(10, trial as u64)),
                ),
                "gnutella-small" => (
                    4096,
                    Box::new(crate::stream::realworld::SparseRandom::new(
                        4096,
                        4.8,
                        trial as u64,
                    )),
                ),
                _ => (
                    1 << 11,
                    Box::new(crate::stream::erdos::ErdosRenyi::new(
                        1 << 11,
                        0.25,
                        trial as u64,
                    )),
                ),
            };

            let mut cfg = CoordinatorConfig::for_vertices(v);
            cfg.graph_seed = 0xBEEF ^ (trial as u64) << 8;
            cfg.alpha = 1;
            cfg.use_greedycc = false;
            let session = Landscape::from_config(cfg).unwrap();
            let mut ingest = session.ingest_handle();
            let mut referee = Referee::new(v);
            let stream = crate::stream::dynamify::Dynamify::new(ModelRef(&*model), 3);
            for u in stream {
                referee.apply(&u);
                ingest.ingest(u);
            }
            ingest.flush();
            let forest = session.query_handle().full_connectivity_query();
            if !Referee::same_partition(&forest.component, &referee.component_map()) {
                failures += 1;
            }
        }
        crate::log_info!("{name}: {failures}/{trials} failures");
        t.row(vec![name.to_string(), trials.to_string(), failures.to_string()]);
    }
    t
}

/// Borrowed-model adapter for Dynamify.
struct ModelRef<'a>(&'a dyn EdgeModel);
impl<'a> EdgeModel for ModelRef<'a> {
    fn num_vertices(&self) -> u64 {
        self.0.num_vertices()
    }
    fn contains(&self, a: u32, b: u32) -> bool {
        self.0.contains(a, b)
    }
    fn expected_edges(&self) -> f64 {
        self.0.expected_edges()
    }
}

/// The adjacency-matrix comparison of §2.1 (used by the micro bench and
/// EXPERIMENTS.md): raw update throughput of bit-flips vs sketch
/// ingestion, plus the space crossover.
pub fn adjacency_matrix_comparison(v: u64, updates: u64) -> (f64, f64) {
    use crate::baseline::AdjacencyMatrix;
    use crate::stream::update::Update;
    let mut m = AdjacencyMatrix::new(v);
    let mut rng = crate::util::rng::Xoshiro256::new(1);
    let ups: Vec<Update> = (0..updates)
        .map(|_| {
            let a = rng.next_below(v - 1) as u32;
            let b = a + 1 + rng.next_below(v - 1 - a as u64) as u32;
            Update::insert(a, b)
        })
        .collect();
    let sw = Stopwatch::new();
    for u in &ups {
        m.apply(u);
    }
    let matrix_rate = updates as f64 / sw.elapsed_secs();
    std::hint::black_box(&m);

    let (n, secs) = crate::experiments::figures::measured_ingestion_rate(
        "kron10",
        updates.min(2_000_000),
    );
    (matrix_rate, n as f64 / secs)
}
