//! The spill backing: bounded-resident sketch blocks over fixed-size
//! segment files.
//!
//! One [`SpillBacking`] holds **one sketch copy's** state (a
//! `KConnectivity` with k copies owns k of them, one per
//! `SketchStore`).  The unit of paging is the per-vertex sketch
//! *block*: the vertex's full `params.words()`-long bucket array, plus
//! an 8-byte LSN header on disk:
//!
//! ```text
//! dir/seg-0000.bin, seg-0001.bin, ...       (fixed-size, sparse)
//! block(u)  := segment[u / blocks_per_segment]
//!              at offset (u % blocks_per_segment) × (8 + words×8)
//! on disk   := [u64 le LSN] [words × u64 le buckets]
//! ```
//!
//! The LSN is the WAL **end offset** of the last logged record folded
//! into the block — recovery replays a WAL record into a block only
//! when `record_end > block.lsn`, which makes replay idempotent over
//! blocks that were evicted (and therefore persisted) after the last
//! durable cut.  See `docs/STORAGE.md` for the full argument.
//!
//! Write path (per shard-aligned stripe, single distributor writer):
//! a **resident** block is XOR-merged in place; a **cold** vertex's
//! first touch parks the delta in the stripe's [`DeltaGutter`]
//! (write-optimized buffering — no I/O); a second touch while parked
//! faults the block in, folds the parked delta, and promotes the block
//! to resident-hot.  Gutters flush to segments in vertex-sorted
//! sequential sweeps at ticket-retire points ([`SpillBacking::maintain`]).
//! Reads never populate the LRU: queries range-read straight from the
//! segment (plus the parked gutter delta), so a Borůvka sweep over V
//! cold vertices cannot thrash the hot set.
//!
//! A pwrite/pread failure on the hot merge path is unrecoverable (the
//! in-memory state can no longer be made durable), so those paths
//! panic with context instead of threading `io::Result` through every
//! sketch-merge signature; setup/checkpoint/recovery paths return
//! `io::Result` normally.

use std::collections::{HashMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::gutter::DeltaGutter;
use crate::sketch::shard::ShardSpec;

/// Sizing and placement knobs for a [`SpillBacking`].
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Directory holding this copy's segment files (created on open).
    pub dir: PathBuf,
    /// Resident block budget in **sketch bytes** (`words × 8` per
    /// block, headers and map overhead excluded — the same accounting
    /// as the `resident_sketch_bytes` gauge).  `u64::MAX` disables
    /// eviction (durability-only mode).
    pub resident_budget_bytes: u64,
    /// Blocks per segment file; fixes every segment's size at
    /// `blocks_per_segment × (8 + words×8)` bytes.
    pub blocks_per_segment: u32,
}

impl SpillConfig {
    /// A config with the default segment geometry (1024 blocks per
    /// segment).
    pub fn new(dir: PathBuf, resident_budget_bytes: u64) -> Self {
        Self {
            dir,
            resident_budget_bytes,
            blocks_per_segment: 1024,
        }
    }
}

/// A resident (in-memory) copy of one vertex's sketch block.
struct Block {
    words: Box<[u64]>,
    /// WAL end offset of the last logged record folded in (what gets
    /// persisted in the on-disk header on eviction/checkpoint).
    lsn: u64,
    /// Lazy-LRU stamp: matches the newest queue entry for this vertex.
    stamp: u64,
    dirty: bool,
}

/// One shard-aligned stripe: the single-writer unit of the spill tier,
/// mirroring the sketch store's shard ownership.
struct Stripe {
    resident: HashMap<u32, Block>,
    /// Lazy-deletion LRU: (vertex, stamp) pairs in touch order; stale
    /// entries (stamp mismatch) are skipped at eviction time.
    lru: VecDeque<(u32, u64)>,
    gutter: DeltaGutter,
    clock: u64,
    /// Sketch bytes held by `resident` (gauge + budget input).
    resident_bytes: u64,
    /// Largest record-end LSN hint seen by this stripe — the stamp for
    /// gutter-flushed blocks, whose individual record hints are folded
    /// away (always ≥ every contributing record's end offset because
    /// the stripe has a single logging writer).
    max_lsn: u64,
}

/// Panic with context on an unrecoverable hot-path storage error.
fn io_ok<T>(r: io::Result<T>, what: &str) -> T {
    match r {
        Ok(v) => v,
        Err(e) => panic!("spill backing: {what} failed: {e}"),
    }
}

/// Bounded-resident, segment-backed storage for one sketch copy.
pub struct SpillBacking {
    words: usize,
    spec: ShardSpec,
    block_bytes: u64,
    blocks_per_segment: u32,
    segment_len: u64,
    /// Per-stripe budget: the store budget divided evenly across the
    /// shard-aligned stripes (round-robin sharding spreads vertices
    /// uniformly, so an even split is the right static partition).
    stripe_budget: u64,
    /// Gutter flush high-water mark per stripe (bytes).
    gutter_hwm: u64,
    segments: Vec<File>,
    stripes: Vec<Mutex<Stripe>>,
    /// WAL end-offset watermark (shared with the session's
    /// `DurabilityLog`); the LSN source for merges that carry no
    /// per-record hint.
    watermark: Arc<AtomicU64>,
    faults: AtomicU64,
    spilled: AtomicU64,
    resident: AtomicU64,
}

impl SpillBacking {
    /// Open (or create) the segment files for `vertices` blocks of
    /// `words` words each under `cfg.dir`.  Existing segment contents
    /// are preserved — recovery reopens the checkpointed files; a
    /// fresh session starts from all-sparse (all-zero, LSN 0) files.
    /// Fresh-vs-stale-directory safety lives one level up, in the
    /// session's WAL `create_new` check.
    pub fn open(
        words: usize,
        vertices: u64,
        spec: ShardSpec,
        cfg: &SpillConfig,
        watermark: Arc<AtomicU64>,
    ) -> io::Result<Self> {
        let block_bytes = 8 + words as u64 * 8;
        let bps = cfg.blocks_per_segment.max(1);
        let segment_len = bps as u64 * block_bytes;
        let num_segments = vertices.div_ceil(bps as u64).max(1);
        std::fs::create_dir_all(&cfg.dir)?;
        let mut segments = Vec::with_capacity(num_segments as usize);
        for i in 0..num_segments {
            let f = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(false)
                .open(cfg.dir.join(format!("seg-{i:04}.bin")))?;
            // eager fixed-size allocation; sparse until written, so a
            // fresh store costs no real disk
            if f.metadata()?.len() < segment_len {
                f.set_len(segment_len)?;
            }
            segments.push(f);
        }
        let shards = spec.count();
        let budget = cfg.resident_budget_bytes;
        let stripe_budget = if budget == u64::MAX {
            u64::MAX
        } else {
            (budget / shards as u64).max(words as u64 * 8)
        };
        let gutter_hwm = if stripe_budget == u64::MAX {
            // durability-only mode still batches cold writes a little
            (words as u64 * 8) * 64
        } else {
            (stripe_budget / 4).max(words as u64 * 8)
        };
        let stripes = (0..shards)
            .map(|_| {
                Mutex::new(Stripe {
                    resident: HashMap::new(),
                    lru: VecDeque::new(),
                    gutter: DeltaGutter::new(words),
                    clock: 0,
                    resident_bytes: 0,
                    max_lsn: 0,
                })
            })
            .collect();
        Ok(Self {
            words,
            spec,
            block_bytes,
            blocks_per_segment: bps,
            segment_len,
            stripe_budget,
            gutter_hwm,
            segments,
            stripes,
            watermark,
            faults: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            resident: AtomicU64::new(0),
        })
    }

    /// Words per block (one sketch copy's full bucket array).
    pub fn words(&self) -> usize {
        self.words
    }

    fn stripe(&self, shard: usize) -> MutexGuard<'_, Stripe> {
        self.stripes[shard].lock().unwrap_or_else(|p| p.into_inner())
    }

    fn seg_of(&self, u: u32) -> (&File, u64) {
        let seg = (u / self.blocks_per_segment) as usize;
        let off = (u % self.blocks_per_segment) as u64 * self.block_bytes;
        (&self.segments[seg], off)
    }

    /// Read vertex `u`'s full on-disk block: `(lsn, words)`.
    fn read_block(&self, u: u32) -> io::Result<(u64, Box<[u64]>)> {
        let (file, off) = self.seg_of(u);
        let mut buf = vec![0u8; self.block_bytes as usize];
        file.read_exact_at(&mut buf, off)?;
        let lsn = u64::from_le_bytes(buf[..8].try_into().map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData, "block header slice")
        })?);
        let words = buf[8..]
            .chunks_exact(8)
            .map(|c| {
                u64::from_le_bytes(c.try_into().unwrap_or([0; 8]))
            })
            .collect();
        Ok((lsn, words))
    }

    /// Write vertex `u`'s block (header + words) back to its segment.
    fn write_block(&self, u: u32, lsn: u64, words: &[u64]) -> io::Result<()> {
        let (file, off) = self.seg_of(u);
        let mut buf = Vec::with_capacity(self.block_bytes as usize);
        buf.extend_from_slice(&lsn.to_le_bytes());
        for w in words {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        file.write_all_at(&buf, off)?;
        // lint: allow(relaxed-ordering) — monotone statistics counter
        self.spilled.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn touch(&self, st: &mut Stripe, u: u32) {
        st.clock += 1;
        let stamp = st.clock;
        if let Some(b) = st.resident.get_mut(&u) {
            b.stamp = stamp;
        }
        st.lru.push_back((u, stamp));
    }

    fn insert_resident(&self, st: &mut Stripe, u: u32, block: Block) {
        let bytes = (self.words * 8) as u64;
        st.resident.insert(u, block);
        st.resident_bytes += bytes;
        // lint: allow(relaxed-ordering) — gauge source, read off-path
        self.resident.fetch_add(bytes, Ordering::Relaxed);
        self.touch(st, u);
    }

    /// Evict least-recently-used blocks until the stripe is back under
    /// its budget, writing dirty ones through to their segments.
    fn evict_to_budget(&self, st: &mut Stripe) {
        let bytes = (self.words * 8) as u64;
        while st.resident_bytes > self.stripe_budget {
            let Some((u, stamp)) = st.lru.pop_front() else {
                break;
            };
            let stale = st.resident.get(&u).map(|b| b.stamp != stamp).unwrap_or(true);
            if stale {
                continue; // lazy-deletion entry superseded by a newer touch
            }
            let Some(b) = st.resident.remove(&u) else {
                continue;
            };
            st.resident_bytes -= bytes;
            // lint: allow(relaxed-ordering) — gauge source, read off-path
            self.resident.fetch_sub(bytes, Ordering::Relaxed);
            if b.dirty {
                io_ok(self.write_block(u, b.lsn, &b.words), "eviction writeback");
            }
        }
    }

    /// Flush the stripe's gutter: fold each parked delta into its
    /// on-disk block in one vertex-sorted sequential sweep.  Flushed
    /// blocks are stamped with the stripe's `max_lsn` (≥ every
    /// contributing record's end offset — single logging writer).
    fn flush_gutter(&self, st: &mut Stripe) {
        if st.gutter.is_empty() {
            return;
        }
        let stamp = st.max_lsn;
        for (u, delta) in st.gutter.drain_sorted() {
            let (lsn, mut words) = io_ok(self.read_block(u), "gutter flush read");
            for (w, d) in words.iter_mut().zip(delta.iter()) {
                *w ^= d;
            }
            io_ok(
                self.write_block(u, lsn.max(stamp), &words),
                "gutter flush write",
            );
        }
    }

    /// XOR-merge `delta` into vertex `u`'s block.  `lsn` is the WAL
    /// end offset of the logged record this delta came from (pass the
    /// current watermark for unlogged merges — safe because unlogged
    /// mutation paths run with no appended-but-unmerged records in
    /// flight; see the module docs).
    pub fn merge_delta(&self, u: u32, delta: &[u64], lsn: u64) {
        debug_assert_eq!(delta.len(), self.words);
        let shard = self.spec.shard_of(u);
        let mut st = self.stripe(shard);
        st.max_lsn = st.max_lsn.max(lsn);
        if let Some(b) = st.resident.get_mut(&u) {
            for (w, d) in b.words.iter_mut().zip(delta) {
                *w ^= d;
            }
            b.dirty = true;
            b.lsn = b.lsn.max(lsn);
            self.touch(&mut st, u);
        } else if st.gutter.contains(u) {
            // second touch while parked: this vertex is warming up —
            // fault the block in and promote it to resident-hot
            let (disk_lsn, mut words) = io_ok(self.read_block(u), "fault-in read");
            // lint: allow(relaxed-ordering) — monotone statistics counter
            self.faults.fetch_add(1, Ordering::Relaxed);
            if let Some(parked) = st.gutter.take(u) {
                for (w, d) in words.iter_mut().zip(parked.iter()) {
                    *w ^= d;
                }
            }
            for (w, d) in words.iter_mut().zip(delta) {
                *w ^= d;
            }
            self.insert_resident(
                &mut st,
                u,
                Block {
                    words,
                    lsn: disk_lsn.max(lsn),
                    stamp: 0, // insert_resident's touch re-stamps
                    dirty: true,
                },
            );
        } else {
            // cold first touch: park the delta, no I/O
            st.gutter.xor(u, delta);
        }
        if st.gutter.bytes() > self.gutter_hwm * 4 {
            // backstop between maintain() calls so a pathological cold
            // stream cannot grow the gutter unboundedly
            self.flush_gutter(&mut st);
        }
        self.evict_to_budget(&mut st);
    }

    /// Read `dst.len()` words of vertex `u`'s block starting at word
    /// `word_off`, without populating the resident set (query sweeps
    /// must not thrash the hot LRU).  Folds in any parked gutter delta
    /// so reads always see un-flushed updates.
    pub fn read_words_into(&self, u: u32, word_off: usize, dst: &mut [u64]) {
        debug_assert!(word_off + dst.len() <= self.words);
        let shard = self.spec.shard_of(u);
        let st = self.stripe(shard);
        if let Some(b) = st.resident.get(&u) {
            dst.copy_from_slice(&b.words[word_off..word_off + dst.len()]);
            return;
        }
        let (file, off) = self.seg_of(u);
        let mut buf = vec![0u8; dst.len() * 8];
        io_ok(
            file.read_exact_at(&mut buf, off + 8 + word_off as u64 * 8),
            "query range read",
        );
        for (d, c) in dst.iter_mut().zip(buf.chunks_exact(8)) {
            *d = u64::from_le_bytes(c.try_into().unwrap_or([0; 8]));
        }
        if let Some(parked) = st.gutter.peek(u) {
            for (d, p) in dst.iter_mut().zip(parked[word_off..].iter()) {
                *d ^= p;
            }
        }
    }

    /// Ticket-retire maintenance for one shard's stripe: flush the
    /// gutter once it crosses the high-water mark, then re-enforce the
    /// budget.  Called by the owning distributor between batches so
    /// flush I/O happens at scheduling points, not mid-merge.
    pub fn maintain(&self, shard: usize) {
        let mut st = self.stripe(shard);
        if st.gutter.bytes() > self.gutter_hwm {
            self.flush_gutter(&mut st);
        }
        self.evict_to_budget(&mut st);
    }

    /// Replay one WAL record's delta during recovery: fold it into the
    /// block **only if** `record_end > block.lsn` (the idempotence
    /// rule).  Uses the disk block directly — recovery runs
    /// single-threaded with empty gutters.  Returns whether the record
    /// was applied.
    pub fn replay_delta(&self, u: u32, delta: &[u64], record_end: u64) -> io::Result<bool> {
        debug_assert_eq!(delta.len(), self.words);
        let shard = self.spec.shard_of(u);
        let mut st = self.stripe(shard);
        st.max_lsn = st.max_lsn.max(record_end);
        if let Some(b) = st.resident.get_mut(&u) {
            if record_end <= b.lsn {
                return Ok(false);
            }
            for (w, d) in b.words.iter_mut().zip(delta) {
                *w ^= d;
            }
            b.lsn = record_end;
            b.dirty = true;
            return Ok(true);
        }
        let (disk_lsn, mut words) = self.read_block(u)?;
        if record_end <= disk_lsn {
            return Ok(false);
        }
        for (w, d) in words.iter_mut().zip(delta) {
            *w ^= d;
        }
        self.insert_resident(
            &mut st,
            u,
            Block {
                words,
                lsn: record_end,
                stamp: 0,
                dirty: true,
            },
        );
        self.evict_to_budget(&mut st);
        Ok(true)
    }

    /// Write every un-persisted mutation through to the segment files
    /// and fsync them — the segment half of the durable-cut contract
    /// (the caller then appends + fsyncs the WAL cut marker).  Blocks
    /// stay resident; only their dirty bits clear.
    pub fn checkpoint(&self) -> io::Result<()> {
        for stripe in &self.stripes {
            let mut st = stripe.lock().unwrap_or_else(|p| p.into_inner());
            self.flush_gutter(&mut st);
            let mut dirty: Vec<u32> = st
                .resident
                .iter()
                .filter(|(_, b)| b.dirty)
                .map(|(u, _)| *u)
                .collect();
            dirty.sort_unstable(); // sequential sweep per segment
            for u in dirty {
                if let Some(b) = st.resident.get_mut(&u) {
                    self.write_block(u, b.lsn, &b.words)?;
                    b.dirty = false;
                }
            }
        }
        for f in &self.segments {
            f.sync_data()?;
        }
        Ok(())
    }

    /// Reset to the empty-sketch state: drop every resident block and
    /// parked delta and re-sparse the segment files (all zeros, LSN 0).
    /// Not WAL-logged — a test/maintenance utility, like the resident
    /// store's `clear`.
    pub fn clear(&self) {
        for stripe in &self.stripes {
            let mut st = stripe.lock().unwrap_or_else(|p| p.into_inner());
            let bytes = st.resident_bytes;
            st.resident.clear();
            st.lru.clear();
            st.gutter.clear();
            st.resident_bytes = 0;
            st.clock = 0;
            st.max_lsn = 0;
            // lint: allow(relaxed-ordering) — gauge source, read off-path
            self.resident.fetch_sub(bytes, Ordering::Relaxed);
        }
        for f in &self.segments {
            io_ok(f.set_len(0), "segment truncate");
            io_ok(f.set_len(self.segment_len), "segment re-sparse");
        }
    }

    /// Current WAL watermark (the LSN hint for unlogged merges).
    pub fn watermark_now(&self) -> u64 {
        // lint: allow(relaxed-ordering) — monotone hint; stale reads only under-stamp, repaired by the max() folds
        self.watermark.load(Ordering::Relaxed)
    }

    /// Sketch bytes currently resident across all stripes (the
    /// `resident_sketch_bytes` gauge source).
    pub fn resident_bytes(&self) -> u64 {
        // lint: allow(relaxed-ordering) — gauge read
        self.resident.load(Ordering::Relaxed)
    }

    /// Cold blocks faulted in from segments since open.
    pub fn block_faults(&self) -> u64 {
        // lint: allow(relaxed-ordering) — statistics read
        self.faults.load(Ordering::Relaxed)
    }

    /// Bytes written to segment files since open (evictions, gutter
    /// flushes, checkpoints).
    pub fn spill_bytes_written(&self) -> u64 {
        // lint: allow(relaxed-ordering) — statistics read
        self.spilled.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "landscape_spill_{}_{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn backing(name: &str, words: usize, vertices: u64, budget: u64) -> SpillBacking {
        let cfg = SpillConfig {
            dir: tmp(name),
            resident_budget_bytes: budget,
            blocks_per_segment: 8, // small segments exercise seg math
        };
        SpillBacking::open(
            words,
            vertices,
            ShardSpec::new(2),
            &cfg,
            Arc::new(AtomicU64::new(0)),
        )
        .unwrap()
    }

    fn read_all(b: &SpillBacking, u: u32) -> Vec<u64> {
        let mut out = vec![0u64; b.words()];
        b.read_words_into(u, 0, &mut out);
        out
    }

    #[test]
    fn merge_read_roundtrip_through_gutter_and_fault() {
        let b = backing("roundtrip", 4, 32, u64::MAX);
        // first touch parks in the gutter; reads must still see it
        b.merge_delta(5, &[1, 2, 3, 4], 10);
        assert_eq!(read_all(&b, 5), vec![1, 2, 3, 4]);
        assert_eq!(b.block_faults(), 0);
        // second touch faults in and folds both deltas
        b.merge_delta(5, &[8, 0, 0, 1], 20);
        assert_eq!(b.block_faults(), 1);
        assert_eq!(read_all(&b, 5), vec![9, 2, 3, 5]);
        // partial-range read
        let mut mid = vec![0u64; 2];
        b.read_words_into(5, 1, &mut mid);
        assert_eq!(mid, vec![2, 3]);
        // untouched vertex reads all-zero
        assert_eq!(read_all(&b, 6), vec![0; 4]);
    }

    #[test]
    fn budget_is_enforced_and_evicted_blocks_survive_on_disk() {
        // budget of exactly 2 blocks per stripe (2 stripes)
        let b = backing("budget", 4, 64, 2 * 2 * 4 * 8);
        for u in 0..32u32 {
            // two touches each → every block becomes resident-hot
            b.merge_delta(u, &[u as u64 + 1, 0, 0, 0], u as u64);
            b.merge_delta(u, &[0, u as u64 + 1, 0, 0], 100 + u as u64);
        }
        assert!(
            b.resident_bytes() <= 2 * 2 * 4 * 8,
            "resident {} exceeds budget",
            b.resident_bytes()
        );
        assert!(b.spill_bytes_written() > 0, "evictions must write through");
        // every vertex — evicted or resident — still reads back exactly
        for u in 0..32u32 {
            assert_eq!(read_all(&b, u), vec![u as u64 + 1, u as u64 + 1, 0, 0]);
        }
    }

    #[test]
    fn maintain_flushes_the_gutter_sequentially() {
        let b = backing("maintain", 2, 64, 2 * 2 * 8);
        // park many cold single-touch vertices (never fault)
        for u in 0..40u32 {
            b.merge_delta(u, &[u as u64, 7], u as u64);
        }
        assert_eq!(b.block_faults(), 0);
        b.maintain(0);
        b.maintain(1);
        assert!(b.spill_bytes_written() > 0);
        for u in 0..40u32 {
            assert_eq!(read_all(&b, u), vec![u as u64, 7]);
        }
    }

    #[test]
    fn replay_is_idempotent_over_persisted_lsns() {
        let b = backing("replay", 2, 16, u64::MAX);
        // live-merge a logged record, checkpoint it to disk
        b.merge_delta(3, &[5, 5], 100);
        b.checkpoint().unwrap();
        // a replay of the same record (end=100) must be a no-op...
        assert!(!b.replay_delta(3, &[5, 5], 100).unwrap());
        assert_eq!(read_all(&b, 3), vec![5, 5]);
        // ...while a later record replays exactly once
        assert!(b.replay_delta(3, &[1, 0], 150).unwrap());
        assert!(!b.replay_delta(3, &[1, 0], 150).unwrap());
        assert_eq!(read_all(&b, 3), vec![4, 5]);
    }

    #[test]
    fn checkpoint_then_reopen_recovers_all_state() {
        let dir = tmp("reopen");
        let cfg = SpillConfig {
            dir: dir.clone(),
            resident_budget_bytes: u64::MAX,
            blocks_per_segment: 4,
        };
        let wm = Arc::new(AtomicU64::new(0));
        let b = SpillBacking::open(3, 20, ShardSpec::new(2), &cfg, wm.clone()).unwrap();
        for u in 0..20u32 {
            b.merge_delta(u, &[u as u64, 1, 2], u as u64 + 1);
        }
        b.checkpoint().unwrap();
        drop(b);
        let b2 = SpillBacking::open(3, 20, ShardSpec::new(2), &cfg, wm).unwrap();
        for u in 0..20u32 {
            assert_eq!(read_all(&b2, u), vec![u as u64, 1, 2]);
        }
        // LSNs survived the checkpoint: pre-checkpoint records skip
        assert!(!b2.replay_delta(7, &[9, 9, 9], 8).unwrap());
        assert_eq!(read_all(&b2, 7), vec![7, 1, 2]);
    }

    #[test]
    fn clear_resets_memory_and_disk() {
        let b = backing("clear", 2, 16, u64::MAX);
        b.merge_delta(1, &[1, 1], 5);
        b.merge_delta(1, &[2, 0], 6);
        b.checkpoint().unwrap();
        b.clear();
        assert_eq!(b.resident_bytes(), 0);
        assert_eq!(read_all(&b, 1), vec![0, 0]);
        // post-clear, old LSNs are gone: any record replays
        assert!(b.replay_delta(1, &[3, 3], 1).unwrap());
        assert_eq!(read_all(&b, 1), vec![3, 3]);
    }
}
