//! The external-memory storage tier: pluggable backings for the
//! sketch store, write-ahead durability, and crash recovery.
//!
//! The paper's "dense graphs previously prohibitively expensive to
//! study" claim assumes sketch state fits in RAM; the ROADMAP
//! north-star (V ≥ 2^20 on commodity hardware) does not.  Following
//! GraphZeppelin (arXiv 2203.14927) and *The Case for External Graph
//! Sketching* (arXiv 2504.17563), this module makes the sketch store's
//! storage a trait with two implementations:
//!
//! * [`ResidentBacking`] — the existing all-in-RAM dense atomic
//!   arrays.  It is *defined in* `sketch/store.rs` (its relaxed-atomic
//!   merge kernels are whitelisted there by `landscape_lint`'s
//!   Relaxed-ordering rule) and re-exported here as part of the
//!   storage surface.
//! * [`SpillBacking`] — a bounded LRU set of hot per-vertex blocks
//!   over fixed-size segment files, with gutter-buffered cold writes
//!   ([`crate::gutter::DeltaGutter`]).
//!
//! Durability is layered on top by the [`wal`] module: every logged
//! batch delta is appended to a [`DurabilityLog`] before it merges,
//! the log is fsync'd at epoch cuts (so `cut()` doubles as a
//! durability point), and [`replay_into`] reconstructs post-crash
//! state by replaying the WAL tail past the last durable cut over the
//! checkpointed segments — idempotently, via per-block LSNs.  The full
//! layout and the recovery argument live in `docs/STORAGE.md`.

#![deny(missing_docs)]

pub mod spill;
pub mod wal;

pub use crate::sketch::store::ResidentBacking;
pub use spill::{SpillBacking, SpillConfig};
pub use wal::{scan, Appended, DurabilityLog, WalRecord, WalScan, WalWriter};

use std::io;
use std::path::Path;

use crate::sketch::store::SketchStore;
use crate::sketch::CameoSketch;

/// The storage surface one sketch copy's state lives behind.
///
/// Implementations must preserve XOR-merge semantics: `merge_delta`
/// folds `delta` into vertex `u`'s full block, and `read_words_into`
/// returns exactly the words every prior merge has produced (including
/// any still buffered in a gutter).  The `lsn` parameter is the WAL
/// end offset of the logged record a delta came from — purely-resident
/// implementations ignore it; spilling implementations persist it per
/// block so recovery replay is idempotent.
pub trait SketchBacking {
    /// Words per vertex block (`params.words()` of the owning store).
    fn words(&self) -> usize;
    /// XOR-merge a full-block `delta` into vertex `u`, tagging the
    /// mutation with WAL end offset `lsn` (ignored when not spilling).
    fn merge_delta(&self, u: u32, delta: &[u64], lsn: u64);
    /// Copy `dst.len()` words of `u`'s block starting at `word_off`.
    fn read_words_into(&self, u: u32, word_off: usize, dst: &mut [u64]);
    /// Scheduling-point maintenance for one shard (gutter flush, LRU
    /// eviction); a no-op for resident backings.
    fn maintain(&self, shard: usize);
    /// Persist all un-persisted state and fsync it (the segment half
    /// of a durable cut); a no-op for resident backings.
    fn checkpoint(&self) -> io::Result<()>;
    /// Reset to the all-zero empty-sketch state.
    fn clear(&self);
    /// Sketch bytes currently resident in memory.
    fn resident_bytes(&self) -> u64;
    /// Cold blocks faulted in from storage (0 when resident).
    fn block_faults(&self) -> u64;
    /// Bytes written through to storage (0 when resident).
    fn spill_bytes_written(&self) -> u64;
}

/// The concrete backing a [`SketchStore`] runs on.
///
/// An enum rather than a `Box<dyn SketchBacking>` so the resident
/// merge hot path keeps its static dispatch and inlined unrolled
/// kernels — the match resolves per call site with no vtable.
pub enum Backing {
    /// All sketch state resident in dense atomic arrays.
    Resident(ResidentBacking),
    /// Bounded-resident blocks over segment files (+ WAL durability).
    Spill(SpillBacking),
}

impl SketchBacking for Backing {
    fn words(&self) -> usize {
        match self {
            Backing::Resident(b) => b.words(),
            Backing::Spill(b) => b.words(),
        }
    }
    fn merge_delta(&self, u: u32, delta: &[u64], lsn: u64) {
        match self {
            Backing::Resident(b) => SketchBacking::merge_delta(b, u, delta, lsn),
            Backing::Spill(b) => b.merge_delta(u, delta, lsn),
        }
    }
    fn read_words_into(&self, u: u32, word_off: usize, dst: &mut [u64]) {
        match self {
            Backing::Resident(b) => b.read_words_into(u, word_off, dst),
            Backing::Spill(b) => b.read_words_into(u, word_off, dst),
        }
    }
    fn maintain(&self, shard: usize) {
        match self {
            Backing::Resident(_) => {}
            Backing::Spill(b) => b.maintain(shard),
        }
    }
    fn checkpoint(&self) -> io::Result<()> {
        match self {
            Backing::Resident(_) => Ok(()),
            Backing::Spill(b) => b.checkpoint(),
        }
    }
    fn clear(&self) {
        match self {
            Backing::Resident(b) => b.clear(),
            Backing::Spill(b) => b.clear(),
        }
    }
    fn resident_bytes(&self) -> u64 {
        match self {
            Backing::Resident(b) => b.resident_bytes(),
            Backing::Spill(b) => b.resident_bytes(),
        }
    }
    fn block_faults(&self) -> u64 {
        match self {
            Backing::Resident(_) => 0,
            Backing::Spill(b) => b.block_faults(),
        }
    }
    fn spill_bytes_written(&self) -> u64 {
        match self {
            Backing::Resident(_) => 0,
            Backing::Spill(b) => b.spill_bytes_written(),
        }
    }
}

impl SketchBacking for ResidentBacking {
    fn words(&self) -> usize {
        ResidentBacking::words(self)
    }
    fn merge_delta(&self, u: u32, delta: &[u64], _lsn: u64) {
        // a resident block is its own durability domain: nothing to tag
        ResidentBacking::merge_delta(self, u, delta)
    }
    fn read_words_into(&self, u: u32, word_off: usize, dst: &mut [u64]) {
        ResidentBacking::read_words_into(self, u, word_off, dst)
    }
    fn maintain(&self, _shard: usize) {}
    fn checkpoint(&self) -> io::Result<()> {
        Ok(())
    }
    fn clear(&self) {
        ResidentBacking::clear(self)
    }
    fn resident_bytes(&self) -> u64 {
        ResidentBacking::resident_bytes(self)
    }
    fn block_faults(&self) -> u64 {
        0
    }
    fn spill_bytes_written(&self) -> u64 {
        0
    }
}

impl SketchBacking for SpillBacking {
    fn words(&self) -> usize {
        SpillBacking::words(self)
    }
    fn merge_delta(&self, u: u32, delta: &[u64], lsn: u64) {
        SpillBacking::merge_delta(self, u, delta, lsn)
    }
    fn read_words_into(&self, u: u32, word_off: usize, dst: &mut [u64]) {
        SpillBacking::read_words_into(self, u, word_off, dst)
    }
    fn maintain(&self, shard: usize) {
        SpillBacking::maintain(self, shard)
    }
    fn checkpoint(&self) -> io::Result<()> {
        SpillBacking::checkpoint(self)
    }
    fn clear(&self) {
        SpillBacking::clear(self)
    }
    fn resident_bytes(&self) -> u64 {
        SpillBacking::resident_bytes(self)
    }
    fn block_faults(&self) -> u64 {
        SpillBacking::block_faults(self)
    }
    fn spill_bytes_written(&self) -> u64 {
        SpillBacking::spill_bytes_written(self)
    }
}

/// Counters describing one WAL-tail replay.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// Tail records whose delta was applied to at least one copy.
    pub replayed: u64,
    /// Tail records wholly skipped by the LSN idempotence rule (their
    /// effect was already persisted by a post-cut eviction).
    pub skipped: u64,
    /// Total records in the replayed tail.
    pub tail_records: u64,
    /// Whether the log ended in a torn record (tolerated: the torn
    /// record never merged anywhere, so dropping it loses nothing).
    pub torn_tail: bool,
}

/// Replay the WAL tail (everything past the last durable-cut marker)
/// of the log at `wal_path` into `stores` — the k sketch copies of one
/// graph, in copy order.
///
/// `Delta` records carry the concatenation of all k copies' deltas and
/// are split across the stores; `Exact` records carry copy-independent
/// edge indices, re-expanded per copy under its own seeds.  Each
/// application goes through the store's LSN-checked replay path, so
/// records whose effect already reached the segment files (evicted
/// after the cut, before the crash) are skipped rather than
/// double-applied.
pub fn replay_into(stores: &[SketchStore], wal_path: &Path) -> io::Result<ReplayStats> {
    let scanned = wal::scan(wal_path)?;
    let k = stores.len().max(1);
    let words = stores
        .first()
        .map(|s| s.params().words())
        .unwrap_or_default();
    let mut stats = ReplayStats {
        torn_tail: scanned.torn,
        ..ReplayStats::default()
    };
    for (end, rec) in &scanned.records[scanned.tail_start()..] {
        stats.tail_records += 1;
        let mut applied = false;
        match rec {
            WalRecord::Delta { vertex, delta, .. } => {
                if delta.len() != words * k {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "WAL delta record for vertex {vertex} holds {} words, \
                             expected {} ({}×{} copies)",
                            delta.len(),
                            words * k,
                            words,
                            k
                        ),
                    ));
                }
                for (store, chunk) in stores.iter().zip(delta.chunks(words)) {
                    applied |= store.replay_delta(*vertex, chunk, *end)?;
                }
            }
            WalRecord::Exact {
                vertex, indices, ..
            } => {
                for store in stores {
                    let delta =
                        CameoSketch::delta_of_batch(store.params(), store.seeds(), indices);
                    applied |= store.replay_delta(*vertex, &delta, *end)?;
                }
            }
            // the tail starts past the last cut by construction, so no
            // Cut can appear here; tolerate one anyway (fresh logs)
            WalRecord::Cut { .. } => continue,
        }
        if applied {
            stats.replayed += 1;
        } else {
            stats.skipped += 1;
        }
    }
    Ok(stats)
}
