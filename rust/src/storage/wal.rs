//! Append-only write-ahead log of batch deltas.
//!
//! The log is the durability half of the storage tier: every sketch or
//! exact-index delta the distributors merge is first appended here, so
//! a crash can lose nothing that reached the sketch state.  Records are
//! length-prefixed and the payloads **reuse the `net/` v2 frame
//! encoders** — a sketch delta is a `DELTA2` frame and an exact-index
//! batch is an `EXACTDELTA2` frame, byte-identical to what the remote
//! transport puts on the wire — plus one storage-private record type,
//! the *durable-cut marker*, appended (and fsync'd) when an epoch cut
//! is made durable:
//!
//! ```text
//! wal.log   := record*
//! record    := [u32 le payload_len] [payload]
//! payload   := DELTA2 frame          (tag 5: seq, vertex, k·words u64s)
//!            | EXACTDELTA2 frame     (tag 9: seq, vertex, indices)
//!            | cut marker            (tag 0xC5: u64 le epoch)
//! ```
//!
//! A `DELTA2` payload carries the **concatenation of all k copies'**
//! deltas for the vertex (length `k × params.words()`); an
//! `EXACTDELTA2` payload's indices are copy-independent, exactly as on
//! the wire.  The `seq` field is the record ordinal, for debugging.
//!
//! **Torn-tail tolerance:** appends are not fsync'd individually (the
//! durability contract is *at epoch cuts*, see `docs/STORAGE.md`), so
//! after a crash the file may end mid-record.  [`scan`] stops cleanly
//! at the first short, oversized, or unparseable record and reports the
//! valid prefix length; [`WalWriter::open_append`] truncates the torn
//! tail before resuming appends.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::net::Message;

/// Upper bound on a single record's payload (matches the `net/` reader
/// cap): anything larger is treated as corruption, not a record.
const MAX_PAYLOAD: u32 = 1 << 28;

/// Storage-private payload tag for the durable-cut marker (chosen well
/// clear of the `net/` frame tags 0..=9).
const CUT_TAG: u8 = 0xC5;

/// One decoded log record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// A sketch delta for `vertex`: the concatenation of all k copies'
    /// `params.words()`-long deltas (a `DELTA2` frame on disk).
    Delta {
        /// Record ordinal at append time (debugging only).
        seq: u64,
        /// The destination vertex.
        vertex: u32,
        /// `k × words` XOR-delta words.
        delta: Vec<u64>,
    },
    /// An exact-index batch for `vertex` (an `EXACTDELTA2` frame on
    /// disk); the encoded edge indices are valid for every sketch copy.
    Exact {
        /// Record ordinal at append time (debugging only).
        seq: u64,
        /// The destination vertex.
        vertex: u32,
        /// Odd-parity encoded edge indices of the batch.
        indices: Vec<u64>,
    },
    /// A durable-cut marker: every record before this offset is also
    /// reflected in the checkpointed segment files, and the log was
    /// fsync'd immediately after this record.
    Cut {
        /// The epoch the durable cut covered.
        epoch: u64,
    },
}

/// The result of scanning a log file: the decodable prefix.
#[derive(Debug)]
pub struct WalScan {
    /// Records in append order, each paired with its **end offset**
    /// (the log length after the record was appended — the LSN the
    /// spill tier stamps blocks with).
    pub records: Vec<(u64, WalRecord)>,
    /// Length of the valid prefix; anything past it is a torn tail.
    pub valid_len: u64,
    /// Whether trailing bytes past `valid_len` were present (a torn
    /// final record from a crash mid-append).
    pub torn: bool,
}

impl WalScan {
    /// Index into `records` just past the last durable-cut marker
    /// (0 when no marker exists — the whole log is tail).
    pub fn tail_start(&self) -> usize {
        self.records
            .iter()
            .rposition(|(_, r)| matches!(r, WalRecord::Cut { .. }))
            .map(|i| i + 1)
            .unwrap_or(0)
    }
}

/// Decode one payload, or `None` if it is not a valid record (the scan
/// treats that as the corruption boundary).
fn decode_payload(payload: &[u8]) -> Option<WalRecord> {
    match payload.first()? {
        &CUT_TAG => {
            let bytes: [u8; 8] = payload.get(1..9)?.try_into().ok()?;
            if payload.len() != 9 {
                return None;
            }
            Some(WalRecord::Cut {
                epoch: u64::from_le_bytes(bytes),
            })
        }
        _ => {
            let mut r = payload;
            let msg = Message::read_from(&mut r).ok()?;
            if !r.is_empty() {
                return None; // trailing garbage inside the record
            }
            match msg {
                Message::Delta2 { seq, vertex, delta } => {
                    Some(WalRecord::Delta { seq, vertex, delta })
                }
                Message::ExactDelta2 {
                    seq,
                    vertex,
                    indices,
                } => Some(WalRecord::Exact {
                    seq,
                    vertex,
                    indices,
                }),
                _ => None, // a frame type that never belongs in the log
            }
        }
    }
}

/// Scan `path`, decoding the valid record prefix and tolerating a torn
/// tail.  Reads the whole file into memory — this runs at recovery
/// time, never on the ingest path.
pub fn scan(path: &Path) -> std::io::Result<WalScan> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    let mut records = Vec::new();
    let mut off = 0usize;
    loop {
        let Some(len_bytes) = bytes.get(off..off + 4) else {
            break; // short length prefix: torn
        };
        let len = u32::from_le_bytes(len_bytes.try_into().map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "length slice")
        })?) as usize;
        if len == 0 || len > MAX_PAYLOAD as usize {
            break; // nonsense length: corruption boundary
        }
        let Some(payload) = bytes.get(off + 4..off + 4 + len) else {
            break; // short payload: torn final record
        };
        let Some(rec) = decode_payload(payload) else {
            break; // undecodable payload: corruption boundary
        };
        off += 4 + len;
        records.push((off as u64, rec));
    }
    Ok(WalScan {
        records,
        valid_len: off as u64,
        torn: off < bytes.len(),
    })
}

/// The append half of the log.  Not internally synchronized — wrap in
/// [`DurabilityLog`] (or a mutex) for concurrent appenders.
pub struct WalWriter {
    file: File,
    len: u64,
    seq: u64,
}

impl WalWriter {
    /// Create a fresh log at `path`.  Fails if the file already exists:
    /// silently overwriting a previous session's log would destroy the
    /// very state [`crate::session::Landscape::recover`] exists to
    /// restore.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        let file = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(path)?;
        Ok(Self {
            file,
            len: 0,
            seq: 0,
        })
    }

    /// Open an existing log for appending, truncating any torn tail
    /// left by a crash mid-append.  Returns the writer positioned at
    /// the end of the valid prefix.
    pub fn open_append(path: &Path) -> std::io::Result<Self> {
        let prior = scan(path)?;
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        if prior.torn {
            file.set_len(prior.valid_len)?;
        }
        Ok(Self {
            file,
            len: prior.valid_len,
            seq: prior.records.len() as u64,
        })
    }

    /// Append one pre-encoded payload; returns the new log length (the
    /// record's end offset).
    fn append_payload(&mut self, payload: &[u8]) -> std::io::Result<u64> {
        debug_assert!(payload.len() <= MAX_PAYLOAD as usize);
        let mut buf = Vec::with_capacity(4 + payload.len());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        // one write_all per record: a crash can tear at most the final
        // record, which scan()/open_append() tolerate by construction
        self.file.write_all(&buf)?;
        self.len += buf.len() as u64;
        self.seq += 1;
        Ok(self.len)
    }

    /// Encode a `net/` frame into a payload buffer.
    fn frame_payload(msg: &Message) -> std::io::Result<Vec<u8>> {
        let mut payload = Vec::with_capacity(msg.wire_bytes() as usize);
        msg.write_to(&mut payload).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
        })?;
        Ok(payload)
    }

    /// Append a sketch-delta record (`delta` = k concatenated copies).
    /// Returns the record's end offset.
    pub fn append_delta(&mut self, vertex: u32, delta: &[u64]) -> std::io::Result<u64> {
        let payload = Self::frame_payload(&Message::Delta2 {
            seq: self.seq,
            vertex,
            delta: delta.to_vec(),
        })?;
        self.append_payload(&payload)
    }

    /// Append an exact-index record.  Returns the record's end offset.
    pub fn append_exact(&mut self, vertex: u32, indices: &[u64]) -> std::io::Result<u64> {
        let payload = Self::frame_payload(&Message::ExactDelta2 {
            seq: self.seq,
            vertex,
            indices: indices.to_vec(),
        })?;
        self.append_payload(&payload)
    }

    /// Append a durable-cut marker.  Returns the record's end offset.
    pub fn append_cut(&mut self, epoch: u64) -> std::io::Result<u64> {
        let mut payload = [0u8; 9];
        payload[0] = CUT_TAG;
        payload[1..9].copy_from_slice(&epoch.to_le_bytes());
        self.append_payload(&payload)
    }

    /// Flush appended records to stable storage (the fsync of the
    /// durable-cut contract).
    pub fn sync(&self) -> std::io::Result<()> {
        self.file.sync_data()
    }

    /// Current log length in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// Whether the log holds no records yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// The session-level durability log: a mutex-wrapped [`WalWriter`] plus
/// the shared **watermark** — the log's current end offset, which the
/// spill tier reads to stamp mutated blocks with an LSN (see
/// `docs/STORAGE.md` for why replay needs it).
///
/// Appenders are the distributor threads (one append per retired
/// batch, *before* the merge, under the session merge gate's shared
/// side); the durable-cut path appends the marker and fsyncs under the
/// gate's exclusive side.
pub struct DurabilityLog {
    path: PathBuf,
    writer: Mutex<WalWriter>,
    watermark: Arc<AtomicU64>,
}

/// Receipt for one [`DurabilityLog`] append: the record's **end
/// offset** (the LSN the caller must stamp the ensuing merge with —
/// reading the shared watermark instead is racy, see `docs/STORAGE.md`)
/// and the **bytes** the record occupies (for `wal_bytes` metering).
#[derive(Clone, Copy, Debug)]
pub struct Appended {
    /// File offset one past the record — its LSN.
    pub end: u64,
    /// Bytes the record occupies on disk, length prefix included.
    pub bytes: u64,
}

impl DurabilityLog {
    fn wrap(path: PathBuf, writer: WalWriter) -> Self {
        let watermark = Arc::new(AtomicU64::new(writer.len()));
        Self {
            path,
            writer: Mutex::new(writer),
            watermark,
        }
    }

    /// Create a fresh log at `path` (fails if one already exists).
    pub fn create(path: &Path) -> std::io::Result<Self> {
        Ok(Self::wrap(path.to_path_buf(), WalWriter::create(path)?))
    }

    /// Re-open an existing log, truncating any torn tail.
    pub fn open_append(path: &Path) -> std::io::Result<Self> {
        Ok(Self::wrap(path.to_path_buf(), WalWriter::open_append(path)?))
    }

    /// The log file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The shared end-offset watermark handle (cloned into each spill
    /// backing as its LSN source).
    pub fn watermark(&self) -> Arc<AtomicU64> {
        self.watermark.clone()
    }

    fn lock(&self) -> MutexGuard<'_, WalWriter> {
        self.writer.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn publish(&self, end: u64) {
        // the same thread appends then merges (program order suffices);
        // cross-thread readers only look under the session merge gate.
        // lint: allow(relaxed-ordering) — monotone watermark hint; the merge gate synchronizes readers
        self.watermark.store(end, Ordering::Relaxed);
    }

    /// Append a sketch-delta record.
    pub fn append_delta(&self, vertex: u32, delta: &[u64]) -> std::io::Result<Appended> {
        let mut w = self.lock();
        let before = w.len();
        let end = w.append_delta(vertex, delta)?;
        drop(w);
        self.publish(end);
        Ok(Appended {
            end,
            bytes: end - before,
        })
    }

    /// Append an exact-index record.
    pub fn append_exact(&self, vertex: u32, indices: &[u64]) -> std::io::Result<Appended> {
        let mut w = self.lock();
        let before = w.len();
        let end = w.append_exact(vertex, indices)?;
        drop(w);
        self.publish(end);
        Ok(Appended {
            end,
            bytes: end - before,
        })
    }

    /// Append a durable-cut marker and fsync the log — the durability
    /// point of the epoch-cut contract.  Returns the bytes appended.
    pub fn cut_sync(&self, epoch: u64) -> std::io::Result<u64> {
        let mut w = self.lock();
        let before = w.len();
        let end = w.append_cut(epoch)?;
        w.sync()?;
        drop(w);
        self.publish(end);
        Ok(end - before)
    }

    /// Current log length in bytes.
    pub fn len(&self) -> u64 {
        self.lock().len()
    }

    /// Whether the log holds no records yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "landscape_wal_{}_{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("wal.log")
    }

    #[test]
    fn roundtrip_all_record_types() {
        let path = tmp("roundtrip");
        let mut w = WalWriter::create(&path).unwrap();
        let e1 = w.append_delta(7, &[1, 0, u64::MAX, 42]).unwrap();
        let e2 = w.append_exact(9, &[3, 5, 8]).unwrap();
        let e3 = w.append_cut(11).unwrap();
        let e4 = w.append_exact(2, &[]).unwrap();
        w.sync().unwrap();

        let s = scan(&path).unwrap();
        assert!(!s.torn);
        assert_eq!(s.valid_len, w.len());
        let (offs, recs): (Vec<u64>, Vec<WalRecord>) = s.records.into_iter().unzip();
        assert_eq!(offs, vec![e1, e2, e3, e4]);
        assert_eq!(
            recs,
            vec![
                WalRecord::Delta {
                    seq: 0,
                    vertex: 7,
                    delta: vec![1, 0, u64::MAX, 42]
                },
                WalRecord::Exact {
                    seq: 1,
                    vertex: 9,
                    indices: vec![3, 5, 8]
                },
                WalRecord::Cut { epoch: 11 },
                WalRecord::Exact {
                    seq: 3,
                    vertex: 2,
                    indices: vec![]
                },
            ]
        );
    }

    #[test]
    fn tail_start_points_past_last_cut() {
        let path = tmp("tail");
        let mut w = WalWriter::create(&path).unwrap();
        w.append_delta(1, &[1]).unwrap();
        w.append_cut(1).unwrap();
        w.append_delta(2, &[2]).unwrap();
        w.append_cut(2).unwrap();
        w.append_delta(3, &[3]).unwrap();
        let s = scan(&path).unwrap();
        assert_eq!(s.tail_start(), 4);
        assert!(matches!(
            s.records[s.tail_start()].1,
            WalRecord::Delta { vertex: 3, .. }
        ));

        // no marker at all: the whole log is tail
        let path2 = tmp("tail_none");
        let mut w2 = WalWriter::create(&path2).unwrap();
        w2.append_delta(1, &[1]).unwrap();
        assert_eq!(scan(&path2).unwrap().tail_start(), 0);
    }

    #[test]
    fn torn_final_record_is_tolerated_and_truncated() {
        let path = tmp("torn");
        let mut w = WalWriter::create(&path).unwrap();
        w.append_delta(1, &[10, 20]).unwrap();
        let keep = w.append_exact(2, &[30]).unwrap();
        w.append_delta(3, &[40, 50, 60]).unwrap();
        drop(w);

        // tear the final record mid-payload, as a crash mid-append would
        let full = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 9).unwrap();
        drop(f);

        let s = scan(&path).unwrap();
        assert!(s.torn);
        assert_eq!(s.valid_len, keep);
        assert_eq!(s.records.len(), 2);

        // open_append truncates the tail and appends cleanly after it
        let mut w = WalWriter::open_append(&path).unwrap();
        assert_eq!(w.len(), keep);
        w.append_cut(5).unwrap();
        let s = scan(&path).unwrap();
        assert!(!s.torn);
        assert_eq!(s.records.len(), 3);
        assert_eq!(s.records[2].1, WalRecord::Cut { epoch: 5 });
    }

    #[test]
    fn garbage_length_prefix_stops_the_scan() {
        let path = tmp("garbage");
        let mut w = WalWriter::create(&path).unwrap();
        let keep = w.append_delta(4, &[7]).unwrap();
        drop(w);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&u32::MAX.to_le_bytes()).unwrap();
        f.write_all(&[0xAB; 16]).unwrap();
        drop(f);
        let s = scan(&path).unwrap();
        assert!(s.torn);
        assert_eq!(s.valid_len, keep);
        assert_eq!(s.records.len(), 1);
    }

    #[test]
    fn create_refuses_to_clobber_an_existing_log() {
        let path = tmp("clobber");
        let _w = WalWriter::create(&path).unwrap();
        assert!(WalWriter::create(&path).is_err());
    }

    #[test]
    fn durability_log_tracks_the_watermark() {
        let path = tmp("durable");
        let log = DurabilityLog::create(&path).unwrap();
        let wm = log.watermark();
        assert_eq!(wm.load(Ordering::Relaxed), 0);
        let a1 = log.append_delta(1, &[1, 2]).unwrap();
        assert_eq!(a1.end, a1.bytes, "first record starts at offset 0");
        assert_eq!(wm.load(Ordering::Relaxed), a1.end);
        let a2 = log.append_exact(2, &[9]).unwrap();
        assert_eq!(a2.end, a1.bytes + a2.bytes);
        assert_eq!(wm.load(Ordering::Relaxed), a2.end);
        log.cut_sync(3).unwrap();
        assert_eq!(wm.load(Ordering::Relaxed), log.len());

        // re-open resumes at the same watermark
        drop(log);
        let log = DurabilityLog::open_append(&path).unwrap();
        assert_eq!(log.watermark().load(Ordering::Relaxed), log.len());
    }
}
