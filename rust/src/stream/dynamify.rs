//! The insert/delete stream transform (paper §7.1).
//!
//! The paper turns each static graph into a fully dynamic stream by
//! repeatedly inserting and deleting all its edges: with `repeats` odd,
//! every edge appears `repeats` times, alternating insert/delete, so the
//! stream's net effect is exactly the original edge list while the total
//! update count is `repeats × E` (matching Table 2's updates/edges ≈ 7).
//!
//! Each round walks the candidate pair space in a *different* Feistel
//! order, so inserts and deletes of different edges interleave
//! arbitrarily — the adversarially-orderless property the semi-streaming
//! model requires — while any prefix remains valid (an edge is only
//! deleted while present: round r's delete follows round r-1's insert).

use crate::stream::permute::FeistelPermutation;
use crate::stream::{EdgeModel, GraphStream, Update, UpdateKind};
use crate::util::rng::Xoshiro256;

/// Sparse models materialize: if the candidate domain is more than this
/// factor larger than the edge set, scanning it once per round would
/// dominate, so the edge list is collected once and shuffled per round.
const MATERIALIZE_RATIO: f64 = 64.0;

/// Wraps an [`EdgeModel`] into a dynamic update stream.
///
/// Dense models walk the candidate pair domain in a per-round Feistel
/// order (O(1) memory); sparse models over large V materialize the edge
/// list once (one presence scan) and Fisher–Yates shuffle it per round —
/// otherwise each round would scan a V² domain for a tiny edge set.
pub struct Dynamify<M: EdgeModel> {
    model: M,
    repeats: u32,
    round: u32,
    perm: FeistelPermutation,
    cursor: u64,
    emitted: u64,
    expected_total: Option<u64>,
    /// Some(edges) when the sparse path is active.
    materialized: Option<Vec<(u32, u32)>>,
}

impl<M: EdgeModel> Dynamify<M> {
    /// `repeats` must be odd so every present edge nets to inserted.
    pub fn new(model: M, repeats: u32) -> Self {
        assert!(repeats % 2 == 1, "repeats must be odd");
        let v = model.num_vertices();
        let domain = (v * v) as f64;
        let materialized = if model.expected_edges() * MATERIALIZE_RATIO < domain {
            let mut edges = crate::stream::edge_list(&model);
            let mut rng = Xoshiro256::new(Self::round_seed(&model, 0));
            rng.shuffle(&mut edges);
            Some(edges)
        } else {
            None
        };
        let perm = FeistelPermutation::covering(v * v, Self::round_seed(&model, 0));
        let expected = match &materialized {
            Some(e) => (e.len() as u64) * repeats as u64,
            None => (model.expected_edges() * repeats as f64) as u64,
        };
        Self {
            model,
            repeats,
            round: 0,
            perm,
            cursor: 0,
            emitted: 0,
            expected_total: Some(expected),
            materialized,
        }
    }

    fn round_seed(model: &M, round: u32) -> u64 {
        crate::hashing::splitmix64(
            model.num_vertices() ^ (round as u64 + 1).wrapping_mul(0x2545F4914F6CDD1D),
        )
    }

    /// Exact stream length requires scanning; tests use collect().len().
    pub fn model(&self) -> &M {
        &self.model
    }
}

impl<M: EdgeModel> Iterator for Dynamify<M> {
    type Item = Update;

    fn next(&mut self) -> Option<Update> {
        // sparse path: walk the materialized, per-round-shuffled list
        if let Some(edges) = &mut self.materialized {
            loop {
                if self.round >= self.repeats {
                    return None;
                }
                if self.cursor >= edges.len() as u64 {
                    self.round += 1;
                    if self.round >= self.repeats {
                        return None;
                    }
                    let mut rng =
                        Xoshiro256::new(Self::round_seed(&self.model, self.round));
                    rng.shuffle(edges);
                    self.cursor = 0;
                    continue;
                }
                let (a, b) = edges[self.cursor as usize];
                self.cursor += 1;
                self.emitted += 1;
                let kind = if self.round % 2 == 0 {
                    UpdateKind::Insert
                } else {
                    UpdateKind::Delete
                };
                return Some(Update { u: a, v: b, kind });
            }
        }

        let v = self.model.num_vertices();
        loop {
            if self.round >= self.repeats {
                return None;
            }
            if self.cursor >= self.perm.domain() {
                self.round += 1;
                if self.round >= self.repeats {
                    return None;
                }
                self.perm = FeistelPermutation::covering(
                    v * v,
                    Self::round_seed(&self.model, self.round),
                );
                self.cursor = 0;
                continue;
            }
            let raw = self.perm.apply(self.cursor);
            self.cursor += 1;
            let a = (raw / v.max(1)) as u64;
            let b = raw % v.max(1);
            if raw >= v * v || a >= b || b >= v {
                continue; // out of the triangular pair domain
            }
            let (a, b) = (a as u32, b as u32);
            if !self.model.contains(a, b) {
                continue;
            }
            self.emitted += 1;
            let kind = if self.round % 2 == 0 {
                UpdateKind::Insert
            } else {
                UpdateKind::Delete
            };
            return Some(Update { u: a, v: b, kind });
        }
    }
}

impl<M: EdgeModel> GraphStream for Dynamify<M> {
    fn num_vertices(&self) -> u64 {
        self.model.num_vertices()
    }
    fn len_hint(&self) -> Option<u64> {
        self.expected_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::erdos::ErdosRenyi;
    use crate::stream::{edge_list, VecStream};
    use std::collections::HashMap;

    fn net_effect(updates: &[Update]) -> Vec<(u32, u32)> {
        let mut present: HashMap<(u32, u32), bool> = HashMap::new();
        for u in updates {
            let e = u.endpoints();
            let slot = present.entry(e).or_insert(false);
            match u.kind {
                UpdateKind::Insert => {
                    assert!(!*slot, "insert of present edge {e:?}");
                    *slot = true;
                }
                UpdateKind::Delete => {
                    assert!(*slot, "delete of absent edge {e:?}");
                    *slot = false;
                }
            }
        }
        let mut edges: Vec<(u32, u32)> = present
            .into_iter()
            .filter_map(|(e, p)| p.then_some(e))
            .collect();
        edges.sort_unstable();
        edges
    }

    #[test]
    fn stream_is_valid_and_nets_to_the_model() {
        let g = ErdosRenyi::new(64, 0.2, 11);
        let want = edge_list(&g);
        let updates: Vec<Update> = Dynamify::new(g, 3).collect();
        assert_eq!(net_effect(&updates), want);
        assert_eq!(updates.len(), want.len() * 3);
    }

    #[test]
    fn repeats_one_is_insert_only() {
        let g = ErdosRenyi::new(32, 0.3, 2);
        let updates: Vec<Update> = Dynamify::new(g, 1).collect();
        assert!(updates.iter().all(|u| u.kind == UpdateKind::Insert));
        assert_eq!(net_effect(&updates).len(), updates.len());
    }

    #[test]
    #[should_panic]
    fn even_repeats_rejected() {
        let g = ErdosRenyi::new(8, 0.5, 1);
        let _ = Dynamify::new(g, 2);
    }

    #[test]
    fn rounds_use_different_orders() {
        let g = ErdosRenyi::new(64, 0.3, 4);
        let updates: Vec<Update> = Dynamify::new(g, 3).collect();
        let per_round = updates.len() / 3;
        let r0: Vec<(u32, u32)> = updates[..per_round].iter().map(|u| u.endpoints()).collect();
        let r1: Vec<(u32, u32)> = updates[per_round..2 * per_round]
            .iter()
            .map(|u| u.endpoints())
            .collect();
        assert_ne!(r0, r1, "round orders should differ");
        // but the edge *sets* are identical
        let mut s0 = r0.clone();
        let mut s1 = r1.clone();
        s0.sort_unstable();
        s1.sort_unstable();
        assert_eq!(s0, s1);
    }

    #[test]
    fn len_hint_is_reasonable() {
        let g = ErdosRenyi::new(128, 0.25, 5);
        let s = Dynamify::new(g, 7);
        let hint = s.len_hint().unwrap() as f64;
        let actual = s.count() as f64;
        assert!((hint - actual).abs() / actual < 0.2, "hint={hint} actual={actual}");
    }

    #[test]
    fn sparse_path_materializes_and_is_valid() {
        // avg degree 2 over 4096 vertices: far under the 1/64 ratio
        let g = crate::stream::realworld::SparseRandom::new(4096, 2.0, 5);
        let s = Dynamify::new(g, 5);
        assert!(s.materialized.is_some(), "sparse model should materialize");
        let updates: Vec<Update> = s.collect();
        let want = edge_list(&crate::stream::realworld::SparseRandom::new(4096, 2.0, 5));
        assert_eq!(net_effect(&updates), want);
        assert_eq!(updates.len(), want.len() * 5);
    }

    #[test]
    fn dense_path_stays_streaming() {
        let g = ErdosRenyi::new(64, 0.2, 11);
        assert!(Dynamify::new(g, 3).materialized.is_none());
    }

    #[test]
    fn replay_through_vecstream_matches() {
        let g = ErdosRenyi::new(32, 0.4, 8);
        let updates: Vec<Update> = Dynamify::new(g, 3).collect();
        let replay: Vec<Update> = VecStream::new(32, updates.clone()).collect();
        assert_eq!(replay, updates);
    }
}
