//! Stream update records — the `((u,v), Δ)` elements of the graph
//! semi-streaming model (paper §3).

/// Insert or delete — the Δ ∈ {+1, -1} of the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UpdateKind {
    Insert,
    Delete,
}

/// One stream element.  The wire encoding is 9 bytes (1 kind + 2×u32
/// endpoints), matching the paper's "graph stream updates are 9 bytes".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Update {
    pub u: u32,
    pub v: u32,
    pub kind: UpdateKind,
}

/// Bytes per update on the wire / in the data-acquisition accounting.
pub const UPDATE_WIRE_BYTES: u64 = 9;

impl Update {
    #[inline]
    pub fn insert(u: u32, v: u32) -> Self {
        Self {
            u,
            v,
            kind: UpdateKind::Insert,
        }
    }

    #[inline]
    pub fn delete(u: u32, v: u32) -> Self {
        Self {
            u,
            v,
            kind: UpdateKind::Delete,
        }
    }

    /// Normalized endpoints (lo, hi).
    #[inline]
    pub fn endpoints(&self) -> (u32, u32) {
        if self.u < self.v {
            (self.u, self.v)
        } else {
            (self.v, self.u)
        }
    }

    /// Serialize to the 9-byte wire format.
    #[inline]
    pub fn to_bytes(&self) -> [u8; 9] {
        let mut out = [0u8; 9];
        out[0] = match self.kind {
            UpdateKind::Insert => 0,
            UpdateKind::Delete => 1,
        };
        out[1..5].copy_from_slice(&self.u.to_le_bytes());
        out[5..9].copy_from_slice(&self.v.to_le_bytes());
        out
    }

    /// Parse the 9-byte wire format.
    #[inline]
    pub fn from_bytes(b: &[u8; 9]) -> Result<Self, String> {
        let kind = match b[0] {
            0 => UpdateKind::Insert,
            1 => UpdateKind::Delete,
            x => return Err(format!("bad update kind byte {x}")),
        };
        Ok(Self {
            kind,
            u: u32::from_le_bytes(b[1..5].try_into().unwrap()),
            v: u32::from_le_bytes(b[5..9].try_into().unwrap()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::Cases;

    #[test]
    fn wire_roundtrip() {
        Cases::new(100).run(|rng| {
            let upd = Update {
                u: rng.next_u64() as u32,
                v: rng.next_u64() as u32,
                kind: if rng.next_bool(0.5) {
                    UpdateKind::Insert
                } else {
                    UpdateKind::Delete
                },
            };
            let bytes = upd.to_bytes();
            assert_eq!(Update::from_bytes(&bytes).unwrap(), upd);
        });
    }

    #[test]
    fn bad_kind_rejected() {
        let mut b = Update::insert(1, 2).to_bytes();
        b[0] = 9;
        assert!(Update::from_bytes(&b).is_err());
    }

    #[test]
    fn endpoints_normalized() {
        assert_eq!(Update::insert(9, 2).endpoints(), (2, 9));
        assert_eq!(Update::delete(2, 9).endpoints(), (2, 9));
    }
}
