//! The dataset registry — Table 2's evaluation suite, scaled to this
//! container (see DESIGN.md "Scaling note" and "Substitutions").
//!
//! Paper datasets and their stand-ins:
//!
//! | paper         | here             | class              | scaling |
//! |---------------|------------------|--------------------|---------|
//! | kron13..17    | kron10..13       | dense Kronecker    | V ÷ 8–16 |
//! | erdos18..20   | erdos11..13      | dense G(V, 1/4)    | V ÷ 128 |
//! | p2p-gnutella  | gnutella         | sparse overlay     | 1:1     |
//! | rec-amazon    | amazon           | near-planar grid   | 1:1     |
//! | google-plus   | googleplus       | heavy power-law    | V ÷ 8   |
//! | web-uk-2005   | webuk            | dense power-law    | V ÷ 32  |
//! | ca-citeseer   | citeseer         | sparse power-law   | V ÷ 64  |

use crate::stream::dynamify::Dynamify;
use crate::stream::erdos::ErdosRenyi;
use crate::stream::kron::Kronecker;
use crate::stream::realworld::{ChungLu, GridLike, SparseRandom};
use crate::stream::EdgeModel;

/// A registered dataset: an edge model plus its stream parameters.
pub enum DatasetModel {
    Kron(Kronecker),
    Erdos(ErdosRenyi),
    ChungLu(ChungLu),
    Grid(GridLike),
    Sparse(SparseRandom),
}

impl EdgeModel for DatasetModel {
    fn num_vertices(&self) -> u64 {
        match self {
            DatasetModel::Kron(m) => m.num_vertices(),
            DatasetModel::Erdos(m) => m.num_vertices(),
            DatasetModel::ChungLu(m) => m.num_vertices(),
            DatasetModel::Grid(m) => m.num_vertices(),
            DatasetModel::Sparse(m) => m.num_vertices(),
        }
    }

    fn contains(&self, a: u32, b: u32) -> bool {
        match self {
            DatasetModel::Kron(m) => m.contains(a, b),
            DatasetModel::Erdos(m) => m.contains(a, b),
            DatasetModel::ChungLu(m) => m.contains(a, b),
            DatasetModel::Grid(m) => m.contains(a, b),
            DatasetModel::Sparse(m) => m.contains(a, b),
        }
    }

    fn expected_edges(&self) -> f64 {
        match self {
            DatasetModel::Kron(m) => m.expected_edges(),
            DatasetModel::Erdos(m) => m.expected_edges(),
            DatasetModel::ChungLu(m) => m.expected_edges(),
            DatasetModel::Grid(m) => m.expected_edges(),
            DatasetModel::Sparse(m) => m.expected_edges(),
        }
    }
}

/// Dataset descriptor.
pub struct Dataset {
    pub name: &'static str,
    /// Paper dataset this stands in for.
    pub paper_name: &'static str,
    pub model: DatasetModel,
    /// Insert/delete repetition factor (paper uses 7).
    pub repeats: u32,
}

impl Dataset {
    pub fn stream(&self) -> Dynamify<&DatasetModel> {
        Dynamify::new(&self.model, self.repeats)
    }
}

impl<'a> EdgeModel for &'a DatasetModel {
    fn num_vertices(&self) -> u64 {
        (**self).num_vertices()
    }
    fn contains(&self, a: u32, b: u32) -> bool {
        (**self).contains(a, b)
    }
    fn expected_edges(&self) -> f64 {
        (**self).expected_edges()
    }
}

const SEED: u64 = 0xDA7A5E7;

/// Look a dataset up by name.
pub fn by_name(name: &str) -> Option<Dataset> {
    let d = match name {
        "kron10" => Dataset {
            name: "kron10",
            paper_name: "kron13 (scaled)",
            model: DatasetModel::Kron(Kronecker::paper(10, SEED)),
            repeats: 7,
        },
        "kron11" => Dataset {
            name: "kron11",
            paper_name: "kron15 (scaled)",
            model: DatasetModel::Kron(Kronecker::paper(11, SEED)),
            repeats: 7,
        },
        "kron12" => Dataset {
            name: "kron12",
            paper_name: "kron16 (scaled)",
            model: DatasetModel::Kron(Kronecker::paper(12, SEED)),
            repeats: 7,
        },
        "kron13" => Dataset {
            name: "kron13",
            paper_name: "kron17 (scaled)",
            model: DatasetModel::Kron(Kronecker::paper(13, SEED)),
            repeats: 7,
        },
        "erdos11" => Dataset {
            name: "erdos11",
            paper_name: "erdos18 (scaled)",
            model: DatasetModel::Erdos(ErdosRenyi::new(1 << 11, 0.5, SEED)),
            repeats: 7,
        },
        "erdos12" => Dataset {
            name: "erdos12",
            paper_name: "erdos19 (scaled)",
            model: DatasetModel::Erdos(ErdosRenyi::new(1 << 12, 0.5, SEED)),
            repeats: 7,
        },
        "erdos13" => Dataset {
            name: "erdos13",
            paper_name: "erdos20 (scaled)",
            model: DatasetModel::Erdos(ErdosRenyi::new(1 << 13, 0.5, SEED)),
            repeats: 7,
        },
        "gnutella" => Dataset {
            name: "gnutella",
            paper_name: "p2p-gnutella (1:1)",
            model: DatasetModel::Sparse(SparseRandom::new(63_000, 4.8, SEED)),
            repeats: 13,
        },
        "amazon" => Dataset {
            name: "amazon",
            paper_name: "rec-amazon (1:1)",
            model: DatasetModel::Grid(GridLike::new(92_000, 0.66, 0.2, SEED)),
            repeats: 13,
        },
        "googleplus" => Dataset {
            name: "googleplus",
            paper_name: "google-plus (scaled)",
            model: DatasetModel::ChungLu(ChungLu::new(14_000, 0.55, 220_000, SEED)),
            repeats: 13,
        },
        "webuk" => Dataset {
            name: "webuk",
            paper_name: "web-uk-2005 (scaled)",
            model: DatasetModel::ChungLu(ChungLu::new(40_000, 0.45, 470_000, SEED)),
            repeats: 13,
        },
        "citeseer" => Dataset {
            name: "citeseer",
            paper_name: "ca-citeseer (scaled)",
            model: DatasetModel::ChungLu(ChungLu::new(36_000, 0.3, 13_000, SEED)),
            repeats: 13,
        },
        _ => return None,
    };
    Some(d)
}

/// All registry names in Table-2 order.
pub fn all_names() -> &'static [&'static str] {
    &[
        "kron10",
        "kron11",
        "kron12",
        "kron13",
        "citeseer",
        "gnutella",
        "amazon",
        "googleplus",
        "webuk",
        "erdos11",
        "erdos12",
        "erdos13",
    ]
}

/// The quick subset used by default bench runs (small enough for
/// minutes-scale wall clock on one core).
pub fn quick_names() -> &'static [&'static str] {
    &["kron10", "kron11", "gnutella", "googleplus", "erdos11"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete_and_consistent() {
        for name in all_names() {
            let d = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(d.name, *name);
            assert!(d.repeats % 2 == 1, "{name}: repeats must be odd");
            assert!(d.model.num_vertices() >= 2);
            assert!(d.model.expected_edges() > 0.0);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn quick_subset_is_registered() {
        for name in quick_names() {
            assert!(by_name(name).is_some());
        }
    }

    #[test]
    fn kron_datasets_are_dense_realworld_sparse() {
        let kron = by_name("kron10").unwrap();
        let gnutella = by_name("gnutella").unwrap();
        let kd = kron.model.expected_edges()
            / (kron.model.num_vertices() * (kron.model.num_vertices() - 1) / 2) as f64;
        let gd = gnutella.model.expected_edges()
            / (gnutella.model.num_vertices() * (gnutella.model.num_vertices() - 1) / 2)
                as f64;
        assert!(kd > 0.05, "kron density {kd}");
        assert!(gd < 1e-3, "gnutella density {gd}");
    }

    #[test]
    fn streams_are_drivable() {
        let d = by_name("erdos11").unwrap();
        let mut n = 0u64;
        for _ in d.stream().take(1000) {
            n += 1;
        }
        assert_eq!(n, 1000);
    }
}
