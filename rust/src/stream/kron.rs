//! Stochastic-Kronecker edge model — the paper's `kron13..17` datasets
//! (§7.1) follow the Graph500 generator spec but at ~25% density.
//!
//! Edge probability is the Kronecker product of a 2×2 initiator over the
//! bit-planes of the endpoint ids, normalized so the *mean* pair
//! probability equals the target density, then clipped at 1.  Membership
//! is a deterministic hash threshold against that probability, so the
//! model is O(1) state like the others.

use crate::hashing::splitmix64;
use crate::sketch::params::encode_edge;
use crate::stream::EdgeModel;

/// Kronecker initiator as a symmetric 2×2 weight matrix scaled to sum 4
/// (so the product over bit-planes has mean 1 over all pairs).
///
/// Graph500's raw (0.57, 0.19, 0.19, 0.05) weights make the per-pair
/// product so skewed that, at the *dense* ~V²/4 edge counts the paper's
/// kron streams have, most probability mass would be clipped at 1 and
/// the realized density would collapse.  The paper's generator avoids
/// this by sampling edges with replacement (heavy cells saturate); our
/// closed-form membership model instead flattens the initiator toward
/// uniform — preserving the low-id degree skew qualitatively while
/// keeping the realized density at the paper's level.
const INITIATOR: [[f64; 2]; 2] = [
    [1.40, 1.00],
    [1.00, 0.60],
];

/// Kronecker model over V = 2^scale vertices at a target mean density.
#[derive(Clone, Copy, Debug)]
pub struct Kronecker {
    scale: u32,
    density: f64,
    seed: u64,
}

impl Kronecker {
    /// `scale`: log2(V).  `density`: target mean edge probability — the
    /// paper's kron streams sit near 0.25.
    pub fn new(scale: u32, density: f64, seed: u64) -> Self {
        assert!(scale >= 1 && scale <= 30);
        assert!((0.0..=1.0).contains(&density));
        Self {
            scale,
            density,
            seed,
        }
    }

    /// The paper's kron configuration at a given scale: ≈ V²/4 edges,
    /// i.e. half of all unordered pairs (Table 2's kron13..17 ratios).
    pub fn paper(scale: u32, seed: u64) -> Self {
        Self::new(scale, 0.5, seed)
    }

    /// Pair probability before clipping.
    #[inline]
    fn raw_probability(&self, a: u32, b: u32) -> f64 {
        let mut p = self.density;
        for bit in 0..self.scale {
            let ba = ((a >> bit) & 1) as usize;
            let bb = ((b >> bit) & 1) as usize;
            // symmetrize: unordered pair sees the average of both orders
            p *= 0.5 * (INITIATOR[ba][bb] + INITIATOR[bb][ba]);
        }
        p
    }
}

impl EdgeModel for Kronecker {
    fn num_vertices(&self) -> u64 {
        1u64 << self.scale
    }

    #[inline]
    fn contains(&self, a: u32, b: u32) -> bool {
        let p = self.raw_probability(a, b).min(1.0);
        if p <= 0.0 {
            return false;
        }
        let idx = encode_edge(a, b, self.num_vertices());
        let h = splitmix64(self.seed ^ idx.wrapping_mul(0x8EBC6AF09C88C6E3));
        (h as f64) < p * 2f64.powi(64)
    }

    fn expected_edges(&self) -> f64 {
        // mean pair probability ≈ density (clipping skews it down for
        // skewed initiators; report the nominal value)
        let v = self.num_vertices();
        self.density * (v * (v - 1) / 2) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::count_edges;

    #[test]
    fn density_in_the_right_regime() {
        let g = Kronecker::paper(9, 5); // V=512
        let edges = count_edges(&g) as f64;
        let pairs = (512.0 * 511.0) / 2.0;
        let density = edges / pairs;
        // clipping makes the realized density land below the nominal
        // 0.25, but it must stay dense (same regime as the paper's kron)
        assert!(
            density > 0.20 && density < 0.70,
            "density={density}"
        );
    }

    #[test]
    fn degree_distribution_is_skewed() {
        // Kronecker graphs concentrate edges among low-id vertices
        let g = Kronecker::paper(9, 5);
        let v = 512u32;
        let degree = |x: u32| -> usize {
            (0..v)
                .filter(|&y| y != x && g.contains(x.min(y), x.max(y)))
                .count()
        };
        let low: usize = (0..16).map(degree).sum();
        let high: usize = (v - 16..v).map(degree).sum();
        assert!(
            low > 2 * high,
            "low-id degree sum {low} vs high-id {high}"
        );
    }

    #[test]
    fn membership_is_deterministic_and_symmetric_encoding() {
        let g = Kronecker::paper(8, 1);
        for a in 0..30u32 {
            for b in (a + 1)..30 {
                assert_eq!(g.contains(a, b), g.contains(a, b));
            }
        }
    }

    #[test]
    fn seeds_change_the_graph() {
        let a = Kronecker::paper(8, 1);
        let b = Kronecker::paper(8, 2);
        let diff = (0..200u32)
            .filter(|&x| a.contains(x, x + 1) != b.contains(x, x + 1))
            .count();
        assert!(diff > 10);
    }
}
