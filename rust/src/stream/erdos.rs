//! Erdős–Rényi G(V, p) edge model — the paper's `erdos18..20` datasets
//! (§7.1) use p = 1/4.
//!
//! Presence is a pure hash-threshold function so the model is O(1) state
//! regardless of density.

use crate::hashing::splitmix64;
use crate::sketch::params::encode_edge;
use crate::stream::EdgeModel;

/// G(V, p) with deterministic membership.
#[derive(Clone, Copy, Debug)]
pub struct ErdosRenyi {
    v: u64,
    /// presence threshold over the hash's u64 range
    threshold: u64,
    p: f64,
    seed: u64,
}

impl ErdosRenyi {
    pub fn new(v: u64, p: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        let threshold = if p >= 1.0 {
            u64::MAX
        } else {
            (p * 2f64.powi(64)) as u64
        };
        Self { v, threshold, p, seed }
    }
}

impl EdgeModel for ErdosRenyi {
    fn num_vertices(&self) -> u64 {
        self.v
    }

    #[inline]
    fn contains(&self, a: u32, b: u32) -> bool {
        let idx = encode_edge(a, b, self.v);
        splitmix64(self.seed ^ idx.wrapping_mul(0xE7037ED1A0B428DB)) < self.threshold
    }

    fn expected_edges(&self) -> f64 {
        self.p * (self.v * (self.v - 1) / 2) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::count_edges;

    #[test]
    fn density_close_to_p() {
        let g = ErdosRenyi::new(512, 0.25, 7);
        let edges = count_edges(&g) as f64;
        let expect = g.expected_edges();
        assert!(
            (edges - expect).abs() / expect < 0.05,
            "edges={edges} expect={expect}"
        );
    }

    #[test]
    fn deterministic_membership() {
        let g = ErdosRenyi::new(128, 0.3, 9);
        for a in 0..20u32 {
            for b in (a + 1)..20 {
                assert_eq!(g.contains(a, b), g.contains(a, b));
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = ErdosRenyi::new(256, 0.5, 1);
        let b = ErdosRenyi::new(256, 0.5, 2);
        let diff = (0..255u32)
            .filter(|&x| a.contains(x, x + 1) != b.contains(x, x + 1))
            .count();
        assert!(diff > 40);
    }

    #[test]
    fn extreme_probabilities() {
        let none = ErdosRenyi::new(64, 0.0, 3);
        let all = ErdosRenyi::new(64, 1.0, 3);
        assert_eq!(count_edges(&none), 0);
        assert_eq!(count_edges(&all), 64 * 63 / 2);
    }
}
