//! Real-world-*like* edge models.
//!
//! The container has no network access to SNAP / NetworkRepository, so
//! the paper's five real datasets are substituted by generators matched
//! in vertex count (scaled where RAM requires), edge count, and degree
//! structure class — see DESIGN.md "Substitutions".  What Table 3's
//! regimes actually depend on is (a) |updates| relative to the
//! leaf-fullness threshold and (b) density, which these match.
//!
//! * [`ChungLu`] — power-law expected degrees (`google-plus`-like heavy
//!   tail, `web-uk`-like when dense, `ca-citeseer`-like when sparse).
//! * [`GridLike`] — near-planar lattice with sparse shortcuts
//!   (`rec-amazon`-like product-co-purchase structure).
//! * [`SparseRandom`] — thin Erdős–Rényi (`p2p-gnutella`-like overlay).

use crate::hashing::splitmix64;
use crate::sketch::params::encode_edge;
use crate::stream::erdos::ErdosRenyi;
use crate::stream::EdgeModel;

/// Chung–Lu model: P[(a,b)] = min(1, w_a·w_b / S) with Zipfian weights
/// w_i ∝ (i+1)^-beta scaled so the expected edge count hits a target.
#[derive(Clone, Debug)]
pub struct ChungLu {
    v: u64,
    beta: f64,
    /// per-vertex weights (computed once; O(V) memory)
    weights: Vec<f64>,
    weight_sum: f64,
    seed: u64,
}

impl ChungLu {
    /// `beta` in (0, 1) keeps the weight sum heavy-tailed but summable
    /// enough for Chung–Lu; `target_edges` sets the scale.
    pub fn new(v: u64, beta: f64, target_edges: u64, seed: u64) -> Self {
        assert!(v >= 2);
        let mut weights: Vec<f64> = (0..v).map(|i| ((i + 1) as f64).powf(-beta)).collect();
        let raw_sum: f64 = weights.iter().sum();
        // E[edges] = sum_{i<j} w_i w_j / S ≈ S/2 when S = sum of weights;
        // scale weights so S = 2·target.
        let scale = (2.0 * target_edges as f64) / raw_sum;
        for w in &mut weights {
            *w *= scale.max(f64::MIN_POSITIVE);
        }
        let weight_sum: f64 = weights.iter().sum();
        Self {
            v,
            beta,
            weights,
            weight_sum,
            seed,
        }
    }

    /// The Zipf exponent this model was built with.
    pub fn beta(&self) -> f64 {
        self.beta
    }

    #[inline]
    fn probability(&self, a: u32, b: u32) -> f64 {
        (self.weights[a as usize] * self.weights[b as usize] / self.weight_sum).min(1.0)
    }
}

impl EdgeModel for ChungLu {
    fn num_vertices(&self) -> u64 {
        self.v
    }

    #[inline]
    fn contains(&self, a: u32, b: u32) -> bool {
        let p = self.probability(a, b);
        if p <= 0.0 {
            return false;
        }
        let idx = encode_edge(a, b, self.v);
        let h = splitmix64(self.seed ^ idx.wrapping_mul(0x589965CC75374CC3));
        (h as f64) < p * 2f64.powi(64)
    }

    fn expected_edges(&self) -> f64 {
        // S/2 minus the diagonal correction; close enough for reporting
        self.weight_sum / 2.0
    }
}

/// Near-planar lattice: vertices on a ⌈√V⌉ grid, edges between 4-neighbors
/// with probability `p_local`, plus hash-sparse long-range shortcuts.
#[derive(Clone, Copy, Debug)]
pub struct GridLike {
    v: u64,
    side: u32,
    p_local: f64,
    shortcut_per_vertex: f64,
    seed: u64,
}

impl GridLike {
    pub fn new(v: u64, p_local: f64, shortcut_per_vertex: f64, seed: u64) -> Self {
        let side = (v as f64).sqrt().ceil() as u32;
        Self {
            v,
            side,
            p_local,
            shortcut_per_vertex,
            seed,
        }
    }

    #[inline]
    fn coords(&self, x: u32) -> (u32, u32) {
        (x / self.side, x % self.side)
    }
}

impl EdgeModel for GridLike {
    fn num_vertices(&self) -> u64 {
        self.v
    }

    #[inline]
    fn contains(&self, a: u32, b: u32) -> bool {
        let (ra, ca) = self.coords(a);
        let (rb, cb) = self.coords(b);
        let idx = encode_edge(a, b, self.v);
        let h = splitmix64(self.seed ^ idx.wrapping_mul(0x1D8E4E27C47D124F));
        let manhattan = ra.abs_diff(rb) + ca.abs_diff(cb);
        if manhattan == 1 {
            (h as f64) < self.p_local * 2f64.powi(64)
        } else {
            // long-range shortcut probability tuned to the target rate
            let p = self.shortcut_per_vertex / self.v as f64;
            (h as f64) < p * 2f64.powi(64)
        }
    }

    fn expected_edges(&self) -> f64 {
        let lattice = 2.0 * self.v as f64; // ~2V grid-adjacent pairs
        lattice * self.p_local + self.shortcut_per_vertex * self.v as f64 / 2.0
    }
}

/// Thin overlay network (`p2p-gnutella`-like): plain sparse G(V, p) with
/// p chosen from a target average degree.
#[derive(Clone, Copy, Debug)]
pub struct SparseRandom {
    inner: ErdosRenyi,
}

impl SparseRandom {
    pub fn new(v: u64, avg_degree: f64, seed: u64) -> Self {
        let p = (avg_degree / (v - 1) as f64).min(1.0);
        Self {
            inner: ErdosRenyi::new(v, p, seed),
        }
    }
}

impl EdgeModel for SparseRandom {
    fn num_vertices(&self) -> u64 {
        self.inner.num_vertices()
    }
    fn contains(&self, a: u32, b: u32) -> bool {
        self.inner.contains(a, b)
    }
    fn expected_edges(&self) -> f64 {
        self.inner.expected_edges()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::count_edges;

    #[test]
    fn chung_lu_hits_target_edge_count() {
        let g = ChungLu::new(1 << 10, 0.45, 8000, 3);
        let edges = count_edges(&g) as f64;
        assert!(
            (edges - 8000.0).abs() / 8000.0 < 0.25,
            "edges={edges}"
        );
    }

    #[test]
    fn chung_lu_degrees_are_heavy_tailed() {
        let g = ChungLu::new(1 << 10, 0.5, 10000, 4);
        let v = 1u32 << 10;
        let degree = |x: u32| -> usize {
            (0..v)
                .filter(|&y| y != x && g.contains(x.min(y), x.max(y)))
                .count()
        };
        let top: usize = (0..8).map(degree).sum();
        let bottom: usize = (v - 8..v).map(degree).sum();
        assert!(top > 5 * bottom.max(1), "top={top} bottom={bottom}");
    }

    #[test]
    fn grid_is_mostly_local() {
        let g = GridLike::new(1 << 10, 0.9, 0.2, 5);
        let v = 1u32 << 10;
        let mut local = 0usize;
        let mut long = 0usize;
        for a in 0..v {
            for b in (a + 1)..v {
                if g.contains(a, b) {
                    let (ra, ca) = (a / g.side, a % g.side);
                    let (rb, cb) = (b / g.side, b % g.side);
                    if ra.abs_diff(rb) + ca.abs_diff(cb) == 1 {
                        local += 1;
                    } else {
                        long += 1;
                    }
                }
            }
        }
        assert!(local > 5 * long.max(1), "local={local} long={long}");
    }

    #[test]
    fn sparse_random_degree_matches() {
        let g = SparseRandom::new(1 << 11, 4.8, 6);
        let edges = count_edges(&g) as f64;
        let expect = 4.8 * (1 << 11) as f64 / 2.0;
        assert!((edges - expect).abs() / expect < 0.15, "edges={edges}");
    }

    #[test]
    fn all_models_deterministic() {
        let cl = ChungLu::new(256, 0.4, 1000, 1);
        let gl = GridLike::new(256, 0.8, 0.5, 1);
        let sr = SparseRandom::new(256, 4.0, 1);
        for a in 0..40u32 {
            for b in (a + 1)..40 {
                assert_eq!(cl.contains(a, b), cl.contains(a, b));
                assert_eq!(gl.contains(a, b), gl.contains(a, b));
                assert_eq!(sr.contains(a, b), sr.contains(a, b));
            }
        }
    }
}
