//! Graph streams: the semi-streaming input model (paper §3), synthetic
//! dataset generators matching the paper's evaluation suite (§7.1), the
//! insert/delete stream transform, and the 9-byte binary wire format.
//!
//! All generators are *deterministic functions of their seed* with O(1)
//! state: edge presence is decided by hash thresholds, and stream order
//! by Feistel permutations — no edge list is ever materialized, so
//! dense-graph streams far larger than RAM could be produced.

pub mod datasets;
pub mod dynamify;
pub mod erdos;
pub mod file;
pub mod kron;
pub mod permute;
pub mod realworld;
pub mod update;

pub use update::{Update, UpdateKind};

/// A graph-update stream: an iterator of updates plus its header data.
pub trait GraphStream: Iterator<Item = Update> {
    /// Number of vertices of the underlying graph.
    fn num_vertices(&self) -> u64;
    /// Total number of updates this stream will yield, if known.
    fn len_hint(&self) -> Option<u64>;
}

/// Edge-presence models: a deterministic membership oracle for the
/// *final* graph a stream defines.  `contains` must be a pure function
/// of (model, a, b) — generators derive presence from hash thresholds.
pub trait EdgeModel: Send + Sync {
    fn num_vertices(&self) -> u64;
    /// Membership test; callers guarantee a < b < V.
    fn contains(&self, a: u32, b: u32) -> bool;
    /// Expected number of edges (for sizing / reporting).
    fn expected_edges(&self) -> f64;
}

/// Exact edge count by full enumeration — O(V²), for tests and the
/// dataset-inventory bench on small V.
pub fn count_edges<M: EdgeModel>(model: &M) -> u64 {
    let v = model.num_vertices() as u32;
    let mut n = 0;
    for a in 0..v {
        for b in (a + 1)..v {
            if model.contains(a, b) {
                n += 1;
            }
        }
    }
    n
}

/// Materialize a model's edge list — tests only.
pub fn edge_list<M: EdgeModel>(model: &M) -> Vec<(u32, u32)> {
    let v = model.num_vertices() as u32;
    let mut edges = Vec::new();
    for a in 0..v {
        for b in (a + 1)..v {
            if model.contains(a, b) {
                edges.push((a, b));
            }
        }
    }
    edges
}

/// An in-memory stream over a materialized update vector (tests, small
/// benches, and file replay).
pub struct VecStream {
    vertices: u64,
    updates: std::vec::IntoIter<Update>,
    total: u64,
}

impl VecStream {
    pub fn new(vertices: u64, updates: Vec<Update>) -> Self {
        let total = updates.len() as u64;
        Self {
            vertices,
            updates: updates.into_iter(),
            total,
        }
    }
}

impl Iterator for VecStream {
    type Item = Update;
    fn next(&mut self) -> Option<Update> {
        self.updates.next()
    }
}

impl GraphStream for VecStream {
    fn num_vertices(&self) -> u64 {
        self.vertices
    }
    fn len_hint(&self) -> Option<u64> {
        Some(self.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Tiny;
    impl EdgeModel for Tiny {
        fn num_vertices(&self) -> u64 {
            4
        }
        fn contains(&self, a: u32, b: u32) -> bool {
            (a, b) == (0, 1) || (a, b) == (2, 3)
        }
        fn expected_edges(&self) -> f64 {
            2.0
        }
    }

    #[test]
    fn count_and_list_agree() {
        assert_eq!(count_edges(&Tiny), 2);
        assert_eq!(edge_list(&Tiny), vec![(0, 1), (2, 3)]);
    }

    #[test]
    fn vec_stream_reports_header() {
        let s = VecStream::new(4, vec![Update::insert(0, 1)]);
        assert_eq!(s.num_vertices(), 4);
        assert_eq!(s.len_hint(), Some(1));
        assert_eq!(s.collect::<Vec<_>>().len(), 1);
    }
}
