//! Binary stream file format ("LSTRM1"): header + 9-byte update records.
//!
//! Matches the paper's setup where streams are read from files by the
//! main node's ingest threads.  Layout:
//!
//! ```text
//! magic   [8]  b"LSTRM1\0\0"
//! version u32  le
//! vertices u64 le
//! count   u64  le
//! records count × 9 bytes (see Update::to_bytes)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::stream::{GraphStream, Update};

const MAGIC: &[u8; 8] = b"LSTRM1\0\0";
const VERSION: u32 = 1;

/// Write a full stream to `path`.
pub fn write_stream<S: GraphStream>(path: &Path, stream: S) -> std::io::Result<u64> {
    let vertices = stream.num_vertices();
    let mut w = BufWriter::with_capacity(1 << 20, File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&vertices.to_le_bytes())?;
    // count patched after the fact via a second header write
    w.write_all(&0u64.to_le_bytes())?;
    let mut count = 0u64;
    for upd in stream {
        w.write_all(&upd.to_bytes())?;
        count += 1;
    }
    w.flush()?;
    drop(w);
    // patch the count field (offset 20)
    use std::io::{Seek, SeekFrom};
    let mut f = std::fs::OpenOptions::new().write(true).open(path)?;
    f.seek(SeekFrom::Start(20))?;
    f.write_all(&count.to_le_bytes())?;
    Ok(count)
}

/// Buffered reader over a stream file.
pub struct FileStream {
    reader: BufReader<File>,
    vertices: u64,
    count: u64,
    read: u64,
}

impl FileStream {
    pub fn open(path: &Path) -> std::io::Result<Self> {
        let mut reader = BufReader::with_capacity(1 << 20, File::open(path)?);
        let mut magic = [0u8; 8];
        reader.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad stream magic",
            ));
        }
        let mut buf4 = [0u8; 4];
        reader.read_exact(&mut buf4)?;
        let version = u32::from_le_bytes(buf4);
        if version != VERSION {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unsupported stream version {version}"),
            ));
        }
        let mut buf8 = [0u8; 8];
        reader.read_exact(&mut buf8)?;
        let vertices = u64::from_le_bytes(buf8);
        reader.read_exact(&mut buf8)?;
        let count = u64::from_le_bytes(buf8);
        Ok(Self {
            reader,
            vertices,
            count,
            read: 0,
        })
    }

    /// Declared update count from the header.
    pub fn declared_count(&self) -> u64 {
        self.count
    }
}

impl Iterator for FileStream {
    type Item = Update;
    fn next(&mut self) -> Option<Update> {
        if self.read >= self.count {
            return None;
        }
        let mut rec = [0u8; 9];
        self.reader.read_exact(&mut rec).ok()?;
        self.read += 1;
        Update::from_bytes(&rec).ok()
    }
}

impl GraphStream for FileStream {
    fn num_vertices(&self) -> u64 {
        self.vertices
    }
    fn len_hint(&self) -> Option<u64> {
        Some(self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::dynamify::Dynamify;
    use crate::stream::erdos::ErdosRenyi;
    use crate::stream::VecStream;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("landscape_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_small_stream() {
        let path = tmpfile("roundtrip.lstrm");
        let updates = vec![
            Update::insert(0, 1),
            Update::insert(2, 3),
            Update::delete(0, 1),
        ];
        let n = write_stream(&path, VecStream::new(8, updates.clone())).unwrap();
        assert_eq!(n, 3);
        let fs = FileStream::open(&path).unwrap();
        assert_eq!(fs.num_vertices(), 8);
        assert_eq!(fs.declared_count(), 3);
        assert_eq!(fs.collect::<Vec<_>>(), updates);
    }

    #[test]
    fn roundtrip_generated_stream() {
        let path = tmpfile("generated.lstrm");
        let make = || Dynamify::new(ErdosRenyi::new(64, 0.2, 9), 3);
        let want: Vec<Update> = make().collect();
        write_stream(&path, make()).unwrap();
        let got: Vec<Update> = FileStream::open(&path).unwrap().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("bad.lstrm");
        std::fs::write(&path, b"NOTASTREAMFILE\0\0\0\0\0\0\0\0\0\0\0\0").unwrap();
        assert!(FileStream::open(&path).is_err());
    }

    #[test]
    fn file_size_is_header_plus_9n() {
        let path = tmpfile("size.lstrm");
        let updates: Vec<Update> = (0..100).map(|i| Update::insert(i, i + 1)).collect();
        write_stream(&path, VecStream::new(256, updates)).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(len, 28 + 100 * 9);
    }
}
