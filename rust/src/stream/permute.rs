//! Feistel-network index permutations.
//!
//! Stream generators need each "round" of the insert/delete transform to
//! visit the candidate edge space in a different pseudo-random order
//! *without materializing a permutation array* (the candidate space is
//! V², far too large).  A balanced 4-round Feistel network over a
//! 2w-bit domain is a bijection computable in O(1) per element, seeded
//! per round.

use crate::hashing::splitmix64;

/// A bijection over `[0, 2^(2·half_bits))`.
#[derive(Clone, Copy, Debug)]
pub struct FeistelPermutation {
    half_bits: u32,
    keys: [u64; 4],
}

impl FeistelPermutation {
    /// A permutation over a domain of at least `min_size`, rounded up to
    /// the next even power of two.  `min_size ≥ 1`.
    pub fn covering(min_size: u64, seed: u64) -> Self {
        let bits = 64 - (min_size.max(2) - 1).leading_zeros();
        let half_bits = bits.div_ceil(2).max(1);
        let mut keys = [0u64; 4];
        for (i, k) in keys.iter_mut().enumerate() {
            *k = splitmix64(seed ^ (i as u64 + 1).wrapping_mul(0xA0761D6478BD642F));
        }
        Self { half_bits, keys }
    }

    /// Domain size 2^(2·half_bits).
    pub fn domain(&self) -> u64 {
        1u64 << (2 * self.half_bits)
    }

    /// Apply the permutation.
    #[inline]
    pub fn apply(&self, x: u64) -> u64 {
        debug_assert!(x < self.domain());
        let mask = (1u64 << self.half_bits) - 1;
        let mut left = x >> self.half_bits;
        let mut right = x & mask;
        for &k in &self.keys {
            let f = splitmix64(right ^ k) & mask;
            let new_right = left ^ f;
            left = right;
            right = new_right;
        }
        (left << self.half_bits) | right
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::Cases;

    #[test]
    fn is_a_bijection_on_small_domains() {
        Cases::new(10).run(|rng| {
            let p = FeistelPermutation::covering(1 + rng.next_below(4000), rng.next_u64());
            let n = p.domain();
            assert!(n <= 1 << 13, "test domain kept small");
            let mut seen = vec![false; n as usize];
            for x in 0..n {
                let y = p.apply(x) as usize;
                assert!(!seen[y], "collision at {x} -> {y}");
                seen[y] = true;
            }
        });
    }

    #[test]
    fn domain_covers_min_size() {
        for min in [1u64, 2, 3, 100, 1 << 20, (1 << 26) + 1] {
            let p = FeistelPermutation::covering(min, 7);
            assert!(p.domain() >= min, "domain {} < {min}", p.domain());
        }
    }

    #[test]
    fn different_seeds_give_different_orders() {
        let a = FeistelPermutation::covering(1 << 10, 1);
        let b = FeistelPermutation::covering(1 << 10, 2);
        let same = (0..1024).filter(|&x| a.apply(x) == b.apply(x)).count();
        assert!(same < 8, "{same} agreements");
    }

    #[test]
    fn order_looks_shuffled() {
        // successive outputs shouldn't be successive inputs
        let p = FeistelPermutation::covering(1 << 12, 3);
        let monotone_pairs = (0..4095u64)
            .filter(|&x| p.apply(x) + 1 == p.apply(x + 1))
            .count();
        assert!(monotone_pairs < 10);
    }
}
