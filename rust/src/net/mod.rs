//! Wire protocol between the main node and remote workers, plus exact
//! byte accounting.
//!
//! The paper uses OpenMPI; this environment vendors no MPI (or tokio),
//! so the transport is length-framed messages over TCP with blocking
//! I/O — one coordinator connection per worker thread, which matches
//! the paper's one-batch-in-flight-per-worker-CPU structure.  All sizes
//! are metered at the framing layer so Theorem 5.2's communication
//! bound is validated against real serialized bytes.
//!
//! Frames (all little-endian):
//!
//! ```text
//! HELLO    tag=0  u64 vertices, u32 columns, u64 graph_seed, u32 k
//! BATCH    tag=1  u32 vertex, u32 count, count×u64 indices
//! DELTA    tag=2  u32 vertex, u32 words, words×u64 delta
//! SHUTDOWN tag=3
//! ```

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

/// Protocol messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Message {
    Hello {
        vertices: u64,
        columns: u32,
        graph_seed: u64,
        k: u32,
    },
    Batch {
        vertex: u32,
        others: Vec<u32>,
    },
    Delta {
        vertex: u32,
        delta: Vec<u64>,
    },
    Shutdown,
}

impl Message {
    /// Serialized size in bytes (tag + header + payload).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Message::Hello { .. } => 1 + 8 + 4 + 8 + 4,
            Message::Batch { others, .. } => 1 + 4 + 4 + others.len() as u64 * 4,
            Message::Delta { delta, .. } => 1 + 4 + 4 + delta.len() as u64 * 8,
            Message::Shutdown => 1,
        }
    }

    /// Write the frame; returns bytes written.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<u64> {
        match self {
            Message::Hello {
                vertices,
                columns,
                graph_seed,
                k,
            } => {
                w.write_all(&[0u8])?;
                w.write_all(&vertices.to_le_bytes())?;
                w.write_all(&columns.to_le_bytes())?;
                w.write_all(&graph_seed.to_le_bytes())?;
                w.write_all(&k.to_le_bytes())?;
            }
            Message::Batch { vertex, others } => {
                w.write_all(&[1u8])?;
                w.write_all(&vertex.to_le_bytes())?;
                w.write_all(&(others.len() as u32).to_le_bytes())?;
                for x in others {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Message::Delta { vertex, delta } => {
                w.write_all(&[2u8])?;
                w.write_all(&vertex.to_le_bytes())?;
                w.write_all(&(delta.len() as u32).to_le_bytes())?;
                for x in delta {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Message::Shutdown => {
                w.write_all(&[3u8])?;
            }
        }
        w.flush()?;
        Ok(self.wire_bytes())
    }

    /// Read one frame.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Message> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        match tag[0] {
            0 => {
                let vertices = read_u64(r)?;
                let columns = read_u32(r)?;
                let graph_seed = read_u64(r)?;
                let k = read_u32(r)?;
                Ok(Message::Hello {
                    vertices,
                    columns,
                    graph_seed,
                    k,
                })
            }
            1 => {
                let vertex = read_u32(r)?;
                let count = read_u32(r)? as usize;
                if count > (1 << 28) {
                    bail!("batch too large: {count}");
                }
                Ok(Message::Batch {
                    vertex,
                    others: read_u32s(r, count)?,
                })
            }
            2 => {
                let vertex = read_u32(r)?;
                let words = read_u32(r)? as usize;
                if words > (1 << 28) {
                    bail!("delta too large: {words}");
                }
                Ok(Message::Delta {
                    vertex,
                    delta: read_u64s(r, words)?,
                })
            }
            3 => Ok(Message::Shutdown),
            t => Err(anyhow!("unknown frame tag {t}")),
        }
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_u64s<R: Read>(r: &mut R, n: usize) -> Result<Vec<u64>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        let n = msg.write_to(&mut buf).unwrap();
        assert_eq!(n as usize, buf.len(), "wire_bytes must match actual bytes");
        let got = Message::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Message::Hello {
            vertices: 1 << 17,
            columns: 3,
            graph_seed: 0xDEAD,
            k: 4,
        });
        roundtrip(Message::Batch {
            vertex: 9,
            others: vec![1, 2, u32::MAX],
        });
        roundtrip(Message::Delta {
            vertex: 9,
            delta: vec![0, 5, 7, 9],
        });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn unknown_tag_rejected() {
        let buf = [42u8];
        assert!(Message::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        Message::Batch {
            vertex: 1,
            others: vec![1, 2, 3],
        }
        .write_to(&mut buf)
        .unwrap();
        buf.truncate(buf.len() - 4);
        assert!(Message::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn batch_bytes_match_hypertree_accounting() {
        // the coordinator accounts batches via VertexBatch::wire_bytes;
        // the framed message must agree within the 1-byte tag + header
        let others = vec![1u32; 100];
        let msg = Message::Batch {
            vertex: 0,
            others: others.clone(),
        };
        let vb = crate::hypertree::VertexBatch { vertex: 0, others };
        // framing: 1+4+4 vs accounting 8 — both linear with 4B/update
        assert!((msg.wire_bytes() as i64 - vb.wire_bytes() as i64).abs() <= 8);
    }
}
