//! Wire protocol between the main node and remote workers, plus exact
//! byte accounting.
//!
//! The paper uses OpenMPI; this environment vendors no MPI (or tokio),
//! so the transport is length-framed messages over TCP with blocking
//! I/O — one coordinator connection per worker thread.  All sizes are
//! metered at the framing layer so Theorem 5.2's communication bound is
//! validated against real serialized bytes.
//!
//! Protocol v1 (lockstep) runs one BATCH/DELTA exchange at a time.
//! Protocol v2 adds sequence tags so a distributor can keep a window of
//! batches in flight and consume deltas **out of order** (XOR merging
//! commutes), a coalesced MULTIBATCH frame that amortizes per-frame
//! headers across a burst, and an explicit ERROR/BYE close handshake so
//! both sides can tell a clean drain from a dead peer.
//!
//! Frames (all little-endian):
//!
//! ```text
//! HELLO      tag=0  u64 vertices, u32 columns, u64 graph_seed, u32 k, u32 threshold
//! BATCH      tag=1  u32 vertex, u32 count, count×u32 other-endpoints
//! DELTA      tag=2  u32 vertex, u32 words, words×u64 delta
//! SHUTDOWN   tag=3
//! BATCH2     tag=4  u64 seq, u32 vertex, u32 count, count×u32 other-endpoints
//! DELTA2     tag=5  u64 seq, u32 vertex, u32 words, words×u64 delta
//! MULTIBATCH tag=6  u32 count, count×(u64 seq, u32 vertex, u32 n, n×u32)
//! ERROR      tag=7  u32 code, u32 len, len×u8 utf-8 reason
//! BYE        tag=8
//! EXACTDELTA2 tag=9 u64 seq, u32 vertex, u32 count, count×u64 edge-indices
//! TBATCH2    tag=10 u32 tenant, u64 seq, u32 vertex, u32 count, count×u32 other-endpoints
//! TDELTA2    tag=11 u32 tenant, u64 seq, u32 vertex, u32 words, words×u64 delta
//! ```
//!
//! TBATCH2/TDELTA2 are the multi-tenant serving layer's tagged
//! generation of BATCH2/DELTA2: the 4-byte tenant id travels with the
//! batch and is echoed on the delta, so one worker connection can carry
//! interleaved batches of N logical graphs while the coordinator meters
//! each tenant's wire bytes separately (Theorem 5.2 per tenant — see
//! docs/SERVING.md).  Workers stay tenant-oblivious: every tenant
//! shares the fabric's sketch parameters and graph seed, so the delta
//! computation is identical and the tag is pure routing metadata.
//! Tagged batches are deliberately sent as standalone frames (no
//! TMULTIBATCH): coalescing would amortize ~1 byte per batch but smear
//! frame bytes across tenants, and exact per-tenant accounting is the
//! point.
//!
//! HELLO's `threshold` is the hybrid handshake (0 = sketch deltas
//! only): batches whose odd-parity index count is ≤ threshold are
//! answered with an EXACTDELTA2 — the raw surviving edge indices, one
//! copy-independent list — instead of a DELTA2 of k sketch deltas.
//! Exact frames are byte-metered like any other delta leg, so Theorem
//! 5.2's communication accounting stays exact under the hybrid scheme.
//!
//! BATCH/BATCH2 payloads are the batch's **other endpoints** (`u32`
//! each); the worker reconstructs the `u64` edge indices itself via
//! `encode_edge(vertex, other)` — shipping endpoints instead of indices
//! halves the batch leg's bytes and moves the encode cost to the worker.

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

/// One sequence-tagged batch inside a MULTIBATCH frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SeqBatch {
    pub seq: u64,
    pub vertex: u32,
    pub others: Vec<u32>,
}

impl SeqBatch {
    /// Bytes this entry contributes to a MULTIBATCH payload.
    pub fn entry_bytes(&self) -> u64 {
        8 + 4 + 4 + self.others.len() as u64 * 4
    }
}

/// Protocol messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Message {
    Hello {
        vertices: u64,
        columns: u32,
        graph_seed: u64,
        k: u32,
        /// Hybrid handshake: answer batches with ≤ this many odd-parity
        /// indices as EXACTDELTA2 frames (0 = always sketch deltas).
        threshold: u32,
    },
    Batch {
        vertex: u32,
        others: Vec<u32>,
    },
    Delta {
        vertex: u32,
        delta: Vec<u64>,
    },
    Shutdown,
    /// v2: a sequence-tagged batch (answered by a [`Message::Delta2`]
    /// with the same `seq`, in any order).
    Batch2 {
        seq: u64,
        vertex: u32,
        others: Vec<u32>,
    },
    /// v2: the delta for the batch submitted under `seq`.
    Delta2 {
        seq: u64,
        vertex: u32,
        delta: Vec<u64>,
    },
    /// v2: a burst of sequence-tagged batches in one frame.
    MultiBatch { batches: Vec<SeqBatch> },
    /// v2 hybrid: an exact-set delta for the batch submitted under
    /// `seq` — the batch's odd-parity encoded edge indices, valid for
    /// every sketch copy (indices are seed-independent).
    ExactDelta2 {
        seq: u64,
        vertex: u32,
        indices: Vec<u64>,
    },
    /// Multi-tenant v2: a sequence-tagged batch belonging to logical
    /// graph `tenant` (answered by a [`Message::TDelta2`] echoing both
    /// `tenant` and `seq`, in any order).
    TBatch2 {
        tenant: u32,
        seq: u64,
        vertex: u32,
        others: Vec<u32>,
    },
    /// Multi-tenant v2: the delta for the batch submitted under
    /// (`tenant`, `seq`).
    TDelta2 {
        tenant: u32,
        seq: u64,
        vertex: u32,
        delta: Vec<u64>,
    },
    /// v2: fatal protocol/backend error; the sender closes after this.
    Error { code: u32, reason: String },
    /// v2: clean-close acknowledgement — the worker has answered every
    /// batch it read and is closing.
    Bye,
}

/// Exact wire size of a DELTA2 frame carrying `words` u64 words.
pub fn delta2_wire_bytes(words: usize) -> u64 {
    1 + 8 + 4 + 4 + words as u64 * 8
}

/// Exact wire size of an EXACTDELTA2 frame carrying `count` indices.
pub fn exact_delta2_wire_bytes(count: usize) -> u64 {
    1 + 8 + 4 + 4 + count as u64 * 8
}

/// Exact wire size of a TBATCH2 frame carrying `count` other-endpoints.
pub fn tbatch2_wire_bytes(count: usize) -> u64 {
    1 + 4 + 8 + 4 + 4 + count as u64 * 4
}

/// Exact wire size of a TDELTA2 frame carrying `words` u64 words.
pub fn tdelta2_wire_bytes(words: usize) -> u64 {
    1 + 4 + 8 + 4 + 4 + words as u64 * 8
}

impl Message {
    /// Serialized size in bytes (tag + header + payload).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Message::Hello { .. } => 1 + 8 + 4 + 8 + 4 + 4,
            Message::Batch { others, .. } => 1 + 4 + 4 + others.len() as u64 * 4,
            Message::Delta { delta, .. } => 1 + 4 + 4 + delta.len() as u64 * 8,
            Message::Shutdown => 1,
            Message::Batch2 { others, .. } => 1 + 8 + 4 + 4 + others.len() as u64 * 4,
            Message::Delta2 { delta, .. } => delta2_wire_bytes(delta.len()),
            Message::MultiBatch { batches } => {
                1 + 4 + batches.iter().map(SeqBatch::entry_bytes).sum::<u64>()
            }
            Message::ExactDelta2 { indices, .. } => exact_delta2_wire_bytes(indices.len()),
            Message::TBatch2 { others, .. } => tbatch2_wire_bytes(others.len()),
            Message::TDelta2 { delta, .. } => tdelta2_wire_bytes(delta.len()),
            Message::Error { reason, .. } => 1 + 4 + 4 + reason.len() as u64,
            Message::Bye => 1,
        }
    }

    /// Write the frame; returns bytes written.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<u64> {
        match self {
            Message::Hello {
                vertices,
                columns,
                graph_seed,
                k,
                threshold,
            } => {
                w.write_all(&[0u8])?;
                w.write_all(&vertices.to_le_bytes())?;
                w.write_all(&columns.to_le_bytes())?;
                w.write_all(&graph_seed.to_le_bytes())?;
                w.write_all(&k.to_le_bytes())?;
                w.write_all(&threshold.to_le_bytes())?;
            }
            Message::Batch { vertex, others } => {
                w.write_all(&[1u8])?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u32s(w, others)?;
            }
            Message::Delta { vertex, delta } => {
                w.write_all(&[2u8])?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u64s(w, delta)?;
            }
            Message::Shutdown => {
                w.write_all(&[3u8])?;
            }
            Message::Batch2 {
                seq,
                vertex,
                others,
            } => {
                w.write_all(&[4u8])?;
                w.write_all(&seq.to_le_bytes())?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u32s(w, others)?;
            }
            Message::Delta2 { seq, vertex, delta } => {
                w.write_all(&[5u8])?;
                w.write_all(&seq.to_le_bytes())?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u64s(w, delta)?;
            }
            Message::MultiBatch { batches } => {
                w.write_all(&[6u8])?;
                w.write_all(&(batches.len() as u32).to_le_bytes())?;
                for b in batches {
                    w.write_all(&b.seq.to_le_bytes())?;
                    w.write_all(&b.vertex.to_le_bytes())?;
                    write_u32s(w, &b.others)?;
                }
            }
            Message::ExactDelta2 {
                seq,
                vertex,
                indices,
            } => {
                w.write_all(&[9u8])?;
                w.write_all(&seq.to_le_bytes())?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u64s(w, indices)?;
            }
            Message::TBatch2 {
                tenant,
                seq,
                vertex,
                others,
            } => {
                w.write_all(&[10u8])?;
                w.write_all(&tenant.to_le_bytes())?;
                w.write_all(&seq.to_le_bytes())?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u32s(w, others)?;
            }
            Message::TDelta2 {
                tenant,
                seq,
                vertex,
                delta,
            } => {
                w.write_all(&[11u8])?;
                w.write_all(&tenant.to_le_bytes())?;
                w.write_all(&seq.to_le_bytes())?;
                w.write_all(&vertex.to_le_bytes())?;
                write_u64s(w, delta)?;
            }
            Message::Error { code, reason } => {
                w.write_all(&[7u8])?;
                w.write_all(&code.to_le_bytes())?;
                w.write_all(&(reason.len() as u32).to_le_bytes())?;
                w.write_all(reason.as_bytes())?;
            }
            Message::Bye => {
                w.write_all(&[8u8])?;
            }
        }
        w.flush()?;
        Ok(self.wire_bytes())
    }

    /// Read one frame.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Message> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        match tag[0] {
            0 => {
                let vertices = read_u64(r)?;
                let columns = read_u32(r)?;
                let graph_seed = read_u64(r)?;
                let k = read_u32(r)?;
                let threshold = read_u32(r)?;
                Ok(Message::Hello {
                    vertices,
                    columns,
                    graph_seed,
                    k,
                    threshold,
                })
            }
            1 => {
                let vertex = read_u32(r)?;
                let count = read_count(r, "batch")?;
                Ok(Message::Batch {
                    vertex,
                    others: read_u32s(r, count)?,
                })
            }
            2 => {
                let vertex = read_u32(r)?;
                let words = read_count(r, "delta")?;
                Ok(Message::Delta {
                    vertex,
                    delta: read_u64s(r, words)?,
                })
            }
            3 => Ok(Message::Shutdown),
            4 => {
                let seq = read_u64(r)?;
                let vertex = read_u32(r)?;
                let count = read_count(r, "batch2")?;
                Ok(Message::Batch2 {
                    seq,
                    vertex,
                    others: read_u32s(r, count)?,
                })
            }
            5 => {
                let seq = read_u64(r)?;
                let vertex = read_u32(r)?;
                let words = read_count(r, "delta2")?;
                Ok(Message::Delta2 {
                    seq,
                    vertex,
                    delta: read_u64s(r, words)?,
                })
            }
            6 => {
                let count = read_count(r, "multibatch")?;
                let mut batches = Vec::with_capacity(count.min(1 << 16));
                for _ in 0..count {
                    let seq = read_u64(r)?;
                    let vertex = read_u32(r)?;
                    let n = read_count(r, "multibatch entry")?;
                    batches.push(SeqBatch {
                        seq,
                        vertex,
                        others: read_u32s(r, n)?,
                    });
                }
                Ok(Message::MultiBatch { batches })
            }
            7 => {
                let code = read_u32(r)?;
                let len = read_count(r, "error reason")?;
                let mut bytes = vec![0u8; len];
                r.read_exact(&mut bytes)?;
                Ok(Message::Error {
                    code,
                    reason: String::from_utf8_lossy(&bytes).into_owned(),
                })
            }
            8 => Ok(Message::Bye),
            9 => {
                let seq = read_u64(r)?;
                let vertex = read_u32(r)?;
                let count = read_count(r, "exactdelta2")?;
                Ok(Message::ExactDelta2 {
                    seq,
                    vertex,
                    indices: read_u64s(r, count)?,
                })
            }
            10 => {
                let tenant = read_u32(r)?;
                let seq = read_u64(r)?;
                let vertex = read_u32(r)?;
                let count = read_count(r, "tbatch2")?;
                Ok(Message::TBatch2 {
                    tenant,
                    seq,
                    vertex,
                    others: read_u32s(r, count)?,
                })
            }
            11 => {
                let tenant = read_u32(r)?;
                let seq = read_u64(r)?;
                let vertex = read_u32(r)?;
                let words = read_count(r, "tdelta2")?;
                Ok(Message::TDelta2 {
                    tenant,
                    seq,
                    vertex,
                    delta: read_u64s(r, words)?,
                })
            }
            t => Err(anyhow!("unknown frame tag {t}")),
        }
    }
}

/// Append a BATCH2 frame to a scatter buffer, byte-identical to
/// `Message::Batch2 { seq, vertex, others }.write_to(..)` — the
/// pipelined client pre-serializes frames from *borrowed* batches so
/// MULTIBATCH assembly never clones payloads or re-encodes per batch.
pub fn encode_batch2_into(buf: &mut Vec<u8>, seq: u64, vertex: u32, others: &[u32]) {
    buf.push(4u8);
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.extend_from_slice(&vertex.to_le_bytes());
    extend_u32s(buf, others);
}

/// Append a MULTIBATCH frame header (tag + entry count) to a scatter
/// buffer; follow with `count` [`encode_seq_batch_into`] entries for a
/// frame byte-identical to `Message::MultiBatch { .. }.write_to(..)`.
pub fn encode_multibatch_header_into(buf: &mut Vec<u8>, count: u32) {
    buf.push(6u8);
    buf.extend_from_slice(&count.to_le_bytes());
}

/// Append one MULTIBATCH entry (see [`encode_multibatch_header_into`]).
pub fn encode_seq_batch_into(buf: &mut Vec<u8>, seq: u64, vertex: u32, others: &[u32]) {
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.extend_from_slice(&vertex.to_le_bytes());
    extend_u32s(buf, others);
}

/// Append a TBATCH2 frame to a scatter buffer, byte-identical to
/// `Message::TBatch2 { tenant, seq, vertex, others }.write_to(..)` —
/// the tagged transport mode pre-serializes frames from borrowed
/// batches exactly like [`encode_batch2_into`].
pub fn encode_tbatch2_into(buf: &mut Vec<u8>, tenant: u32, seq: u64, vertex: u32, others: &[u32]) {
    buf.push(10u8);
    buf.extend_from_slice(&tenant.to_le_bytes());
    buf.extend_from_slice(&seq.to_le_bytes());
    buf.extend_from_slice(&vertex.to_le_bytes());
    extend_u32s(buf, others);
}

fn extend_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
    buf.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn read_count<R: Read>(r: &mut R, what: &str) -> Result<usize> {
    let n = read_u32(r)? as usize;
    if n > (1 << 28) {
        bail!("{what} too large: {n}");
    }
    Ok(n)
}

pub(crate) fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn write_u32s<W: Write>(w: &mut W, xs: &[u32]) -> Result<()> {
    w.write_all(&(xs.len() as u32).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn write_u64s<W: Write>(w: &mut W, xs: &[u64]) -> Result<()> {
    w.write_all(&(xs.len() as u32).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub(crate) fn read_u32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub(crate) fn read_u64s<R: Read>(r: &mut R, n: usize) -> Result<Vec<u64>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let mut buf = Vec::new();
        let n = msg.write_to(&mut buf).unwrap();
        assert_eq!(n as usize, buf.len(), "wire_bytes must match actual bytes");
        let got = Message::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn all_frames_roundtrip() {
        roundtrip(Message::Hello {
            vertices: 1 << 17,
            columns: 3,
            graph_seed: 0xDEAD,
            k: 4,
            threshold: 8,
        });
        roundtrip(Message::Batch {
            vertex: 9,
            others: vec![1, 2, u32::MAX],
        });
        roundtrip(Message::Delta {
            vertex: 9,
            delta: vec![0, 5, 7, 9],
        });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn v2_frames_roundtrip() {
        roundtrip(Message::Batch2 {
            seq: u64::MAX - 1,
            vertex: 7,
            others: vec![3, 4, 5],
        });
        roundtrip(Message::Delta2 {
            seq: 42,
            vertex: 7,
            delta: vec![9, 0, u64::MAX],
        });
        roundtrip(Message::MultiBatch {
            batches: vec![
                SeqBatch {
                    seq: 1,
                    vertex: 0,
                    others: vec![1],
                },
                SeqBatch {
                    seq: 2,
                    vertex: 5,
                    others: vec![],
                },
                SeqBatch {
                    seq: 3,
                    vertex: 9,
                    others: vec![2, 4, 6, 8],
                },
            ],
        });
        roundtrip(Message::Error {
            code: 2,
            reason: "bad frame".into(),
        });
        roundtrip(Message::Bye);
        roundtrip(Message::ExactDelta2 {
            seq: 11,
            vertex: 3,
            indices: vec![1, u64::MAX, 42],
        });
        roundtrip(Message::ExactDelta2 {
            seq: 12,
            vertex: 5,
            indices: vec![],
        });
    }

    #[test]
    fn tenant_frames_roundtrip() {
        roundtrip(Message::TBatch2 {
            tenant: 3,
            seq: u64::MAX - 7,
            vertex: 12,
            others: vec![1, 2, u32::MAX],
        });
        roundtrip(Message::TBatch2 {
            tenant: 0,
            seq: 0,
            vertex: 0,
            others: vec![],
        });
        roundtrip(Message::TDelta2 {
            tenant: 3,
            seq: 99,
            vertex: 12,
            delta: vec![0, u64::MAX, 17],
        });
    }

    #[test]
    fn tenant_wire_bytes_helpers_are_exact() {
        for count in [0usize, 1, 33] {
            let msg = Message::TBatch2 {
                tenant: 7,
                seq: 5,
                vertex: 1,
                others: vec![2u32; count],
            };
            assert_eq!(msg.wire_bytes(), tbatch2_wire_bytes(count));
        }
        for words in [0usize, 1, 17] {
            let msg = Message::TDelta2 {
                tenant: 7,
                seq: 5,
                vertex: 1,
                delta: vec![0u64; words],
            };
            assert_eq!(msg.wire_bytes(), tdelta2_wire_bytes(words));
        }
        // the tenant tag costs exactly 4 bytes over the untagged frames
        assert_eq!(tbatch2_wire_bytes(9), 4 + 1 + 8 + 4 + 4 + 9 * 4);
        assert_eq!(tdelta2_wire_bytes(9), delta2_wire_bytes(9) + 4);
    }

    #[test]
    fn tbatch2_scatter_encoder_matches_message_framing() {
        let msg = Message::TBatch2 {
            tenant: 5,
            seq: 77,
            vertex: 3,
            others: vec![1, 2, u32::MAX],
        };
        let mut want = Vec::new();
        msg.write_to(&mut want).unwrap();
        let mut got = Vec::new();
        encode_tbatch2_into(&mut got, 5, 77, 3, &[1, 2, u32::MAX]);
        assert_eq!(got, want);
        assert_eq!(got.len() as u64, msg.wire_bytes());
    }

    #[test]
    fn delta2_wire_bytes_helper_is_exact() {
        for words in [0usize, 1, 17] {
            let msg = Message::Delta2 {
                seq: 5,
                vertex: 1,
                delta: vec![0u64; words],
            };
            assert_eq!(msg.wire_bytes(), delta2_wire_bytes(words));
        }
    }

    #[test]
    fn exact_delta2_wire_bytes_helper_is_exact() {
        for count in [0usize, 1, 9] {
            let msg = Message::ExactDelta2 {
                seq: 5,
                vertex: 1,
                indices: vec![7u64; count],
            };
            assert_eq!(msg.wire_bytes(), exact_delta2_wire_bytes(count));
        }
        // a cold vertex's exact reply is far smaller than any sketch
        // delta: count ≤ threshold indices vs k × words() u64 words
        assert!(exact_delta2_wire_bytes(8) < delta2_wire_bytes(100));
    }

    #[test]
    fn multibatch_amortizes_headers_for_bursts() {
        // one MULTIBATCH of m entries = 5 + Σ(16 + 4·len) bytes vs
        // m × (17 + 4·len) for separate BATCH2 frames: each entry saves
        // the 1-byte tag against a 5-byte frame header, so coalescing
        // wins on bytes for bursts of more than 5 (and always wins on
        // write/flush syscalls)
        let make = |m: u64| -> Vec<SeqBatch> {
            (0..m)
                .map(|i| SeqBatch {
                    seq: i,
                    vertex: i as u32,
                    others: vec![1, 2],
                })
                .collect()
        };
        let singles = |batches: &[SeqBatch]| -> u64 {
            batches
                .iter()
                .map(|b| {
                    Message::Batch2 {
                        seq: b.seq,
                        vertex: b.vertex,
                        others: b.others.clone(),
                    }
                    .wire_bytes()
                })
                .sum()
        };
        let two = Message::MultiBatch { batches: make(2) };
        assert_eq!(two.wire_bytes(), 5 + 2 * (16 + 8));
        assert_eq!(singles(&make(2)), 2 * (17 + 8));
        let eight = Message::MultiBatch { batches: make(8) };
        assert_eq!(eight.wire_bytes(), 5 + 8 * (16 + 8));
        assert!(
            eight.wire_bytes() < singles(&make(8)),
            "coalescing must save bytes for a window-sized burst"
        );
    }

    #[test]
    fn scatter_encoders_match_message_framing() {
        // the pre-serialized scatter path must emit byte-identical
        // frames (and therefore identical wire_bytes accounting) to the
        // Message-based writer it replaces on the pipelined hot path
        let b2 = Message::Batch2 {
            seq: 77,
            vertex: 3,
            others: vec![1, 2, u32::MAX],
        };
        let mut want = Vec::new();
        b2.write_to(&mut want).unwrap();
        let mut got = Vec::new();
        encode_batch2_into(&mut got, 77, 3, &[1, 2, u32::MAX]);
        assert_eq!(got, want);
        assert_eq!(got.len() as u64, b2.wire_bytes());

        let entries = vec![
            SeqBatch {
                seq: 1,
                vertex: 0,
                others: vec![4, 5],
            },
            SeqBatch {
                seq: 2,
                vertex: 9,
                others: vec![],
            },
        ];
        let multi = Message::MultiBatch {
            batches: entries.clone(),
        };
        let mut want = Vec::new();
        multi.write_to(&mut want).unwrap();
        let mut got = Vec::new();
        encode_multibatch_header_into(&mut got, entries.len() as u32);
        for e in &entries {
            encode_seq_batch_into(&mut got, e.seq, e.vertex, &e.others);
        }
        assert_eq!(got, want);
        assert_eq!(got.len() as u64, multi.wire_bytes());
    }

    #[test]
    fn unknown_tag_rejected() {
        let buf = [42u8];
        assert!(Message::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let mut buf = Vec::new();
        Message::Batch2 {
            seq: 1,
            vertex: 1,
            others: vec![1, 2, 3],
        }
        .write_to(&mut buf)
        .unwrap();
        buf.truncate(buf.len() - 4);
        assert!(Message::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn batch_bytes_match_hypertree_accounting() {
        // the coordinator accounts batches via VertexBatch::wire_bytes;
        // the framed message must agree within the 1-byte tag + header
        let others = vec![1u32; 100];
        let msg = Message::Batch {
            vertex: 0,
            others: others.clone(),
        };
        let vb = crate::hypertree::VertexBatch { vertex: 0, others };
        // framing: 1+4+4 vs accounting 8 — both linear with 4B/update
        assert!((msg.wire_bytes() as i64 - vb.wire_bytes() as i64).abs() <= 8);
    }
}
