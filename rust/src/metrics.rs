//! Shared atomic counters for ingestion, communication, and query
//! accounting — the quantities the paper's tables report.

#![deny(missing_docs)]

use std::sync::atomic::{AtomicU64, Ordering};

/// Global coordinator metrics.  All counters are monotonic; snapshot
/// with [`Metrics::snapshot`].
#[derive(Debug, Default)]
pub struct Metrics {
    /// Stream updates ingested at the main node.
    pub updates_ingested: AtomicU64,
    /// Bytes of raw stream received (data-acquisition cost: 9·N).
    pub stream_bytes: AtomicU64,
    /// Bytes of vertex-based batches sent main → workers.  For remote
    /// workers this is the exact framing-layer byte count (HELLO + batch
    /// frames + SHUTDOWN, reconciled from each connection's writer); for
    /// in-process workers it is the nominal 8+4n accounting.
    pub batch_bytes_sent: AtomicU64,
    /// Bytes of sketch deltas received workers → main.
    pub delta_bytes_received: AtomicU64,
    /// Batches dispatched to workers.
    pub batches_sent: AtomicU64,
    /// Updates processed locally on the main node (underfull leaves).
    pub updates_local: AtomicU64,
    /// Sketch deltas merged.
    pub deltas_merged: AtomicU64,
    /// Full (Borůvka) queries answered.
    pub queries_full: AtomicU64,
    /// Queries served by the partial tier (warm-started Borůvka over
    /// dirty components only).
    pub queries_partial: AtomicU64,
    /// Queries served by GreedyCC.
    pub queries_greedy: AtomicU64,
    /// Components newly marked dirty by forest-edge deletions (clean →
    /// dirty transitions; the partial tier's workload driver).
    pub dirty_components: AtomicU64,
    /// Batches lost at the work-queue boundary (push onto a closed
    /// queue).  Nonzero means updates silently never reached a sketch —
    /// end-to-end tests assert this stays 0 at every query barrier.
    pub batches_dropped: AtomicU64,
    /// Hypertree node-to-node moves (cache-behaviour accounting).
    pub hypertree_moves: AtomicU64,
    /// Peak number of batches simultaneously in flight on any one
    /// remote-worker connection (1 = lockstep; > 1 proves pipelining).
    pub remote_in_flight_peak: AtomicU64,
    /// Batches resubmitted to a surviving worker after a connection
    /// death (failover requeues; these never count as dropped).
    pub batches_requeued: AtomicU64,
    /// Remote-worker connection deaths observed by distributors.
    pub worker_failures: AtomicU64,
    /// Ingest handles spawned from the session over its lifetime
    /// (producer-parallelism audit: the session API's N-producer story).
    pub handles_spawned: AtomicU64,
    /// Bounded per-handle update logs drained into the query engine.
    /// `updates_ingested / log_drains` ≈ the amortization factor keeping
    /// GreedyCC maintenance off the cross-thread hot path.
    pub log_drains: AtomicU64,
    /// The epoch barrier's currently open epoch (a monotone gauge,
    /// raised at every cut): how many stream cuts the session has
    /// lived through.
    pub epoch_current: AtomicU64,
    /// Stream cuts taken (queries, snapshots, and explicit flushes each
    /// take one; `cuts_taken == epoch_current` unless a barrier besides
    /// the session's is in play).
    pub cuts_taken: AtomicU64,
    /// Total microseconds spent blocked in `wait_for(cut)` — the
    /// read-side latency actually paid to the barrier, bounded by
    /// in-flight work at cut time rather than by stream length.
    pub cut_wait_us: AtomicU64,
    /// Hybrid-tier promotions: exact vertices whose observed degree
    /// crossed the threshold and were replayed into a fresh sketch
    /// block (counted on copy 0; all copies transition together).
    pub promotions: AtomicU64,
    /// Hybrid-tier demotions: promoted vertices whose tracked neighbor
    /// set shrank below the hysteresis floor and fell back to exact.
    pub demotions: AtomicU64,
    /// Bytes of EXACTDELTA2 frames received workers → main (a subset of
    /// `delta_bytes_received`: the compact-frame share of the delta leg).
    pub exact_bytes: AtomicU64,
    /// Gauge: vertices currently in the exact tier (copy 0; refreshed
    /// from store truth when a metrics snapshot is taken).
    pub vertices_exact: AtomicU64,
    /// Gauge: vertices currently holding a sketch block (copy 0).  In
    /// sketch-only mode this is all of them.
    pub vertices_sketched: AtomicU64,
    /// Gauge: resident CAMEO sketch bytes across all k copies.
    pub store_sketch_bytes: AtomicU64,
    /// Gauge: resident exact-set bytes across all k copies (hybrid only).
    pub store_exact_bytes: AtomicU64,
    /// Bytes written through to spill segment files (gutter flushes,
    /// LRU evictions, checkpoints) across all k copies.
    pub spill_bytes_written: AtomicU64,
    /// Bytes appended to the write-ahead log (record framing included).
    pub wal_bytes: AtomicU64,
    /// Cold sketch blocks faulted in from segment files across all k
    /// copies (second-touch promotions and query reads of spilled
    /// vertices).
    pub block_faults: AtomicU64,
    /// Gauge: CAMEO sketch bytes currently resident in memory across
    /// all k copies — for spill backings this is what the
    /// `resident_budget_bytes` knob bounds; for resident/hybrid
    /// backings it equals `store_sketch_bytes`.
    pub resident_sketch_bytes: AtomicU64,
    /// Sessions that came up through [`crate::Landscape::recover`]
    /// (WAL-tail replay over checkpointed segments).
    pub recoveries: AtomicU64,
    /// Ingest requests rejected by a tenant's admission quota (each got
    /// a THROTTLED reply with a retry-after hint, never a silent drop).
    /// On a per-tenant metrics object this counts that tenant only.
    pub quota_rejections: AtomicU64,
    /// Gauge: work items registered but not yet retired on the epoch
    /// barrier (per-tenant pipeline backlog; refreshed at snapshot).
    pub queue_depth: AtomicU64,
    /// Total microseconds of wall-clock query latency (connectivity,
    /// reachability, and k-connectivity entry points) — with
    /// `queries_full + queries_partial + queries_greedy` this gives the
    /// mean latency behind the serving layer's promptness checks.
    pub query_us: AtomicU64,
    /// Gauge: logical graphs currently registered on the serving
    /// fabric (1 on a plain single-tenant session's own metrics).
    pub tenants_active: AtomicU64,
}

/// A plain-value copy of [`Metrics`] — each field mirrors the counter
/// of the same name (see the field docs there for semantics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// See [`Metrics::updates_ingested`].
    pub updates_ingested: u64,
    /// See [`Metrics::stream_bytes`].
    pub stream_bytes: u64,
    /// See [`Metrics::batch_bytes_sent`].
    pub batch_bytes_sent: u64,
    /// See [`Metrics::delta_bytes_received`].
    pub delta_bytes_received: u64,
    /// See [`Metrics::batches_sent`].
    pub batches_sent: u64,
    /// See [`Metrics::updates_local`].
    pub updates_local: u64,
    /// See [`Metrics::deltas_merged`].
    pub deltas_merged: u64,
    /// See [`Metrics::queries_full`].
    pub queries_full: u64,
    /// See [`Metrics::queries_partial`].
    pub queries_partial: u64,
    /// See [`Metrics::queries_greedy`].
    pub queries_greedy: u64,
    /// See [`Metrics::dirty_components`].
    pub dirty_components: u64,
    /// See [`Metrics::batches_dropped`].
    pub batches_dropped: u64,
    /// See [`Metrics::hypertree_moves`].
    pub hypertree_moves: u64,
    /// See [`Metrics::remote_in_flight_peak`].
    pub remote_in_flight_peak: u64,
    /// See [`Metrics::batches_requeued`].
    pub batches_requeued: u64,
    /// See [`Metrics::worker_failures`].
    pub worker_failures: u64,
    /// See [`Metrics::handles_spawned`].
    pub handles_spawned: u64,
    /// See [`Metrics::log_drains`].
    pub log_drains: u64,
    /// See [`Metrics::epoch_current`].
    pub epoch_current: u64,
    /// See [`Metrics::cuts_taken`].
    pub cuts_taken: u64,
    /// See [`Metrics::cut_wait_us`].
    pub cut_wait_us: u64,
    /// See [`Metrics::promotions`].
    pub promotions: u64,
    /// See [`Metrics::demotions`].
    pub demotions: u64,
    /// See [`Metrics::exact_bytes`].
    pub exact_bytes: u64,
    /// See [`Metrics::vertices_exact`].
    pub vertices_exact: u64,
    /// See [`Metrics::vertices_sketched`].
    pub vertices_sketched: u64,
    /// See [`Metrics::store_sketch_bytes`].
    pub store_sketch_bytes: u64,
    /// See [`Metrics::store_exact_bytes`].
    pub store_exact_bytes: u64,
    /// See [`Metrics::spill_bytes_written`].
    pub spill_bytes_written: u64,
    /// See [`Metrics::wal_bytes`].
    pub wal_bytes: u64,
    /// See [`Metrics::block_faults`].
    pub block_faults: u64,
    /// See [`Metrics::resident_sketch_bytes`].
    pub resident_sketch_bytes: u64,
    /// See [`Metrics::recoveries`].
    pub recoveries: u64,
    /// See [`Metrics::quota_rejections`].
    pub quota_rejections: u64,
    /// See [`Metrics::queue_depth`].
    pub queue_depth: u64,
    /// See [`Metrics::query_us`].
    pub query_us: u64,
    /// See [`Metrics::tenants_active`].
    pub tenants_active: u64,
}

impl Metrics {
    /// Fresh counters, all zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` to `counter` (relaxed: counters are statistics, never
    /// synchronization).
    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        // lint: allow(relaxed-ordering) — statistics counter; carries no synchronization role, readers tolerate staleness
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite a gauge with `n` (point-in-time values refreshed from
    /// store truth, e.g. the hybrid tier counts).
    #[inline]
    pub fn set(counter: &AtomicU64, n: u64) {
        // lint: allow(relaxed-ordering) — statistics gauge; carries no synchronization role, readers tolerate staleness
        counter.store(n, Ordering::Relaxed);
    }

    /// Raise `counter` to at least `n` (peak/high-watermark gauges).
    #[inline]
    pub fn raise(counter: &AtomicU64, n: u64) {
        // lint: allow(relaxed-ordering) — statistics gauge; carries no synchronization role, readers tolerate staleness
        counter.fetch_max(n, Ordering::Relaxed);
    }

    /// The single relaxed-read site every snapshot field goes through.
    #[inline]
    fn rd(counter: &AtomicU64) -> u64 {
        // lint: allow(relaxed-ordering) — statistics read; cross-counter consistency is only promised at quiescence
        counter.load(Ordering::Relaxed)
    }

    /// A consistent-enough plain-value copy (each counter loaded
    /// relaxed; cross-counter invariants are only exact at quiescence).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            updates_ingested: Self::rd(&self.updates_ingested),
            stream_bytes: Self::rd(&self.stream_bytes),
            batch_bytes_sent: Self::rd(&self.batch_bytes_sent),
            delta_bytes_received: Self::rd(&self.delta_bytes_received),
            batches_sent: Self::rd(&self.batches_sent),
            updates_local: Self::rd(&self.updates_local),
            deltas_merged: Self::rd(&self.deltas_merged),
            queries_full: Self::rd(&self.queries_full),
            queries_partial: Self::rd(&self.queries_partial),
            queries_greedy: Self::rd(&self.queries_greedy),
            dirty_components: Self::rd(&self.dirty_components),
            batches_dropped: Self::rd(&self.batches_dropped),
            hypertree_moves: Self::rd(&self.hypertree_moves),
            remote_in_flight_peak: Self::rd(&self.remote_in_flight_peak),
            batches_requeued: Self::rd(&self.batches_requeued),
            worker_failures: Self::rd(&self.worker_failures),
            handles_spawned: Self::rd(&self.handles_spawned),
            log_drains: Self::rd(&self.log_drains),
            epoch_current: Self::rd(&self.epoch_current),
            cuts_taken: Self::rd(&self.cuts_taken),
            cut_wait_us: Self::rd(&self.cut_wait_us),
            promotions: Self::rd(&self.promotions),
            demotions: Self::rd(&self.demotions),
            exact_bytes: Self::rd(&self.exact_bytes),
            vertices_exact: Self::rd(&self.vertices_exact),
            vertices_sketched: Self::rd(&self.vertices_sketched),
            store_sketch_bytes: Self::rd(&self.store_sketch_bytes),
            store_exact_bytes: Self::rd(&self.store_exact_bytes),
            spill_bytes_written: Self::rd(&self.spill_bytes_written),
            wal_bytes: Self::rd(&self.wal_bytes),
            block_faults: Self::rd(&self.block_faults),
            resident_sketch_bytes: Self::rd(&self.resident_sketch_bytes),
            recoveries: Self::rd(&self.recoveries),
            quota_rejections: Self::rd(&self.quota_rejections),
            queue_depth: Self::rd(&self.queue_depth),
            query_us: Self::rd(&self.query_us),
            tenants_active: Self::rd(&self.tenants_active),
        }
    }
}

impl MetricsSnapshot {
    /// Total network bytes to/from the main node, excluding the input
    /// stream itself — the quantity Theorem 5.2 bounds.
    pub fn network_bytes(&self) -> u64 {
        self.batch_bytes_sent + self.delta_bytes_received
    }

    /// Network communication as a factor of stream size (Table 3's
    /// "Communication" column).
    pub fn communication_factor(&self) -> f64 {
        if self.stream_bytes == 0 {
            return 0.0;
        }
        self.network_bytes() as f64 / self.stream_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counts() {
        let m = Metrics::new();
        Metrics::add(&m.updates_ingested, 10);
        Metrics::add(&m.stream_bytes, 90);
        Metrics::add(&m.batch_bytes_sent, 100);
        Metrics::add(&m.delta_bytes_received, 44);
        let s = m.snapshot();
        assert_eq!(s.updates_ingested, 10);
        assert_eq!(s.network_bytes(), 144);
        assert!((s.communication_factor() - 1.6).abs() < 1e-9);
    }

    #[test]
    fn raise_is_a_high_watermark() {
        let m = Metrics::new();
        Metrics::raise(&m.remote_in_flight_peak, 4);
        Metrics::raise(&m.remote_in_flight_peak, 2);
        Metrics::raise(&m.remote_in_flight_peak, 9);
        assert_eq!(m.snapshot().remote_in_flight_peak, 9);
    }

    #[test]
    fn set_overwrites_a_gauge() {
        let m = Metrics::new();
        Metrics::set(&m.vertices_exact, 100);
        Metrics::set(&m.vertices_exact, 7);
        assert_eq!(m.snapshot().vertices_exact, 7, "gauges move both ways");
    }

    #[test]
    fn zero_stream_factor_is_zero() {
        assert_eq!(MetricsSnapshot::default().communication_factor(), 0.0);
    }
}
