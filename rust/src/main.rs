//! `landscape` — the CLI launcher.
//!
//! ```text
//! landscape gen       --dataset kron11 --out stream.lstrm
//! landscape ingest    --dataset kron11 [--worker native|cube|xla|remote]
//!                     [--k 1] [--alpha 2] [--gamma 0.04] [--query]
//! landscape worker    --listen 0.0.0.0:7011 [--connections N]
//! landscape bench     <fig1|fig3|fig4|fig5|fig16|table2|table3|table4|
//!                      table5|table6|correctness|all> [--full]
//! landscape rambw     — RAM bandwidth probes
//! ```

// the stream-source closure tuple in cmd_ingest is clearer inline
#![allow(clippy::type_complexity)]

use landscape::benchkit::{fmt_bytes, fmt_rate};
use landscape::config::Args;
use landscape::coordinator::{BufferKind, Coordinator, CoordinatorConfig, WorkerKind};
use landscape::stream::{datasets, file, EdgeModel, GraphStream};

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("gen") => cmd_gen(&args),
        Some("ingest") => cmd_ingest(&args),
        Some("worker") => cmd_worker(&args),
        Some("bench") => cmd_bench(&args),
        Some("rambw") => cmd_rambw(),
        _ => {
            eprintln!("{}", HELP);
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "landscape — distributed graph sketching (paper reproduction)

commands:
  gen     --dataset NAME --out FILE        write a stream file
  ingest  --dataset NAME | --stream FILE   run the coordinator
          [--worker native|cube|xla|remote] [--addrs host:port,..]
          [--window N: batches in flight per remote connection]
          [--k N] [--alpha N] [--gamma F] [--buffer hypertree|gutter]
          [--max-updates N] [--query] [--distributors N]
  worker  --listen ADDR [--connections N]  run a remote worker server
  bench   EXPERIMENT [--full]              regenerate a paper table/figure
  rambw                                    RAM bandwidth probes

datasets: kron10..13 erdos11..13 gnutella amazon googleplus webuk citeseer
experiments: fig1 fig3 fig4 fig5 fig16 table2 table3 table4 table5 table6
             correctness all";

fn cmd_gen(args: &Args) -> i32 {
    let name = args.get_str("dataset", "kron10");
    let Some(d) = datasets::by_name(&name) else {
        eprintln!("unknown dataset {name}");
        return 2;
    };
    let out = args.get_str("out", &format!("{name}.lstrm"));
    eprintln!("generating {name} -> {out} ...");
    match file::write_stream(std::path::Path::new(&out), d.stream()) {
        Ok(n) => {
            eprintln!("wrote {n} updates ({})", fmt_bytes((n * 9 + 28) as f64));
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn build_config(args: &Args, vertices: u64) -> Option<CoordinatorConfig> {
    let mut cfg = CoordinatorConfig::for_vertices(vertices);
    cfg.k = args.get_u64("k", 1) as u32;
    cfg.alpha = args.get_u64("alpha", 1) as u32;
    cfg.gamma = args.get_f64("gamma", 0.04);
    cfg.distributor_threads = args.get_usize("distributors", 2);
    cfg.remote_window = args.get_usize("window", 8);
    cfg.use_greedycc = !args.get_bool("no-greedycc");
    cfg.buffer = match args.get_str("buffer", "hypertree").as_str() {
        "hypertree" => BufferKind::Hypertree,
        "gutter" => BufferKind::Gutter,
        other => {
            eprintln!("unknown buffer kind {other}");
            return None;
        }
    };
    cfg.worker = match args.get_str("worker", "native").as_str() {
        "native" => WorkerKind::Native,
        "cube" => WorkerKind::Cube,
        "xla" => xla_worker_kind(args)?,
        "remote" => WorkerKind::Remote {
            addrs: args
                .get_str("addrs", "127.0.0.1:7011")
                .split(',')
                .map(|s| s.to_string())
                .collect(),
        },
        other => {
            eprintln!("unknown worker kind {other}");
            return None;
        }
    };
    Some(cfg)
}

#[cfg(feature = "xla")]
fn xla_worker_kind(args: &Args) -> Option<WorkerKind> {
    Some(WorkerKind::Xla {
        artifact_dir: std::path::PathBuf::from(args.get_str("artifacts", "artifacts")),
    })
}

#[cfg(not(feature = "xla"))]
fn xla_worker_kind(_args: &Args) -> Option<WorkerKind> {
    eprintln!("worker kind `xla` requires a build with `--features xla`");
    None
}

fn cmd_ingest(args: &Args) -> i32 {
    let max_updates = args.get_u64("max-updates", u64::MAX);

    // resolve the stream source
    let (vertices, run): (u64, Box<dyn FnOnce(&mut Coordinator) -> u64>) =
        if let Some(path) = args.get("stream") {
            let fs = match file::FileStream::open(std::path::Path::new(path)) {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("open {path}: {e}");
                    return 1;
                }
            };
            let v = fs.num_vertices();
            (
                v,
                Box::new(move |coord: &mut Coordinator| {
                    let mut n = 0u64;
                    for u in fs {
                        coord.ingest(u);
                        n += 1;
                        if n >= max_updates {
                            break;
                        }
                    }
                    n
                }),
            )
        } else {
            let name = args.get_str("dataset", "kron10");
            let Some(d) = datasets::by_name(&name) else {
                eprintln!("unknown dataset {name}");
                return 2;
            };
            let v = d.model.num_vertices();
            (
                v,
                Box::new(move |coord: &mut Coordinator| {
                    let mut n = 0u64;
                    for u in d.stream() {
                        coord.ingest(u);
                        n += 1;
                        if n >= max_updates {
                            break;
                        }
                    }
                    n
                }),
            )
        };

    let Some(cfg) = build_config(args, vertices) else {
        return 2;
    };
    let k = cfg.k;
    eprintln!(
        "coordinator: V={vertices}, k={k}, sketch/vertex {}",
        fmt_bytes(cfg.params().bytes() as f64 * k as f64)
    );
    let mut coord = match Coordinator::new(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("init: {e:#}");
            return 1;
        }
    };

    let sw = landscape::util::timer::Stopwatch::new();
    let n = run(&mut coord);
    coord.flush_pending();
    let secs = sw.elapsed_secs();
    let m = coord.metrics();
    eprintln!(
        "ingested {n} updates in {secs:.2}s ({}); comm factor {:.2}x; \
         sketch {}; local updates {}",
        fmt_rate(n as f64 / secs),
        m.communication_factor(),
        fmt_bytes(coord.sketch_bytes() as f64),
        m.updates_local,
    );

    if args.get_bool("query") {
        let qsw = landscape::util::timer::Stopwatch::new();
        if k == 1 {
            let forest = coord.full_connectivity_query();
            eprintln!(
                "connectivity: {} components, {} forest edges ({:.3}s)",
                forest.num_components(),
                forest.edges.len(),
                qsw.elapsed_secs()
            );
        } else {
            let cut = coord.k_connectivity();
            eprintln!(
                "k-connectivity: {} ({:.3}s)",
                cut.map(|w| w.to_string()).unwrap_or_else(|| format!(">= {k}")),
                qsw.elapsed_secs()
            );
        }
    }
    0
}

fn cmd_worker(args: &Args) -> i32 {
    let listen = args.get_str("listen", "127.0.0.1:7011");
    let connections = args.get_usize("connections", usize::MAX);
    let server = match landscape::worker::remote::WorkerServer::bind(&listen) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind {listen}: {e:#}");
            return 1;
        }
    };
    eprintln!(
        "worker listening on {} (stateless; serves {} connections)",
        server.local_addr().map(|a| a.to_string()).unwrap_or(listen),
        if connections == usize::MAX {
            "unlimited".to_string()
        } else {
            connections.to_string()
        }
    );
    if let Err(e) = server.serve(connections) {
        eprintln!("serve: {e:#}");
        return 1;
    }
    0
}

fn cmd_bench(args: &Args) -> i32 {
    let Some(exp) = args.positional.first() else {
        eprintln!(
            "usage: landscape bench <{}> [--full]",
            landscape::experiments::EXPERIMENTS.join("|")
        );
        return 2;
    };
    let quick = !args.get_bool("full");
    if landscape::experiments::run_by_name(exp, quick) {
        0
    } else {
        eprintln!("unknown experiment {exp}");
        2
    }
}

fn cmd_rambw() -> i32 {
    let (seq, rnd) = landscape::analysis::rambw::measure_defaults();
    println!(
        "sequential write: {:.2} GiB/s ({} as 9B updates)",
        seq.gib_per_sec(),
        fmt_rate(seq.updates_per_sec())
    );
    println!(
        "random write:     {:.2} GiB/s ({} as 9B updates)",
        rnd.gib_per_sec(),
        fmt_rate(rnd.updates_per_sec())
    );
    0
}
