//! `landscape` — the CLI launcher.
//!
//! ```text
//! landscape gen       --dataset kron11 --out stream.lstrm
//! landscape ingest    --dataset kron11 [--worker native|cube|xla|remote]
//!                     [--producers N] [--k 1] [--alpha 2] [--gamma 0.04] [--query]
//! landscape worker    --listen 0.0.0.0:7011 [--connections N]
//! landscape bench     <fig1|fig3|fig4|fig5|fig16|table2|table3|table4|
//!                      table5|table6|correctness|all> [--full]
//! landscape rambw     — RAM bandwidth probes
//! ```
//!
//! Log verbosity is controlled by `LANDSCAPE_LOG`
//! (`off|error|warn|info|debug`, default `info`).

use landscape::benchkit::{fmt_bytes, fmt_rate};
use landscape::config::Args;
use landscape::coordinator::{BufferKind, CoordinatorConfig, WorkerKind};
use landscape::session::{IngestHandle, Landscape};
use landscape::stream::update::Update;
use landscape::stream::{datasets, file, EdgeModel, GraphStream};
use landscape::{log_error, log_info};

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("gen") => cmd_gen(&args),
        Some("ingest") => cmd_ingest(&args),
        Some("worker") => cmd_worker(&args),
        Some("bench") => cmd_bench(&args),
        Some("rambw") => cmd_rambw(),
        _ => {
            // lint: allow(eprintln) — CLI usage text must reach stderr unconditionally, outside any log level/filter
            eprintln!("{}", HELP);
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "landscape — distributed graph sketching (paper reproduction)

commands:
  gen     --dataset NAME --out FILE        write a stream file
  ingest  --dataset NAME | --stream FILE   run an ingestion session
          [--producers N: concurrent ingest handles (default 1)]
          [--worker native|cube|xla|remote] [--addrs host:port,..]
          [--window N: batches in flight per remote connection]
          [--k N] [--alpha N] [--gamma F] [--buffer hypertree|gutter]
          [--max-updates N] [--query] [--distributors N]
  worker  --listen ADDR [--connections N]  run a remote worker server
  bench   EXPERIMENT [--full]              regenerate a paper table/figure
  rambw                                    RAM bandwidth probes

env: LANDSCAPE_LOG=off|error|warn|info|debug (default info)
datasets: kron10..13 erdos11..13 gnutella amazon googleplus webuk citeseer
experiments: fig1 fig3 fig4 fig5 fig16 table2 table3 table4 table5 table6
             correctness all";

fn cmd_gen(args: &Args) -> i32 {
    let name = args.get_str("dataset", "kron10");
    let Some(d) = datasets::by_name(&name) else {
        log_error!("unknown dataset {name}");
        return 2;
    };
    let out = args.get_str("out", &format!("{name}.lstrm"));
    log_info!("generating {name} -> {out} ...");
    match file::write_stream(std::path::Path::new(&out), d.stream()) {
        Ok(n) => {
            log_info!("wrote {n} updates ({})", fmt_bytes((n * 9 + 28) as f64));
            0
        }
        Err(e) => {
            log_error!("error: {e}");
            1
        }
    }
}

fn build_config(args: &Args, vertices: u64) -> Option<CoordinatorConfig> {
    let mut cfg = CoordinatorConfig::for_vertices(vertices);
    cfg.k = args.get_u64("k", 1) as u32;
    cfg.alpha = args.get_u64("alpha", 1) as u32;
    cfg.gamma = args.get_f64("gamma", 0.04);
    cfg.distributor_threads = args.get_usize("distributors", 2);
    cfg.remote_window = args.get_usize("window", 8);
    cfg.use_greedycc = !args.get_bool("no-greedycc");
    cfg.buffer = match args.get_str("buffer", "hypertree").as_str() {
        "hypertree" => BufferKind::Hypertree,
        "gutter" => BufferKind::Gutter,
        other => {
            log_error!("unknown buffer kind {other}");
            return None;
        }
    };
    cfg.worker = match args.get_str("worker", "native").as_str() {
        "native" => WorkerKind::Native,
        "cube" => WorkerKind::Cube,
        "xla" => xla_worker_kind(args)?,
        "remote" => WorkerKind::Remote {
            addrs: args
                .get_str("addrs", "127.0.0.1:7011")
                .split(',')
                .map(|s| s.to_string())
                .collect(),
        },
        other => {
            log_error!("unknown worker kind {other}");
            return None;
        }
    };
    Some(cfg)
}

#[cfg(feature = "xla")]
fn xla_worker_kind(args: &Args) -> Option<WorkerKind> {
    Some(WorkerKind::Xla {
        artifact_dir: std::path::PathBuf::from(args.get_str("artifacts", "artifacts")),
    })
}

#[cfg(not(feature = "xla"))]
fn xla_worker_kind(_args: &Args) -> Option<WorkerKind> {
    log_error!("worker kind `xla` requires a build with `--features xla`");
    None
}

/// Hand `payload` to the next surviving producer, round-robin.  A dead
/// producer (closed channel) gives the chunk back via `SendError`; it
/// is removed and the chunk re-dealt to a survivor.  With no survivors
/// the chunk is dropped (lost work, reflected in the producers' own
/// ingest counts).
fn deal_chunk(
    senders: &mut Vec<std::sync::mpsc::SyncSender<Vec<Update>>>,
    next: &mut usize,
    mut payload: Vec<Update>,
) {
    while !senders.is_empty() {
        let idx = *next % senders.len();
        match senders[idx].send(payload) {
            Ok(()) => {
                *next = (idx + 1) % senders.len();
                return;
            }
            Err(err) => {
                landscape::log_warn!(
                    "producer {idx} died; re-dealing its {} buffered updates",
                    err.0.len()
                );
                payload = err.0;
                senders.remove(idx);
            }
        }
    }
}

/// Drive `stream` through `producers` concurrent ingest handles: the
/// main thread deals bounded chunks round-robin over per-producer
/// channels, each producer thread owns one [`IngestHandle`].  Returns
/// the number of updates that actually reached a handle (each producer
/// reports its own count; a crashed producer contributes only what it
/// finished, and its crash is logged rather than re-raised so the
/// survivors' work is preserved).
fn ingest_multi(
    session: &Landscape,
    stream: Box<dyn Iterator<Item = Update> + Send>,
    producers: usize,
    max_updates: u64,
) -> u64 {
    const CHUNK: usize = 1024;
    std::thread::scope(|scope| {
        let mut senders = Vec::with_capacity(producers);
        let mut workers = Vec::with_capacity(producers);
        for _ in 0..producers {
            let (tx, rx) = std::sync::mpsc::sync_channel::<Vec<Update>>(8);
            let mut handle: IngestHandle = session.ingest_handle();
            workers.push(scope.spawn(move || {
                let mut ingested = 0u64;
                for chunk in rx {
                    for u in chunk {
                        handle.ingest(u);
                        ingested += 1;
                    }
                }
                // handle drop publishes the tail
                ingested
            }));
            senders.push(tx);
        }
        let mut next = 0usize;
        let mut chunk = Vec::with_capacity(CHUNK);
        for u in stream.take(max_updates as usize) {
            chunk.push(u);
            if chunk.len() >= CHUNK {
                let payload = std::mem::replace(&mut chunk, Vec::with_capacity(CHUNK));
                deal_chunk(&mut senders, &mut next, payload);
                if senders.is_empty() {
                    landscape::log_error!("all producers died; abandoning the stream");
                    break;
                }
            }
        }
        if !chunk.is_empty() {
            deal_chunk(&mut senders, &mut next, chunk);
        }
        drop(senders); // close the channels so producers finish
        // count what each producer really ingested; join errors are
        // producer panics, already paid for with lost updates — log
        // instead of re-raising so the run still reports honestly
        let mut n = 0u64;
        for (i, w) in workers.into_iter().enumerate() {
            match w.join() {
                Ok(ingested) => n += ingested,
                Err(_) => landscape::log_error!("producer {i} panicked; its tail is lost"),
            }
        }
        n
    })
}

fn cmd_ingest(args: &Args) -> i32 {
    let max_updates = args.get_u64("max-updates", u64::MAX);
    let producers = args.get_usize("producers", 1).max(1);

    // resolve the stream source
    let (vertices, stream): (u64, Box<dyn Iterator<Item = Update> + Send>) =
        if let Some(path) = args.get("stream") {
            let fs = match file::FileStream::open(std::path::Path::new(path)) {
                Ok(f) => f,
                Err(e) => {
                    log_error!("open {path}: {e}");
                    return 1;
                }
            };
            (fs.num_vertices(), Box::new(fs))
        } else {
            let name = args.get_str("dataset", "kron10");
            let Some(d) = datasets::by_name(&name) else {
                log_error!("unknown dataset {name}");
                return 2;
            };
            // the stream borrows the dataset model; leak it so the
            // producer threads can hold it for the process lifetime
            let d: &'static datasets::Dataset = Box::leak(Box::new(d));
            (d.model.num_vertices(), Box::new(d.stream()))
        };

    let Some(cfg) = build_config(args, vertices) else {
        return 2;
    };
    let k = cfg.k;
    log_info!(
        "session: V={vertices}, k={k}, {producers} producer(s), sketch/vertex {}",
        fmt_bytes(cfg.params().bytes() as f64 * k as f64)
    );
    let session = match Landscape::from_config(cfg) {
        Ok(s) => s,
        Err(e) => {
            log_error!("init: {e}");
            return 1;
        }
    };

    let sw = landscape::util::timer::Stopwatch::new();
    let n = if producers == 1 {
        // no channel overhead on the single-producer path
        let mut handle = session.ingest_handle();
        let mut n = 0u64;
        for u in stream.take(max_updates as usize) {
            handle.ingest(u);
            n += 1;
        }
        drop(handle); // publish the tail
        n
    } else {
        ingest_multi(&session, stream, producers, max_updates)
    };
    session.flush();
    let secs = sw.elapsed_secs();
    let m = session.metrics();
    log_info!(
        "ingested {n} updates in {secs:.2}s ({}) across {} handle(s); \
         comm factor {:.2}x; sketch {}; local updates {}",
        fmt_rate(n as f64 / secs),
        m.handles_spawned,
        m.communication_factor(),
        fmt_bytes(session.sketch_bytes() as f64),
        m.updates_local,
    );

    if args.get_bool("query") {
        let queries = session.query_handle();
        let qsw = landscape::util::timer::Stopwatch::new();
        if k == 1 {
            let forest = queries.full_connectivity_query();
            log_info!(
                "connectivity: {} components, {} forest edges ({:.3}s)",
                forest.num_components(),
                forest.edges.len(),
                qsw.elapsed_secs()
            );
        } else {
            let cut = queries.k_connectivity();
            log_info!(
                "k-connectivity: {} ({:.3}s)",
                cut.map(|w| w.to_string()).unwrap_or_else(|| format!(">= {k}")),
                qsw.elapsed_secs()
            );
        }
    }
    0
}

fn cmd_worker(args: &Args) -> i32 {
    let listen = args.get_str("listen", "127.0.0.1:7011");
    let connections = args.get_usize("connections", usize::MAX);
    let server = match landscape::worker::remote::WorkerServer::bind(&listen) {
        Ok(s) => s,
        Err(e) => {
            log_error!("bind {listen}: {e:#}");
            return 1;
        }
    };
    log_info!(
        "worker listening on {} (stateless; serves {} connections)",
        server.local_addr().map(|a| a.to_string()).unwrap_or(listen),
        if connections == usize::MAX {
            "unlimited".to_string()
        } else {
            connections.to_string()
        }
    );
    if let Err(e) = server.serve(connections) {
        log_error!("serve: {e:#}");
        return 1;
    }
    0
}

fn cmd_bench(args: &Args) -> i32 {
    let Some(exp) = args.positional.first() else {
        // lint: allow(eprintln) — CLI usage text must reach stderr unconditionally, outside any log level/filter
        eprintln!(
            "usage: landscape bench <{}> [--full]",
            landscape::experiments::EXPERIMENTS.join("|")
        );
        return 2;
    };
    let quick = !args.get_bool("full");
    if landscape::experiments::run_by_name(exp, quick) {
        0
    } else {
        log_error!("unknown experiment {exp}");
        2
    }
}

fn cmd_rambw() -> i32 {
    let (seq, rnd) = landscape::analysis::rambw::measure_defaults();
    println!(
        "sequential write: {:.2} GiB/s ({} as 9B updates)",
        seq.gib_per_sec(),
        fmt_rate(seq.updates_per_sec())
    );
    println!(
        "random write:     {:.2} GiB/s ({} as 9B updates)",
        rnd.gib_per_sec(),
        fmt_rate(rnd.updates_per_sec())
    );
    0
}
