//! Main-node sketch storage: the graph sketch S(G) = ⋃_u S(f_u),
//! partitioned into per-vertex shards.
//!
//! Vertex sketches are split across [`ShardSpec::count`] independent
//! allocations (`shard = hash(u) % N`, N ≈ distributor threads).  Each
//! distributor thread XOR-merges worker deltas into *its own* shard, so
//! the merge hot path never serializes behind a global lock and never
//! bounces cache lines between merging threads — the per-update
//! shared-map contention that caps GraphZeppelin-style ingestion
//! (arXiv 2203.14927) is designed out.
//!
//! Two merge entry points exist, both implemented as 8-way unrolled
//! u64-chunk kernels (stable Rust, `chunks_exact` + slice-pattern
//! destructuring — see the scalar reference variants they are
//! property-tested against):
//!
//! * [`SketchStore::merge_delta`] — atomic `fetch_xor` (relaxed), safe
//!   under arbitrary concurrency; XOR is commutative/associative so no
//!   ordering between deltas matters.  Zero delta words are skipped:
//!   an atomic RMW costs far more than the branch.
//! * [`SketchStore::merge_delta_exclusive`] — relaxed load/store XOR,
//!   the distributor fast path.  Correct only while the calling thread
//!   is the sole writer of the vertex's shard, which the coordinator's
//!   shard-affine batch routing guarantees during ingestion.  The
//!   unrolled body issues all eight loads before the eight stores so
//!   the XOR chains stay independent, and it does *not* branch on zero
//!   words — a plain load/XOR/store is cheaper than a mispredict on
//!   the dense deltas γ-full batches produce.
//!
//! Queries run behind an **epoch cut** (paper §5.3, as an explicit
//! stream cut rather than a drained-pipeline instant): a reader first
//! waits for every pre-cut delta to merge, then holds the session's
//! merge gate exclusively for the read, so post-cut merges — which keep
//! flowing while producers stream — are observed batch-atomically,
//! never torn mid-delta.
//!
//! **Hybrid sparse/dense tier** (arXiv 2605.15173): with a
//! [`HybridConfig`], every vertex starts as a compact *exact* sorted
//! set of encoded edge indices (XOR-toggle semantics — present iff
//! toggled an odd number of times, so insert/delete streams need no
//! separate bookkeeping).  Once the set outgrows `threshold`, the shard
//! owner *promotes* the vertex: the exact set is replayed into a
//! freshly allocated CAMEO block (same seeds, so worker deltas keep
//! merging bit-identically) and retained as a *demotion shadow*;
//! deletions that shrink the shadow below `floor` demote the vertex
//! back to exact.  The dense per-shard arrays stay empty in hybrid
//! mode — sketch blocks are allocated per promoted vertex only, which
//! is where the order-of-magnitude memory win on sparse streams comes
//! from.  Hybrid slots live behind one mutex per shard; the mutex is
//! never contended in the pipeline (writes come only from the shard's
//! own distributor thread, reads hold the session merge gate
//! exclusively), it simply makes the plain non-atomic slot contents
//! data-race-free without adding relaxed atomics outside the kernels.
//!
//! **Storage backing** (`storage/`): the dense arrays above are one of
//! two interchangeable backings behind [`crate::storage::Backing`].
//! [`ResidentBacking`] (defined here so the relaxed-atomic kernels
//! stay inside this file, the `landscape_lint` Relaxed whitelist)
//! keeps everything in RAM; [`crate::storage::SpillBacking`] keeps a
//! bounded hot set resident and pages cold per-vertex blocks to
//! segment files, with WAL-tail replay for crash recovery.  The spill
//! backing is mutually exclusive with the hybrid tier (enforced by the
//! session builder): spilling pages fixed-size CAMEO blocks, not
//! variable-size exact sets.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sketch::params::SketchParams;
use crate::sketch::seeds::SketchSeeds;
use crate::sketch::shard::ShardSpec;
use crate::sketch::CameoSketch;
use crate::storage::{Backing, SketchBacking};

/// Configuration for the hybrid sparse/dense vertex representation.
///
/// A vertex stays as a compact exact edge set until its observed degree
/// exceeds `threshold` (promotion to a full CAMEO sketch); deletions
/// that shrink its tracked set below `floor` demote it back.  Keeping
/// `floor < threshold` gives the hysteresis band that prevents a vertex
/// oscillating at the boundary from flapping between tiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HybridConfig {
    /// Promote once a vertex's exact set holds more than this many edges.
    pub threshold: u32,
    /// Demote a sketched vertex once its tracked set shrinks below this.
    pub floor: u32,
}

/// Promotion/demotion counts produced by one hybrid write operation
/// (always zero when the store runs dense-only).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierTransitions {
    /// Exact → sketched transitions performed by the operation.
    pub promotions: u64,
    /// Sketched → exact transitions performed by the operation.
    pub demotions: u64,
}

impl TierTransitions {
    /// Accumulate another operation's transition counts.
    pub fn absorb(&mut self, other: TierTransitions) {
        self.promotions += other.promotions;
        self.demotions += other.demotions;
    }
}

/// The demotion shadow of a promoted vertex: the exact edge set kept
/// current alongside the sketch so a demotion can restore it without a
/// sketch decode.  Dropped once it outgrows [`shadow_cap`] — a vertex
/// that hot keeps its sketch for the rest of this promotion.
enum Shadow {
    Tracked(Vec<u64>),
    Dropped,
}

/// Per-vertex representation state in hybrid mode.
enum SlotState {
    /// Cold: sorted encoded edge indices, XOR-toggle semantics.
    Exact(Vec<u64>),
    /// Hot: a full CAMEO block (`params.words()` plain words) plus the
    /// demotion shadow.  Invariant: while the shadow is `Tracked`, the
    /// block is bit-identical to the sketch of the shadow set — every
    /// toggle lands on both, so demotion is a plain state swap.
    Sketched {
        words: Box<[u64]>,
        shadow: Shadow,
    },
}

struct HybridShard {
    slots: Vec<SlotState>,
}

struct HybridState {
    cfg: HybridConfig,
    /// One mutex per shard.  Never contended in the pipeline: writes
    /// come only from the shard's own distributor thread (the
    /// single-writer contract) and queries hold the session merge gate
    /// exclusively, which excludes every writer.  The lock exists to
    /// make the plain (non-atomic) slot contents data-race-free without
    /// introducing relaxed atomics outside the sketch kernels.
    shards: Vec<Mutex<HybridShard>>,
}

/// XOR-toggle `idx` in a sorted set: insert if absent, remove if present.
fn toggle_sorted(set: &mut Vec<u64>, idx: u64) {
    match set.binary_search(&idx) {
        Ok(pos) => {
            set.remove(pos);
        }
        Err(pos) => set.insert(pos, idx),
    }
}

/// Above this many tracked entries the demotion shadow is dropped: the
/// vertex is clearly hot and on insert-heavy streams the shadow would
/// otherwise grow without bound next to the fixed-size sketch.
fn shadow_cap(cfg: &HybridConfig) -> usize {
    (cfg.threshold as usize * 4).max(64)
}

/// Lock a hybrid shard, tolerating poison (a panicking writer leaves
/// slot contents valid — every mutation is complete before unlock).
fn lock_shard(m: &Mutex<HybridShard>) -> std::sync::MutexGuard<'_, HybridShard> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// The all-resident dense storage backing: per-shard arrays of atomic
/// sketch words, with the 8-way unrolled XOR-merge kernels.
///
/// Defined in this file (rather than `storage/`) so the
/// `Ordering::Relaxed` kernel bodies stay inside `sketch/store.rs`,
/// the single file `landscape_lint`'s Relaxed-ordering whitelist
/// names; `storage/` re-exports it as part of the backing surface.
/// The single-writer-per-shard debug detector stays one level up in
/// [`SketchStore`], which owns the writer tags.
pub struct ResidentBacking {
    words: usize,
    spec: ShardSpec,
    shards: Vec<Vec<AtomicU64>>,
}

impl ResidentBacking {
    /// Eagerly allocate all-zero dense arrays for `vertices` blocks of
    /// `words` words, partitioned per `spec`.
    pub fn new(words: usize, vertices: u64, spec: ShardSpec) -> Self {
        let shards = (0..spec.count())
            .map(|s| {
                let total = spec.shard_len(s, vertices) * words;
                let mut shard = Vec::with_capacity(total);
                shard.resize_with(total, || AtomicU64::new(0));
                shard
            })
            .collect();
        Self {
            words,
            spec,
            shards,
        }
    }

    /// An empty backing (no dense arrays) — the placeholder a hybrid
    /// store carries, since hybrid state lives in per-slot blocks.
    pub fn empty(words: usize, spec: ShardSpec) -> Self {
        Self {
            words,
            spec,
            shards: (0..spec.count()).map(|_| Vec::new()).collect(),
        }
    }

    /// Words per vertex block.
    pub fn words(&self) -> usize {
        self.words
    }

    /// Shard words + within-shard word offset of vertex `u`.
    #[inline(always)]
    fn locate(&self, u: u32) -> (&[AtomicU64], usize) {
        (
            self.shards[self.spec.shard_of(u)].as_slice(),
            self.spec.slot_of(u) * self.words,
        )
    }

    /// XOR-merge a full-block delta into vertex `u` (thread-safe under
    /// arbitrary concurrency: atomic relaxed `fetch_xor`).  8-way
    /// unrolled; zero delta words are skipped because an atomic RMW
    /// dwarfs the branch.  Bit-identical to
    /// [`Self::merge_delta_scalar`].
    pub fn merge_delta(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.words);
        let (shard, base) = self.locate(u);
        let dst = &shard[base..base + delta.len()];
        let mut dc = delta.chunks_exact(8);
        let mut wc = dst.chunks_exact(8);
        for (d, w) in (&mut dc).zip(&mut wc) {
            let [d0, d1, d2, d3, d4, d5, d6, d7] = d else {
                unreachable!()
            };
            let [w0, w1, w2, w3, w4, w5, w6, w7] = w else {
                unreachable!()
            };
            if *d0 != 0 {
                w0.fetch_xor(*d0, Ordering::Relaxed);
            }
            if *d1 != 0 {
                w1.fetch_xor(*d1, Ordering::Relaxed);
            }
            if *d2 != 0 {
                w2.fetch_xor(*d2, Ordering::Relaxed);
            }
            if *d3 != 0 {
                w3.fetch_xor(*d3, Ordering::Relaxed);
            }
            if *d4 != 0 {
                w4.fetch_xor(*d4, Ordering::Relaxed);
            }
            if *d5 != 0 {
                w5.fetch_xor(*d5, Ordering::Relaxed);
            }
            if *d6 != 0 {
                w6.fetch_xor(*d6, Ordering::Relaxed);
            }
            if *d7 != 0 {
                w7.fetch_xor(*d7, Ordering::Relaxed);
            }
        }
        for (&d, w) in dc.remainder().iter().zip(wc.remainder()) {
            if d != 0 {
                w.fetch_xor(d, Ordering::Relaxed);
            }
        }
    }

    /// Scalar reference for [`Self::merge_delta`] (correctness oracle
    /// and bench baseline).
    pub fn merge_delta_scalar(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.words);
        let (shard, base) = self.locate(u);
        for (i, &d) in delta.iter().enumerate() {
            if d != 0 {
                shard[base + i].fetch_xor(d, Ordering::Relaxed);
            }
        }
    }

    /// XOR-merge on the shard owner's fast path: plain relaxed
    /// load/store, correct only under the single-writer-per-shard
    /// contract (enforced in debug builds by [`SketchStore`]'s writer
    /// tags).  8-way unrolled, all loads before all stores, no
    /// per-word zero branch.  Bit-identical to
    /// [`Self::merge_delta_exclusive_scalar`].
    pub fn merge_delta_exclusive(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.words);
        let (shard, base) = self.locate(u);
        let dst = &shard[base..base + delta.len()];
        let mut dc = delta.chunks_exact(8);
        let mut wc = dst.chunks_exact(8);
        for (d, w) in (&mut dc).zip(&mut wc) {
            let [d0, d1, d2, d3, d4, d5, d6, d7] = d else {
                unreachable!()
            };
            let [w0, w1, w2, w3, w4, w5, w6, w7] = w else {
                unreachable!()
            };
            // all loads before all stores: eight independent XOR chains
            let x0 = w0.load(Ordering::Relaxed) ^ *d0;
            let x1 = w1.load(Ordering::Relaxed) ^ *d1;
            let x2 = w2.load(Ordering::Relaxed) ^ *d2;
            let x3 = w3.load(Ordering::Relaxed) ^ *d3;
            let x4 = w4.load(Ordering::Relaxed) ^ *d4;
            let x5 = w5.load(Ordering::Relaxed) ^ *d5;
            let x6 = w6.load(Ordering::Relaxed) ^ *d6;
            let x7 = w7.load(Ordering::Relaxed) ^ *d7;
            w0.store(x0, Ordering::Relaxed);
            w1.store(x1, Ordering::Relaxed);
            w2.store(x2, Ordering::Relaxed);
            w3.store(x3, Ordering::Relaxed);
            w4.store(x4, Ordering::Relaxed);
            w5.store(x5, Ordering::Relaxed);
            w6.store(x6, Ordering::Relaxed);
            w7.store(x7, Ordering::Relaxed);
        }
        for (&d, w) in dc.remainder().iter().zip(wc.remainder()) {
            w.store(w.load(Ordering::Relaxed) ^ d, Ordering::Relaxed);
        }
    }

    /// Scalar reference for [`Self::merge_delta_exclusive`] (same
    /// single-writer contract).
    pub fn merge_delta_exclusive_scalar(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.words);
        let (shard, base) = self.locate(u);
        for (i, &d) in delta.iter().enumerate() {
            if d != 0 {
                let w = &shard[base + i];
                w.store(w.load(Ordering::Relaxed) ^ d, Ordering::Relaxed);
            }
        }
    }

    /// Copy `dst.len()` words of vertex `u`'s block starting at word
    /// `word_off` (relaxed loads — only sound while no writer is
    /// mid-delta on `u`'s shard, which the session's merge gate
    /// guarantees to readers).
    pub fn read_words_into(&self, u: u32, word_off: usize, dst: &mut [u64]) {
        let (shard, base) = self.locate(u);
        let src = &shard[base + word_off..base + word_off + dst.len()];
        for (d, w) in dst.iter_mut().zip(src) {
            *d = w.load(Ordering::Relaxed);
        }
    }

    /// XOR `acc.len()` words of vertex `u`'s block (from `word_off`)
    /// into `acc` — the supernode aggregation primitive.
    pub fn xor_words_into(&self, u: u32, word_off: usize, acc: &mut [u64]) {
        let (shard, base) = self.locate(u);
        let src = &shard[base + word_off..base + word_off + acc.len()];
        for (a, w) in acc.iter_mut().zip(src) {
            *a ^= w.load(Ordering::Relaxed);
        }
    }

    /// Reset every bucket to zero.
    pub fn clear(&self) {
        for shard in &self.shards {
            for w in shard {
                w.store(0, Ordering::Relaxed);
            }
        }
    }

    /// Total resident sketch bytes (the full eager allocation).
    pub fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.len() as u64 * 8).sum()
    }
}

/// The main node's graph sketch: V vertex sketches across N shards.
pub struct SketchStore {
    params: SketchParams,
    seeds: SketchSeeds,
    spec: ShardSpec,
    /// Where the dense sketch words live: all-resident arrays or the
    /// spill tier.  Hybrid mode carries an empty resident backing —
    /// hybrid state lives in per-slot blocks below.
    backing: Backing,
    /// `Some` enables the hybrid sparse/dense tier; the dense backing
    /// above is then empty and all state lives here.
    hybrid: Option<HybridState>,
    /// Debug-only per-shard writer-ownership tags (0 = free, else the
    /// owning thread's [`thread_tag`]).  The exclusive merge kernels
    /// claim their shard's tag for the duration of the call, turning a
    /// violated single-writer-per-shard contract — which in release
    /// silently loses updates — into an immediate panic under
    /// `cargo test` / Miri / TSan.  See docs/INVARIANTS.md.
    #[cfg(debug_assertions)]
    writer_tags: Vec<AtomicU64>,
}

/// A process-unique nonzero tag for the calling thread (debug builds),
/// used by the shard writer-ownership detector.
#[cfg(debug_assertions)]
fn thread_tag() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TAG: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TAG.with(|t| *t)
}

/// Debug-mode claim on a shard's writer tag; releases on drop (including
/// panic unwind, so one detector firing cannot wedge later tests).
#[cfg(debug_assertions)]
struct WriterGuard<'a> {
    tags: &'a [AtomicU64],
    shard: usize,
    claimed: bool,
}

#[cfg(debug_assertions)]
impl Drop for WriterGuard<'_> {
    fn drop(&mut self) {
        if self.claimed {
            self.tags[self.shard].store(0, Ordering::Release);
        }
    }
}

impl SketchStore {
    /// Allocate an all-zero single-shard graph sketch for `params`,
    /// seeded from `graph_seed`.
    pub fn new(params: SketchParams, graph_seed: u64) -> Self {
        Self::with_shards(params, graph_seed, ShardSpec::SINGLE)
    }

    /// Allocate an all-zero graph sketch partitioned per `spec`.
    pub fn with_shards(params: SketchParams, graph_seed: u64, spec: ShardSpec) -> Self {
        Self::with_shards_hybrid(params, graph_seed, spec, None)
    }

    /// Allocate a graph sketch partitioned per `spec`, with the hybrid
    /// sparse/dense tier enabled when `hybrid` is `Some`.  In hybrid
    /// mode every vertex starts exact and the dense arrays stay empty —
    /// sketch blocks are allocated lazily, per promoted vertex.
    pub fn with_shards_hybrid(
        params: SketchParams,
        graph_seed: u64,
        spec: ShardSpec,
        hybrid: Option<HybridConfig>,
    ) -> Self {
        let words = params.words();
        let backing = if hybrid.is_some() {
            Backing::Resident(ResidentBacking::empty(words, spec))
        } else {
            Backing::Resident(ResidentBacking::new(words, params.v, spec))
        };
        Self::build(params, graph_seed, spec, backing, hybrid)
    }

    /// Allocate a store over an explicit storage backing (the spill
    /// tier's entry point — see [`crate::storage`]).  The backing's
    /// block width must match `params.words()`, and a spill backing is
    /// mutually exclusive with the hybrid tier.
    pub fn with_backing(
        params: SketchParams,
        graph_seed: u64,
        spec: ShardSpec,
        backing: Backing,
    ) -> Self {
        debug_assert_eq!(backing.words(), params.words());
        Self::build(params, graph_seed, spec, backing, None)
    }

    fn build(
        params: SketchParams,
        graph_seed: u64,
        spec: ShardSpec,
        backing: Backing,
        hybrid: Option<HybridConfig>,
    ) -> Self {
        debug_assert!(
            hybrid.is_none() || matches!(backing, Backing::Resident(_)),
            "the hybrid tier pages variable-size exact sets; it cannot spill"
        );
        let hybrid = hybrid.map(|cfg| HybridState {
            cfg,
            shards: (0..spec.count())
                .map(|s| {
                    let slots = (0..spec.shard_len(s, params.v))
                        .map(|_| SlotState::Exact(Vec::new()))
                        .collect();
                    Mutex::new(HybridShard { slots })
                })
                .collect(),
        });
        Self {
            seeds: SketchSeeds::derive(&params, graph_seed),
            params,
            spec,
            backing,
            hybrid,
            #[cfg(debug_assertions)]
            writer_tags: (0..spec.count()).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// The hybrid configuration, when the sparse/dense tier is enabled.
    pub fn hybrid_config(&self) -> Option<HybridConfig> {
        self.hybrid.as_ref().map(|h| h.cfg)
    }

    /// Claim debug-mode write ownership of `shard` until the returned
    /// guard drops.  Re-entrant on the owning thread; panics if another
    /// thread currently holds the shard — the single-writer-per-shard
    /// contract of the exclusive merge kernels has been violated.
    #[cfg(debug_assertions)]
    fn writer_guard(&self, shard: usize) -> WriterGuard<'_> {
        let tag = thread_tag();
        let claimed = match self.writer_tags[shard].compare_exchange(
            0,
            tag,
            Ordering::Acquire,
            Ordering::Acquire,
        ) {
            Ok(_) => true,
            Err(prev) if prev == tag => false, // same thread, nested call
            Err(prev) => panic!(
                "single-writer-per-shard violation: shard {shard} is owned by \
                 thread tag {prev} but thread tag {tag} entered an exclusive \
                 merge; route same-shard batches to one distributor or use \
                 merge_delta (atomic fetch_xor) — see docs/INVARIANTS.md"
            ),
        };
        WriterGuard {
            tags: &self.writer_tags,
            shard,
            claimed,
        }
    }

    /// The sketch geometry (levels × columns × rows) this store was
    /// allocated for.
    pub fn params(&self) -> &SketchParams {
        &self.params
    }

    /// The hash seeds every sketch in this store is derived from
    /// (workers must use the same seeds for deltas to be mergeable).
    pub fn seeds(&self) -> &SketchSeeds {
        &self.seeds
    }

    /// The shard map this store is partitioned by.
    pub fn shards(&self) -> ShardSpec {
        self.spec
    }

    /// Total resident bytes of vertex storage: sketch words plus exact
    /// sets.  Dense mode reports the paper's full Θ(V log³ V) term;
    /// hybrid mode reports what is actually allocated, which is the
    /// measurable memory claim the density-sweep benches make.
    pub fn bytes(&self) -> usize {
        self.sketch_bytes() + self.exact_bytes()
    }

    /// Bytes of CAMEO sketch words currently resident (dense mode: the
    /// full eager allocation; spill mode: the bounded hot set; hybrid:
    /// promoted vertices only).
    pub fn sketch_bytes(&self) -> usize {
        match &self.hybrid {
            None => self.backing.resident_bytes() as usize,
            Some(h) => {
                let block = self.params.words() * 8;
                h.shards
                    .iter()
                    .map(|m| {
                        let g = lock_shard(m);
                        g.slots
                            .iter()
                            .filter(|s| matches!(s, SlotState::Sketched { .. }))
                            .count()
                            * block
                    })
                    .sum()
            }
        }
    }

    /// Bytes of exact-set storage currently resident (hybrid only:
    /// cold vertices' sorted index arrays plus demotion shadows).
    pub fn exact_bytes(&self) -> usize {
        let Some(h) = &self.hybrid else { return 0 };
        h.shards
            .iter()
            .map(|m| {
                let g = lock_shard(m);
                g.slots
                    .iter()
                    .map(|s| match s {
                        SlotState::Exact(set) => set.capacity() * 8,
                        SlotState::Sketched {
                            shadow: Shadow::Tracked(set),
                            ..
                        } => set.capacity() * 8,
                        SlotState::Sketched {
                            shadow: Shadow::Dropped,
                            ..
                        } => 0,
                    })
                    .sum::<usize>()
            })
            .sum()
    }

    /// `(exact, sketched)` vertex counts.  Dense mode reports `(0, V)`:
    /// every vertex has a full sketch.
    pub fn tier_counts(&self) -> (u64, u64) {
        let Some(h) = &self.hybrid else {
            return (0, self.params.v);
        };
        let (mut exact, mut sketched) = (0u64, 0u64);
        for m in &h.shards {
            let g = lock_shard(m);
            for s in &g.slots {
                match s {
                    SlotState::Exact(_) => exact += 1,
                    SlotState::Sketched { .. } => sketched += 1,
                }
            }
        }
        (exact, sketched)
    }

    /// Debug checks shared by every dense-path (non-hybrid) entry.
    #[inline(always)]
    fn debug_check_dense(&self, u: u32) {
        let _ = u;
        debug_assert!((u as u64) < self.params.v);
        debug_assert!(
            self.hybrid.is_none(),
            "dense-path access on a hybrid store; use the hybrid entry points"
        );
    }

    /// XOR-merge a vertex-sketch delta into vertex `u` (thread-safe
    /// under arbitrary concurrency on the resident backing: atomic
    /// relaxed `fetch_xor`; the spill backing serializes per stripe).
    ///
    /// Resident: 8-way unrolled over u64 chunks; zero delta words are
    /// skipped because an atomic RMW dwarfs the branch.  Bit-identical
    /// to [`Self::merge_delta_scalar`] (property-tested, tails
    /// included).
    pub fn merge_delta(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.params.words());
        self.debug_check_dense(u);
        match &self.backing {
            Backing::Resident(r) => r.merge_delta(u, delta),
            Backing::Spill(s) => s.merge_delta(u, delta, s.watermark_now()),
        }
    }

    /// The scalar reference implementation of [`Self::merge_delta`],
    /// retained as the correctness oracle for the unrolled kernel and
    /// as a baseline row in the bench trajectory.
    pub fn merge_delta_scalar(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.params.words());
        self.debug_check_dense(u);
        match &self.backing {
            Backing::Resident(r) => r.merge_delta_scalar(u, delta),
            Backing::Spill(s) => s.merge_delta(u, delta, s.watermark_now()),
        }
    }

    /// XOR-merge a delta into vertex `u` on the shard owner's fast path:
    /// plain load/store (still data-race-free, no atomic RMW cost).
    ///
    /// The caller must be the only thread writing `u`'s shard for the
    /// duration of the call — the coordinator's shard-affine routing
    /// guarantees this for distributor threads during ingestion.  Misuse
    /// cannot cause UB (all accesses stay atomic) but concurrent
    /// same-shard writers could lose updates; use [`Self::merge_delta`]
    /// when exclusivity is not structurally guaranteed.
    ///
    /// Resident: 8-way unrolled, eight relaxed loads, eight XORs,
    /// eight relaxed stores per chunk, with no per-word zero branch —
    /// without the RMW cost the plain store is cheaper than a
    /// mispredict on the dense deltas γ-full batches produce.
    /// Bit-identical to [`Self::merge_delta_exclusive_scalar`]
    /// (property-tested).  Spill: stripe-serialized merge (the
    /// single-writer contract still applies and is still checked).
    pub fn merge_delta_exclusive(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.params.words());
        self.debug_check_dense(u);
        #[cfg(debug_assertions)]
        let _owner = self.writer_guard(self.spec.shard_of(u));
        match &self.backing {
            Backing::Resident(r) => r.merge_delta_exclusive(u, delta),
            Backing::Spill(s) => s.merge_delta(u, delta, s.watermark_now()),
        }
    }

    /// The scalar reference implementation of
    /// [`Self::merge_delta_exclusive`], retained as the correctness
    /// oracle for the unrolled kernel (same single-writer contract).
    pub fn merge_delta_exclusive_scalar(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.params.words());
        self.debug_check_dense(u);
        #[cfg(debug_assertions)]
        let _owner = self.writer_guard(self.spec.shard_of(u));
        match &self.backing {
            Backing::Resident(r) => r.merge_delta_exclusive_scalar(u, delta),
            Backing::Spill(s) => s.merge_delta(u, delta, s.watermark_now()),
        }
    }

    /// XOR-merge a **WAL-logged** delta on the shard owner's path:
    /// like [`Self::merge_delta_exclusive`], but tagging the mutation
    /// with `lsn` — the end offset the [`crate::storage::DurabilityLog`]
    /// returned for this delta's record — so a block evicted to disk
    /// after the last durable cut carries proof the record is already
    /// folded in, and recovery replay stays idempotent.
    pub fn merge_delta_logged(&self, u: u32, delta: &[u64], lsn: u64) {
        debug_assert_eq!(delta.len(), self.params.words());
        self.debug_check_dense(u);
        #[cfg(debug_assertions)]
        let _owner = self.writer_guard(self.spec.shard_of(u));
        match &self.backing {
            Backing::Resident(r) => r.merge_delta_exclusive(u, delta),
            Backing::Spill(s) => s.merge_delta(u, delta, lsn),
        }
    }

    /// Replay one WAL record's per-copy delta during recovery,
    /// applying it only if `record_end` is newer than the block's
    /// persisted LSN.  Returns whether it was applied.  Resident
    /// backings have no persisted LSNs (nothing survived the crash to
    /// double-apply onto), so they always apply.
    pub fn replay_delta(&self, u: u32, delta: &[u64], record_end: u64) -> std::io::Result<bool> {
        debug_assert_eq!(delta.len(), self.params.words());
        self.debug_check_dense(u);
        match &self.backing {
            Backing::Resident(r) => {
                r.merge_delta(u, delta);
                Ok(true)
            }
            Backing::Spill(s) => s.replay_delta(u, delta, record_end),
        }
    }

    /// Scheduling-point maintenance for one shard's backing state
    /// (spill: gutter flush past the high-water mark + LRU eviction).
    /// Distributors call this at ticket-retire points so flush I/O
    /// lands between batches, never mid-merge.
    pub fn maintain(&self, shard: usize) {
        self.backing.maintain(shard);
    }

    /// Persist and fsync all un-persisted backing state — the segment
    /// half of a durable cut (no-op for resident backings).
    pub fn checkpoint(&self) -> std::io::Result<()> {
        self.backing.checkpoint()
    }

    /// Whether this store runs on the spill backing.
    pub fn is_spill(&self) -> bool {
        matches!(self.backing, Backing::Spill(_))
    }

    /// Sketch bytes currently resident in memory for this store's
    /// backing (dense: the full allocation; spill: the bounded hot
    /// set; hybrid: promoted blocks).
    pub fn resident_sketch_bytes(&self) -> u64 {
        if self.hybrid.is_some() {
            return self.sketch_bytes() as u64;
        }
        self.backing.resident_bytes()
    }

    /// Cold blocks faulted in from segment files (spill only).
    pub fn block_faults(&self) -> u64 {
        self.backing.block_faults()
    }

    /// Bytes written through to segment files (spill only).
    pub fn spill_bytes_written(&self) -> u64 {
        self.backing.spill_bytes_written()
    }

    // ---- hybrid (sparse/dense adaptive) entry points -----------------

    /// Toggle `idx` into a hybrid slot, keeping the demotion shadow
    /// current.  Never transitions tiers — callers decide that.
    fn toggle_slot(
        state: &mut SlotState,
        params: &SketchParams,
        seeds: &SketchSeeds,
        cfg: &HybridConfig,
        idx: u64,
    ) {
        debug_assert_ne!(idx, 0, "0 is the padding sentinel");
        match state {
            SlotState::Exact(set) => toggle_sorted(set, idx),
            SlotState::Sketched { words, shadow } => {
                CameoSketch::apply_update(words, params, seeds, idx);
                if let Shadow::Tracked(set) = shadow {
                    toggle_sorted(set, idx);
                    if set.len() > shadow_cap(cfg) {
                        *shadow = Shadow::Dropped;
                    }
                }
            }
        }
    }

    /// Evaluate promotion/demotion for a slot after an ingest-path
    /// write.  Promotion replays the exact set into a freshly allocated
    /// block under the store's own seeds (so worker deltas keep merging
    /// bit-identically) and keeps the set as the demotion shadow;
    /// demotion is a plain state swap thanks to the shadow invariant.
    fn settle_slot(&self, state: &mut SlotState, cfg: &HybridConfig) -> TierTransitions {
        let mut t = TierTransitions::default();
        match state {
            SlotState::Exact(set) if set.len() > cfg.threshold as usize => {
                let set = std::mem::take(set);
                let mut words = vec![0u64; self.params.words()].into_boxed_slice();
                for &idx in &set {
                    CameoSketch::apply_update(&mut words, &self.params, &self.seeds, idx);
                }
                *state = SlotState::Sketched {
                    words,
                    shadow: Shadow::Tracked(set),
                };
                t.promotions = 1;
            }
            SlotState::Sketched {
                shadow: Shadow::Tracked(set),
                ..
            } if set.len() < cfg.floor as usize => {
                let shadow = std::mem::take(set);
                *state = SlotState::Exact(shadow);
                t.demotions = 1;
            }
            _ => {}
        }
        t
    }

    /// Toggle one encoded edge index into vertex `u` on the **ingest**
    /// path, evaluating promotion/demotion.  Dense mode delegates to
    /// [`Self::apply_local`] and reports no transitions.  Must only be
    /// called by `u`'s shard owner (the exclusive-merge contract).
    pub fn ingest_index(&self, u: u32, idx: u64) -> TierTransitions {
        let Some(h) = &self.hybrid else {
            self.apply_local(u, idx);
            return TierTransitions::default();
        };
        let mut g = lock_shard(&h.shards[self.spec.shard_of(u)]);
        let state = &mut g.slots[self.spec.slot_of(u)];
        Self::toggle_slot(state, &self.params, &self.seeds, &h.cfg, idx);
        self.settle_slot(state, &h.cfg)
    }

    /// Merge a worker's sketch delta into vertex `u` on the shard
    /// owner's path, with the batch's raw endpoints (`others`) so the
    /// hybrid tier can keep its demotion shadow current.  Dense mode is
    /// exactly [`Self::merge_delta_exclusive`].
    ///
    /// A sketch delta arriving for a still-exact vertex force-promotes
    /// it first (replaying the exact set into a fresh block), so
    /// correctness never depends on the worker and the store agreeing
    /// about a vertex's tier — workers advertise a threshold but the
    /// store is the single source of truth.
    pub fn merge_sketch_delta(&self, u: u32, delta: &[u64], others: &[u32]) -> TierTransitions {
        let Some(h) = &self.hybrid else {
            self.merge_delta_exclusive(u, delta);
            return TierTransitions::default();
        };
        debug_assert_eq!(delta.len(), self.params.words());
        #[cfg(debug_assertions)]
        let _owner = self.writer_guard(self.spec.shard_of(u));
        let mut g = lock_shard(&h.shards[self.spec.shard_of(u)]);
        let state = &mut g.slots[self.spec.slot_of(u)];
        let mut t = TierTransitions::default();
        if let SlotState::Exact(set) = state {
            let set = std::mem::take(set);
            let mut words = vec![0u64; self.params.words()].into_boxed_slice();
            for &idx in &set {
                CameoSketch::apply_update(&mut words, &self.params, &self.seeds, idx);
            }
            *state = SlotState::Sketched {
                words,
                shadow: Shadow::Tracked(set),
            };
            t.promotions = 1;
        }
        let SlotState::Sketched { words, shadow } = state else {
            unreachable!("force-promotion above leaves the slot sketched")
        };
        for (w, &d) in words.iter_mut().zip(delta) {
            *w ^= d;
        }
        if let Shadow::Tracked(set) = shadow {
            for &o in others {
                toggle_sorted(set, crate::sketch::params::encode_edge(u, o, self.params.v));
            }
            if set.len() > shadow_cap(&h.cfg) {
                *shadow = Shadow::Dropped;
            }
        }
        t.absorb(self.settle_slot(state, &h.cfg));
        t
    }

    /// Apply a worker's exact-set delta (the batch's odd-parity encoded
    /// indices) to vertex `u` on the shard owner's path.  The index
    /// list is copy-independent: the same indices are valid for every
    /// sketch copy regardless of its seeds, which is what lets one
    /// `EXACTDELTA2` frame serve all k stores.
    pub fn merge_exact_delta(&self, u: u32, indices: &[u64]) -> TierTransitions {
        let Some(h) = &self.hybrid else {
            for &idx in indices {
                self.apply_local(u, idx);
            }
            return TierTransitions::default();
        };
        #[cfg(debug_assertions)]
        let _owner = self.writer_guard(self.spec.shard_of(u));
        let mut g = lock_shard(&h.shards[self.spec.shard_of(u)]);
        let state = &mut g.slots[self.spec.slot_of(u)];
        for &idx in indices {
            Self::toggle_slot(state, &self.params, &self.seeds, &h.cfg, idx);
        }
        self.settle_slot(state, &h.cfg)
    }

    /// If vertex `u` is currently in exact (cold) representation,
    /// append its encoded edge indices to `out` and return `true`.
    /// Sketched vertices and dense-mode stores return `false` and leave
    /// `out` untouched — callers fall through to ℓ₀ sampling.
    pub fn exact_indices_into(&self, u: u32, out: &mut Vec<u64>) -> bool {
        let Some(h) = &self.hybrid else { return false };
        let g = lock_shard(&h.shards[self.spec.shard_of(u)]);
        match &g.slots[self.spec.slot_of(u)] {
            SlotState::Exact(set) => {
                out.extend_from_slice(set);
                true
            }
            SlotState::Sketched { .. } => false,
        }
    }

    /// Apply a single edge-index update to vertex `u` locally (the main
    /// node's path for underfull leaves, §5.3).
    ///
    /// In hybrid mode this is the **query-path** toggle: it adjusts the
    /// current representation in place but never promotes or demotes,
    /// so certificate delete/restore cycles (`KConnectivity`) cannot
    /// flap a vertex's tier mid-query.  Ingest paths use
    /// [`Self::ingest_index`] instead.
    pub fn apply_local(&self, u: u32, idx: u64) {
        if let Some(h) = &self.hybrid {
            let mut g = lock_shard(&h.shards[self.spec.shard_of(u)]);
            let state = &mut g.slots[self.spec.slot_of(u)];
            Self::toggle_slot(state, &self.params, &self.seeds, &h.cfg, idx);
            return;
        }
        self.debug_check_dense(u);
        let r = match &self.backing {
            Backing::Resident(r) => r,
            Backing::Spill(s) => {
                // single-update blocks are rare off the batch path;
                // expand to a full-block delta and go through the
                // stripe merge so gutter/LRU semantics stay uniform
                let delta =
                    CameoSketch::delta_of_batch(&self.params, &self.seeds, &[idx]);
                s.merge_delta(u, &delta, s.watermark_now());
                return;
            }
        };
        // relaxed atomic XORs, same rationale as merge_delta
        let (shard, base) = r.locate(u);
        let wpl = self.params.words_per_level();
        let rows = self.params.rows as usize;
        for level in 0..self.params.levels {
            let chk = crate::hashing::checksum(self.seeds.cseed(level), idx);
            let lbase = base + level as usize * wpl;
            for column in 0..self.params.columns {
                let h = crate::hashing::depth_hash(self.seeds.dseed(level, column), idx);
                let depth =
                    crate::hashing::bucket_depth(h, self.params.rows) as usize;
                let cbase = lbase + column as usize * rows * 2;
                shard[cbase].fetch_xor(idx, Ordering::Relaxed);
                shard[cbase + 1].fetch_xor(chk, Ordering::Relaxed);
                shard[cbase + depth * 2].fetch_xor(idx, Ordering::Relaxed);
                shard[cbase + depth * 2 + 1].fetch_xor(chk, Ordering::Relaxed);
            }
        }
    }

    /// Snapshot one level of vertex `u` into `out` (length
    /// `words_per_level`).  Only sound while no writer is mid-delta on
    /// `u`'s shard (the session guarantees this by reading under the
    /// exclusive side of its merge gate, after its cut has retired).
    pub fn read_level_into(&self, u: u32, level: u32, out: &mut [u64]) {
        let wpl = self.params.words_per_level();
        debug_assert_eq!(out.len(), wpl);
        if let Some(h) = &self.hybrid {
            let g = lock_shard(&h.shards[self.spec.shard_of(u)]);
            match &g.slots[self.spec.slot_of(u)] {
                // exact vertices contribute no sketch words; their
                // edges are consumed via exact_indices_into instead
                SlotState::Exact(_) => out.fill(0),
                SlotState::Sketched { words, .. } => {
                    let base = level as usize * wpl;
                    out.copy_from_slice(&words[base..base + wpl]);
                }
            }
            return;
        }
        self.debug_check_dense(u);
        self.backing
            .read_words_into(u, level as usize * wpl, out);
    }

    /// XOR one level of vertex `u` into `acc` — the supernode
    /// aggregation step of sketch Borůvka (S(f_X) = Σ_{u∈X} S(f_u)).
    pub fn xor_level_into(&self, u: u32, level: u32, acc: &mut [u64]) {
        let wpl = self.params.words_per_level();
        debug_assert_eq!(acc.len(), wpl);
        if let Some(h) = &self.hybrid {
            let g = lock_shard(&h.shards[self.spec.shard_of(u)]);
            match &g.slots[self.spec.slot_of(u)] {
                SlotState::Exact(_) => {}
                SlotState::Sketched { words, .. } => {
                    let base = level as usize * wpl;
                    for (slot, w) in acc.iter_mut().zip(&words[base..base + wpl]) {
                        *slot ^= *w;
                    }
                }
            }
            return;
        }
        self.debug_check_dense(u);
        match &self.backing {
            Backing::Resident(r) => r.xor_words_into(u, level as usize * wpl, acc),
            Backing::Spill(s) => {
                // range-read into a scratch block, then fold; spill
                // queries are I/O-bound so the scratch alloc is noise
                let mut tmp = vec![0u64; wpl];
                s.read_words_into(u, level as usize * wpl, &mut tmp);
                for (a, t) in acc.iter_mut().zip(&tmp) {
                    *a ^= *t;
                }
            }
        }
    }

    /// Query vertex `u` at `level` (convenience for tests/examples).
    pub fn query_vertex_level(&self, u: u32, level: u32) -> Option<u64> {
        let mut buf = vec![0u64; self.params.words_per_level()];
        self.read_level_into(u, level, &mut buf);
        CameoSketch::query_level(&buf, &self.params, &self.seeds, level)
    }

    /// Reset every bucket to zero (between bench runs).  Hybrid mode
    /// resets every vertex to an empty exact set — releasing promoted
    /// blocks, demotion shadows, and hence the tier counters and the
    /// `store_exact_bytes`/`vertices_exact` gauges derived from them —
    /// and the backing is always cleared too (spill mode re-sparses
    /// its segment files so persisted blocks cannot resurrect).
    pub fn clear(&self) {
        if let Some(h) = &self.hybrid {
            for m in &h.shards {
                let mut g = lock_shard(m);
                for s in g.slots.iter_mut() {
                    *s = SlotState::Exact(Vec::new());
                }
            }
        }
        self.backing.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::connectivity::boruvka::boruvka_components;
    use crate::sketch::params::encode_edge;

    fn store(v: u64, seed: u64) -> SketchStore {
        SketchStore::new(SketchParams::for_vertices(v), seed)
    }

    #[test]
    fn merge_delta_equals_local_updates() {
        let s1 = store(64, 42);
        let s2 = store(64, 42);
        let edges = [(1u32, 2u32), (1, 5), (1, 60)];
        let idx: Vec<u64> = edges.iter().map(|&(a, b)| encode_edge(a, b, 64)).collect();

        // path A: local single-update application
        for &i in &idx {
            s1.apply_local(1, i);
        }
        // path B: batched delta + merge
        let delta = CameoSketch::delta_of_batch(s2.params(), s2.seeds(), &idx);
        s2.merge_delta(1, &delta);

        let mut a = vec![0u64; s1.params().words_per_level()];
        let mut b = vec![0u64; s2.params().words_per_level()];
        for level in 0..s1.params().levels {
            s1.read_level_into(1, level, &mut a);
            s2.read_level_into(1, level, &mut b);
            assert_eq!(a, b, "level {level}");
        }
    }

    #[test]
    fn query_recovers_single_incident_edge() {
        let s = store(64, 9);
        let idx = encode_edge(7, 13, 64);
        s.apply_local(7, idx);
        s.apply_local(13, idx);
        assert_eq!(s.query_vertex_level(7, 0), Some(idx));
        assert_eq!(s.query_vertex_level(13, 0), Some(idx));
        assert_eq!(s.query_vertex_level(20, 0), None);
    }

    #[test]
    fn xor_level_into_aggregates_supernode() {
        // edges inside {0,1} cancel in the aggregate; the crossing edge
        // to 2 survives — exactly the cut-sampling property of App. A.
        let v = 16u64;
        let s = store(v, 5);
        let inner = encode_edge(0, 1, v);
        let crossing = encode_edge(1, 2, v);
        s.apply_local(0, inner);
        s.apply_local(1, inner);
        s.apply_local(1, crossing);
        s.apply_local(2, crossing);

        let wpl = s.params().words_per_level();
        for level in 0..s.params().levels {
            let mut acc = vec![0u64; wpl];
            s.xor_level_into(0, level, &mut acc);
            s.xor_level_into(1, level, &mut acc);
            let got =
                CameoSketch::query_level(&acc, s.params(), s.seeds(), level);
            assert_eq!(got, Some(crossing), "level {level}");
        }
    }

    #[test]
    fn concurrent_merges_commute() {
        let v = 32u64;
        let params = SketchParams::for_vertices(v);
        let s = std::sync::Arc::new(SketchStore::new(params, 77));
        let idx: Vec<u64> = (0..20)
            .map(|i| encode_edge(3, (i % 30) + 4, v))
            .collect();
        let deltas: Vec<Vec<u64>> = idx
            .chunks(5)
            .map(|c| CameoSketch::delta_of_batch(s.params(), s.seeds(), c))
            .collect();

        let mut handles = Vec::new();
        for d in deltas.clone() {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || s2.merge_delta(3, &d)));
        }
        for h in handles {
            h.join().unwrap();
        }

        // sequential reference
        let s_ref = SketchStore::new(params, 77);
        for d in &deltas {
            s_ref.merge_delta(3, d);
        }
        let mut a = vec![0u64; params.words_per_level()];
        let mut b = vec![0u64; params.words_per_level()];
        for level in 0..params.levels {
            s.read_level_into(3, level, &mut a);
            s_ref.read_level_into(3, level, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn bytes_accounting() {
        let s = store(128, 1);
        assert_eq!(
            s.bytes(),
            128 * SketchParams::for_vertices(128).bytes()
        );
        // sharding never changes the total footprint
        let sharded = SketchStore::with_shards(
            SketchParams::for_vertices(100),
            1,
            ShardSpec::new(8),
        );
        assert_eq!(
            sharded.bytes(),
            100 * SketchParams::for_vertices(100).bytes()
        );
    }

    #[test]
    fn clear_zeroes_everything() {
        let s = store(16, 2);
        s.apply_local(0, encode_edge(0, 1, 16));
        s.clear();
        assert_eq!(s.query_vertex_level(0, 0), None);
    }

    #[test]
    fn exclusive_merge_matches_atomic_merge() {
        let v = 48u64;
        let params = SketchParams::for_vertices(v);
        let atomic = SketchStore::with_shards(params, 7, ShardSpec::new(4));
        let exclusive = SketchStore::with_shards(params, 7, ShardSpec::new(4));
        for u in 0..v as u32 {
            let idx: Vec<u64> = (0..5)
                .map(|i| encode_edge(u, (u + i + 1) % v as u32, v))
                .filter(|&x| x != 0)
                .collect();
            let delta = CameoSketch::delta_of_batch(atomic.params(), atomic.seeds(), &idx);
            atomic.merge_delta(u, &delta);
            exclusive.merge_delta_exclusive(u, &delta);
        }
        let mut a = vec![0u64; params.words_per_level()];
        let mut b = vec![0u64; params.words_per_level()];
        for u in 0..v as u32 {
            for level in 0..params.levels {
                atomic.read_level_into(u, level, &mut a);
                exclusive.read_level_into(u, level, &mut b);
                assert_eq!(a, b, "vertex {u} level {level}");
            }
        }
    }

    /// The unrolled merge kernels must be bit-for-bit the scalar
    /// references for random deltas (dense, sparse, and zero words) at
    /// every vertex — vertices land at different slot offsets within
    /// their shard, so this also sweeps chunk alignment, and words()
    /// is not a multiple of 8 for most V so the tail loop is exercised.
    #[test]
    fn unrolled_store_merges_match_scalar_references() {
        use crate::util::testkit::Cases;
        Cases::new(20).run(|rng| {
            let v = 48u64;
            let params = SketchParams::for_vertices(v);
            let spec = ShardSpec::new(3);
            let unrolled = SketchStore::with_shards(params, 13, spec);
            let scalar = SketchStore::with_shards(params, 13, spec);
            let words = params.words();
            for u in 0..v as u32 {
                let delta: Vec<u64> = (0..words)
                    .map(|_| match rng.next_u64() % 4 {
                        0 => 0, // exercise the zero-skip paths
                        _ => rng.next_u64(),
                    })
                    .collect();
                if u % 2 == 0 {
                    unrolled.merge_delta(u, &delta);
                    scalar.merge_delta_scalar(u, &delta);
                } else {
                    unrolled.merge_delta_exclusive(u, &delta);
                    scalar.merge_delta_exclusive_scalar(u, &delta);
                }
            }
            let wpl = params.words_per_level();
            let (mut a, mut b) = (vec![0u64; wpl], vec![0u64; wpl]);
            for u in 0..v as u32 {
                for level in 0..params.levels {
                    unrolled.read_level_into(u, level, &mut a);
                    scalar.read_level_into(u, level, &mut b);
                    assert_eq!(a, b, "vertex {u} level {level}");
                }
            }
        });
    }

    /// The debug writer-ownership detector: while one thread holds a
    /// shard's writer claim (as a distributor does for the duration of
    /// an exclusive merge), a second thread entering an exclusive merge
    /// on the same shard must panic loudly instead of silently losing
    /// updates to the plain load/XOR/store race.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "single-writer-per-shard violation")]
    fn two_writer_exclusive_merge_panics_in_debug() {
        use std::sync::mpsc;
        let v = 32u64;
        let params = SketchParams::for_vertices(v);
        let s = std::sync::Arc::new(SketchStore::new(params, 3));
        let delta = vec![1u64; params.words()];

        let (claimed_tx, claimed_rx) = mpsc::channel();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let holder = {
            let s = s.clone();
            std::thread::spawn(move || {
                // pose as the shard's owning distributor, mid-merge
                let _owner = s.writer_guard(0);
                claimed_tx.send(()).unwrap();
                // hold the claim until the main thread has observed the
                // detector firing
                let _ = done_rx.recv();
            })
        };
        claimed_rx.recv().unwrap();
        // second concurrent writer on shard 0: the detector must fire;
        // catch it so the holder can be joined (keeps Miri happy), then
        // re-raise for #[should_panic]
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.merge_delta_exclusive(0, &delta)
        }));
        drop(done_tx);
        holder.join().unwrap();
        std::panic::resume_unwind(result.expect_err("detector did not fire"));
    }

    /// Deterministic sharding invariant: merging the same delta set into
    /// stores partitioned 1-, 2-, and 8-way yields bit-identical sketch
    /// state and identical `boruvka_components` output.
    #[test]
    fn shard_count_never_changes_sketch_state_or_queries() {
        let v = 96u64;
        let params = SketchParams::for_vertices(v);
        let seed = 0xBADCAFE;

        // a deterministic mixed workload: batched deltas for every
        // vertex plus a few local single-update applications
        let edges: Vec<(u32, u32)> = (0..160u32)
            .map(|i| {
                let a = (i * 7) % v as u32;
                let b = (a + 1 + (i * 13) % (v as u32 - 1)) % v as u32;
                (a.min(b), a.max(b))
            })
            .filter(|&(a, b)| a != b)
            .collect();

        let build = |spec: ShardSpec| {
            let s = SketchStore::with_shards(params, seed, spec);
            for &(a, b) in &edges {
                let idx = encode_edge(a, b, v);
                let delta =
                    CameoSketch::delta_of_batch(s.params(), s.seeds(), &[idx]);
                s.merge_delta(a, &delta);
                s.merge_delta_exclusive(b, &delta);
            }
            for &(a, b) in edges.iter().take(10) {
                // cancel + re-apply a few edges through the local path
                let idx = encode_edge(a, b, v);
                s.apply_local(a, idx);
                s.apply_local(a, idx);
            }
            s
        };

        let s1 = build(ShardSpec::SINGLE);
        let s2 = build(ShardSpec::new(2));
        let s8 = build(ShardSpec::new(8));
        assert_eq!(s1.shards().count(), 1);
        assert_eq!(s2.shards().count(), 2);
        assert_eq!(s8.shards().count(), 8);

        let wpl = params.words_per_level();
        let (mut a, mut b, mut c) = (vec![0u64; wpl], vec![0u64; wpl], vec![0u64; wpl]);
        for u in 0..v as u32 {
            for level in 0..params.levels {
                s1.read_level_into(u, level, &mut a);
                s2.read_level_into(u, level, &mut b);
                s8.read_level_into(u, level, &mut c);
                assert_eq!(a, b, "1 vs 2 shards: vertex {u} level {level}");
                assert_eq!(a, c, "1 vs 8 shards: vertex {u} level {level}");
            }
        }

        let r1 = boruvka_components(&s1);
        let r2 = boruvka_components(&s2);
        let r8 = boruvka_components(&s8);
        assert_eq!(r1.forest.component, r2.forest.component);
        assert_eq!(r1.forest.component, r8.forest.component);
        assert_eq!(r1.forest.edges, r2.forest.edges);
        assert_eq!(r1.forest.edges, r8.forest.edges);
    }

    // ---- hybrid sparse/dense tier ------------------------------------

    fn hybrid_store(v: u64, seed: u64, threshold: u32, floor: u32) -> SketchStore {
        SketchStore::with_shards_hybrid(
            SketchParams::for_vertices(v),
            seed,
            ShardSpec::SINGLE,
            Some(HybridConfig { threshold, floor }),
        )
    }

    #[test]
    fn hybrid_promote_demote_walk() {
        let v = 64u64;
        let s = hybrid_store(v, 11, 4, 2);
        let idx: Vec<u64> = (0..5).map(|i| encode_edge(3, 10 + i, v)).collect();
        let mut t = TierTransitions::default();
        for &i in &idx {
            t.absorb(s.ingest_index(3, i));
        }
        assert_eq!((t.promotions, t.demotions), (1, 0));
        assert_eq!(s.tier_counts(), (v - 1, 1));
        let mut buf = Vec::new();
        assert!(!s.exact_indices_into(3, &mut buf));
        // delete back below the floor: demotes exactly once
        for &i in &idx[..4] {
            t.absorb(s.ingest_index(3, i));
        }
        assert_eq!((t.promotions, t.demotions), (1, 1));
        assert_eq!(s.tier_counts(), (v, 0));
        buf.clear();
        assert!(s.exact_indices_into(3, &mut buf));
        assert_eq!(buf, vec![idx[4]]);
        // and churn back up: a second promotion replays the survivor
        for &i in &idx[..4] {
            t.absorb(s.ingest_index(3, i));
        }
        assert_eq!((t.promotions, t.demotions), (2, 1));
        assert_eq!(s.tier_counts(), (v - 1, 1));
    }

    #[test]
    fn sketch_delta_force_promotes_and_matches_dense() {
        let v = 64u64;
        let params = SketchParams::for_vertices(v);
        let hybrid = hybrid_store(v, 42, 8, 2);
        let dense = SketchStore::new(params, 42);
        // a cold vertex with two exact edges...
        let pre = [encode_edge(1, 2, v), encode_edge(1, 5, v)];
        for &i in &pre {
            hybrid.ingest_index(1, i);
            dense.apply_local(1, i);
        }
        // ...receives a worker sketch delta: force-promote, then merge
        let batch: Vec<u32> = (10..20).collect();
        let idx: Vec<u64> = batch.iter().map(|&o| encode_edge(1, o, v)).collect();
        let delta = CameoSketch::delta_of_batch(&params, dense.seeds(), &idx);
        let t = hybrid.merge_sketch_delta(1, &delta, &batch);
        assert_eq!((t.promotions, t.demotions), (1, 0));
        dense.merge_delta(1, &delta);
        let mut a = vec![0u64; params.words_per_level()];
        let mut b = vec![0u64; params.words_per_level()];
        for level in 0..params.levels {
            hybrid.read_level_into(1, level, &mut a);
            dense.read_level_into(1, level, &mut b);
            assert_eq!(a, b, "level {level}");
        }
    }

    #[test]
    fn exact_delta_applies_and_can_promote() {
        let v = 64u64;
        let s = hybrid_store(v, 7, 3, 1);
        let idx: Vec<u64> = (0..3).map(|i| encode_edge(9, 20 + i, v)).collect();
        let t = s.merge_exact_delta(9, &idx);
        assert_eq!((t.promotions, t.demotions), (0, 0));
        let mut buf = Vec::new();
        assert!(s.exact_indices_into(9, &mut buf));
        assert_eq!(buf.len(), 3);
        // two more edges cross the threshold inside a single delta
        let more = [encode_edge(9, 30, v), encode_edge(9, 31, v)];
        let t = s.merge_exact_delta(9, &more);
        assert_eq!(t.promotions, 1);
        assert_eq!(s.tier_counts().1, 1);
    }

    #[test]
    fn hybrid_bytes_track_resident_storage() {
        let v = 256u64;
        let params = SketchParams::for_vertices(v);
        let s = hybrid_store(v, 3, 4, 2);
        // empty: nothing resident in either tier
        assert_eq!(s.bytes(), 0);
        // a few cold vertices: exact bytes only
        for u in 0..8u32 {
            s.ingest_index(u, encode_edge(u, u + 100, v));
        }
        assert_eq!(s.sketch_bytes(), 0);
        assert!(s.exact_bytes() > 0);
        // promote one vertex: exactly one block resident
        for i in 0..5u32 {
            s.ingest_index(0, encode_edge(0, 10 + i, v));
        }
        assert_eq!(s.sketch_bytes(), params.words() * 8);
        // the hybrid footprint on this sparse state is a small fraction
        // of the dense store's eager Θ(V log³ V) allocation
        assert!(s.bytes() * 5 < SketchStore::new(params, 3).bytes());
    }

    #[test]
    fn hybrid_components_match_dense() {
        let v = 96u64;
        let params = SketchParams::for_vertices(v);
        let seed = 0xFEED;
        let hybrid = SketchStore::with_shards_hybrid(
            params,
            seed,
            ShardSpec::new(3),
            Some(HybridConfig {
                threshold: 4,
                floor: 2,
            }),
        );
        let dense = SketchStore::with_shards(params, seed, ShardSpec::new(3));
        // a star (promotes its center) plus a long path (stays exact)
        let mut edges: Vec<(u32, u32)> = (1..20u32).map(|i| (0, i)).collect();
        edges.extend((20..90u32).map(|i| (i, i + 1)));
        for &(a, b) in &edges {
            let idx = encode_edge(a, b, v);
            hybrid.ingest_index(a, idx);
            hybrid.ingest_index(b, idx);
            dense.apply_local(a, idx);
            dense.apply_local(b, idx);
        }
        let (exact, sketched) = hybrid.tier_counts();
        assert_eq!(sketched, 1, "only the star center promotes");
        assert_eq!(exact, v - 1);
        let rh = boruvka_components(&hybrid);
        let rd = boruvka_components(&dense);
        assert_eq!(rh.forest.component, rd.forest.component);
    }

    /// `clear()` must reset the hybrid tier completely: exact sets,
    /// promoted blocks, demotion shadows — so tier counts and the
    /// byte accounting (the `store_exact_bytes`/`vertices_exact`
    /// gauge sources) all read as empty afterwards.
    #[test]
    fn clear_resets_hybrid_tier_state() {
        let v = 64u64;
        let s = hybrid_store(v, 4, 3, 1);
        // promote vertex 2, leave vertex 7 exact with one edge
        for i in 0..5u32 {
            s.ingest_index(2, encode_edge(2, 10 + i, v));
        }
        s.ingest_index(7, encode_edge(7, 8, v));
        assert_eq!(s.tier_counts().1, 1);
        assert!(s.bytes() > 0);
        s.clear();
        assert_eq!(s.tier_counts(), (v, 0));
        assert_eq!(s.sketch_bytes(), 0);
        assert_eq!(s.exact_bytes(), 0);
        assert_eq!(s.bytes(), 0);
        let mut buf = Vec::new();
        assert!(s.exact_indices_into(2, &mut buf), "back to exact tier");
        assert!(buf.is_empty(), "promoted block and shadow released");
        assert!(s.exact_indices_into(7, &mut buf) && buf.is_empty());
    }

    // ---- spill backing ----------------------------------------------

    fn spill_store(
        name: &str,
        params: SketchParams,
        seed: u64,
        spec: ShardSpec,
        budget: u64,
    ) -> SketchStore {
        use crate::storage::{SpillBacking, SpillConfig};
        let dir = std::env::temp_dir().join(format!(
            "landscape_store_{}_{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SpillConfig {
            dir,
            resident_budget_bytes: budget,
            blocks_per_segment: 16,
        };
        let backing = SpillBacking::open(
            params.words(),
            params.v,
            spec,
            &cfg,
            std::sync::Arc::new(AtomicU64::new(0)),
        )
        .unwrap();
        SketchStore::with_backing(params, seed, spec, Backing::Spill(backing))
    }

    /// The spill backing must be observationally identical to the
    /// resident one — same merges in, same sketch words and Borůvka
    /// partition out — while keeping its hot set under the budget.
    #[test]
    fn spill_store_matches_resident_reference() {
        let v = 96u64;
        let params = SketchParams::for_vertices(v);
        let spec = ShardSpec::new(3);
        let budget = (params.words() * 8 * 6) as u64; // ~6 of 96 blocks
        let spill = spill_store("equiv", params, 5, spec, budget);
        let resident = SketchStore::with_shards(params, 5, spec);
        assert!(spill.is_spill() && !resident.is_spill());

        let mut lsn = 0u64;
        for i in 0..300u32 {
            let a = (i * 7) % v as u32;
            let b = (a + 1 + (i * 13) % (v as u32 - 1)) % v as u32;
            if a == b {
                continue;
            }
            let idx = encode_edge(a.min(b), a.max(b), v);
            let delta =
                CameoSketch::delta_of_batch(&params, resident.seeds(), &[idx]);
            lsn += 1;
            spill.merge_delta_logged(a, &delta, lsn);
            spill.merge_delta_logged(b, &delta, lsn);
            resident.merge_delta_exclusive(a, &delta);
            resident.merge_delta_exclusive(b, &delta);
        }
        for shard in 0..spec.count() {
            spill.maintain(shard);
        }
        assert!(
            spill.resident_sketch_bytes() <= budget,
            "hot set {} over budget {budget}",
            spill.resident_sketch_bytes()
        );
        assert!(spill.block_faults() > 0, "a tiny budget must fault");
        assert!(spill.spill_bytes_written() > 0, "evictions must spill");

        let wpl = params.words_per_level();
        let (mut a, mut b) = (vec![0u64; wpl], vec![0u64; wpl]);
        for u in 0..v as u32 {
            for level in 0..params.levels {
                spill.read_level_into(u, level, &mut a);
                resident.read_level_into(u, level, &mut b);
                assert_eq!(a, b, "vertex {u} level {level}");
            }
        }
        let rs = boruvka_components(&spill);
        let rr = boruvka_components(&resident);
        assert_eq!(rs.forest.component, rr.forest.component);

        // checkpoint + clear: persisted blocks must not resurrect
        spill.checkpoint().unwrap();
        spill.clear();
        assert_eq!(spill.resident_sketch_bytes(), 0);
        assert_eq!(spill.query_vertex_level(0, 0), None);
    }

    /// A crossing edge whose endpoints are *both* promoted is invisible
    /// to the exact pre-pass and must be recovered by cut sampling —
    /// with the exact members' contributions compensated into their
    /// supernode aggregates so the sketch algebra stays the textbook
    /// cut sketch.
    #[test]
    fn hybrid_boruvka_samples_promoted_crossing_edge() {
        let v = 64u64;
        let s = hybrid_store(v, 21, 4, 2);
        let ingest = |a: u32, b: u32| {
            let idx = encode_edge(a, b, v);
            s.ingest_index(a, idx);
            s.ingest_index(b, idx);
        };
        for i in 1..8 {
            ingest(0, i); // star A: 0 promotes
        }
        for i in 33..40 {
            ingest(32, i); // star B: 32 promotes
        }
        ingest(0, 32); // promoted↔promoted bridge
        assert_eq!(s.tier_counts().1, 2);
        let r = boruvka_components(&s);
        assert_eq!(r.forest.component[0], r.forest.component[32]);
        assert_eq!(r.forest.component[0], r.forest.component[39]);
        assert_ne!(r.forest.component[0], r.forest.component[50]);
    }
}
