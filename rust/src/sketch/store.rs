//! Main-node sketch storage: the graph sketch S(G) = ⋃_u S(f_u).
//!
//! One flat `Vec<AtomicU64>` holds all V vertex sketches.  Sketch deltas
//! arriving from (possibly concurrent) work-distributor threads are
//! merged with relaxed `fetch_xor` — XOR is commutative/associative, so
//! no ordering between deltas matters, and queries only run after the
//! ingestion barrier (the pipeline is drained first, paper §5.3).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sketch::params::SketchParams;
use crate::sketch::seeds::SketchSeeds;
use crate::sketch::CameoSketch;

/// The main node's graph sketch: V vertex sketches in one allocation.
pub struct SketchStore {
    params: SketchParams,
    seeds: SketchSeeds,
    words: Vec<AtomicU64>,
}

impl SketchStore {
    /// Allocate an all-zero graph sketch for `params`, seeded from
    /// `graph_seed`.
    pub fn new(params: SketchParams, graph_seed: u64) -> Self {
        let total = params.v as usize * params.words();
        let mut words = Vec::with_capacity(total);
        words.resize_with(total, || AtomicU64::new(0));
        Self {
            seeds: SketchSeeds::derive(&params, graph_seed),
            params,
            words,
        }
    }

    pub fn params(&self) -> &SketchParams {
        &self.params
    }

    pub fn seeds(&self) -> &SketchSeeds {
        &self.seeds
    }

    /// Total bytes of sketch storage (the paper's Θ(V log³ V) term).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }

    #[inline]
    fn vertex_base(&self, u: u32) -> usize {
        debug_assert!((u as u64) < self.params.v);
        u as usize * self.params.words()
    }

    /// XOR-merge a vertex-sketch delta into vertex `u` (thread-safe).
    pub fn merge_delta(&self, u: u32, delta: &[u64]) {
        debug_assert_eq!(delta.len(), self.params.words());
        let base = self.vertex_base(u);
        for (i, &d) in delta.iter().enumerate() {
            if d != 0 {
                self.words[base + i].fetch_xor(d, Ordering::Relaxed);
            }
        }
    }

    /// Apply a single edge-index update to vertex `u` locally (the main
    /// node's path for underfull leaves, §5.3).
    pub fn apply_local(&self, u: u32, idx: u64) {
        // relaxed atomic XORs, same rationale as merge_delta
        let base = self.vertex_base(u);
        let wpl = self.params.words_per_level();
        let rows = self.params.rows as usize;
        for level in 0..self.params.levels {
            let chk = crate::hashing::checksum(self.seeds.cseed(level), idx);
            let lbase = base + level as usize * wpl;
            for column in 0..self.params.columns {
                let h = crate::hashing::depth_hash(self.seeds.dseed(level, column), idx);
                let depth =
                    crate::hashing::bucket_depth(h, self.params.rows) as usize;
                let cbase = lbase + column as usize * rows * 2;
                self.words[cbase].fetch_xor(idx, Ordering::Relaxed);
                self.words[cbase + 1].fetch_xor(chk, Ordering::Relaxed);
                self.words[cbase + depth * 2].fetch_xor(idx, Ordering::Relaxed);
                self.words[cbase + depth * 2 + 1].fetch_xor(chk, Ordering::Relaxed);
            }
        }
    }

    /// Snapshot one level of vertex `u` into `out` (length
    /// `words_per_level`).  Only sound after the ingestion barrier.
    pub fn read_level_into(&self, u: u32, level: u32, out: &mut [u64]) {
        let wpl = self.params.words_per_level();
        debug_assert_eq!(out.len(), wpl);
        let base = self.vertex_base(u) + level as usize * wpl;
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = self.words[base + i].load(Ordering::Relaxed);
        }
    }

    /// XOR one level of vertex `u` into `acc` — the supernode
    /// aggregation step of sketch Borůvka (S(f_X) = Σ_{u∈X} S(f_u)).
    pub fn xor_level_into(&self, u: u32, level: u32, acc: &mut [u64]) {
        let wpl = self.params.words_per_level();
        debug_assert_eq!(acc.len(), wpl);
        let base = self.vertex_base(u) + level as usize * wpl;
        for (i, slot) in acc.iter_mut().enumerate() {
            *slot ^= self.words[base + i].load(Ordering::Relaxed);
        }
    }

    /// Query vertex `u` at `level` (convenience for tests/examples).
    pub fn query_vertex_level(&self, u: u32, level: u32) -> Option<u64> {
        let mut buf = vec![0u64; self.params.words_per_level()];
        self.read_level_into(u, level, &mut buf);
        CameoSketch::query_level(&buf, &self.params, &self.seeds, level)
    }

    /// Reset every bucket to zero (between bench runs).
    pub fn clear(&self) {
        for w in &self.words {
            w.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::encode_edge;

    fn store(v: u64, seed: u64) -> SketchStore {
        SketchStore::new(SketchParams::for_vertices(v), seed)
    }

    #[test]
    fn merge_delta_equals_local_updates() {
        let s1 = store(64, 42);
        let s2 = store(64, 42);
        let edges = [(1u32, 2u32), (1, 5), (1, 60)];
        let idx: Vec<u64> = edges.iter().map(|&(a, b)| encode_edge(a, b, 64)).collect();

        // path A: local single-update application
        for &i in &idx {
            s1.apply_local(1, i);
        }
        // path B: batched delta + merge
        let delta = CameoSketch::delta_of_batch(s2.params(), s2.seeds(), &idx);
        s2.merge_delta(1, &delta);

        let mut a = vec![0u64; s1.params().words_per_level()];
        let mut b = vec![0u64; s2.params().words_per_level()];
        for level in 0..s1.params().levels {
            s1.read_level_into(1, level, &mut a);
            s2.read_level_into(1, level, &mut b);
            assert_eq!(a, b, "level {level}");
        }
    }

    #[test]
    fn query_recovers_single_incident_edge() {
        let s = store(64, 9);
        let idx = encode_edge(7, 13, 64);
        s.apply_local(7, idx);
        s.apply_local(13, idx);
        assert_eq!(s.query_vertex_level(7, 0), Some(idx));
        assert_eq!(s.query_vertex_level(13, 0), Some(idx));
        assert_eq!(s.query_vertex_level(20, 0), None);
    }

    #[test]
    fn xor_level_into_aggregates_supernode() {
        // edges inside {0,1} cancel in the aggregate; the crossing edge
        // to 2 survives — exactly the cut-sampling property of App. A.
        let v = 16u64;
        let s = store(v, 5);
        let inner = encode_edge(0, 1, v);
        let crossing = encode_edge(1, 2, v);
        s.apply_local(0, inner);
        s.apply_local(1, inner);
        s.apply_local(1, crossing);
        s.apply_local(2, crossing);

        let wpl = s.params().words_per_level();
        for level in 0..s.params().levels {
            let mut acc = vec![0u64; wpl];
            s.xor_level_into(0, level, &mut acc);
            s.xor_level_into(1, level, &mut acc);
            let got =
                CameoSketch::query_level(&acc, s.params(), s.seeds(), level);
            assert_eq!(got, Some(crossing), "level {level}");
        }
    }

    #[test]
    fn concurrent_merges_commute() {
        let v = 32u64;
        let params = SketchParams::for_vertices(v);
        let s = std::sync::Arc::new(SketchStore::new(params, 77));
        let idx: Vec<u64> = (0..20)
            .map(|i| encode_edge(3, (i % 30) + 4, v))
            .collect();
        let deltas: Vec<Vec<u64>> = idx
            .chunks(5)
            .map(|c| CameoSketch::delta_of_batch(s.params(), s.seeds(), c))
            .collect();

        let mut handles = Vec::new();
        for d in deltas.clone() {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || s2.merge_delta(3, &d)));
        }
        for h in handles {
            h.join().unwrap();
        }

        // sequential reference
        let s_ref = SketchStore::new(params, 77);
        for d in &deltas {
            s_ref.merge_delta(3, d);
        }
        let mut a = vec![0u64; params.words_per_level()];
        let mut b = vec![0u64; params.words_per_level()];
        for level in 0..params.levels {
            s.read_level_into(3, level, &mut a);
            s_ref.read_level_into(3, level, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn bytes_accounting() {
        let s = store(128, 1);
        assert_eq!(
            s.bytes(),
            128 * SketchParams::for_vertices(128).bytes()
        );
    }

    #[test]
    fn clear_zeroes_everything() {
        let s = store(16, 2);
        s.apply_local(0, encode_edge(0, 1, 16));
        s.clear();
        assert_eq!(s.query_vertex_level(0, 0), None);
    }
}
