//! CubeSketch — GraphZeppelin's ℓ0-sampler (paper App. B.2), kept as the
//! ablation baseline for Fig. 4 / Fig. 16.
//!
//! Identical bucket matrix and goodness test as CameoSketch; the
//! difference is the update rule: an index with geometric depth `d`
//! touches **every** row `0..=d` of the column (Fig. 10) instead of just
//! {0, d}.  That makes updates `O(log n)` per column — the exact factor
//! CameoSketch removes (Theorem 4.2).
//!
//! The subset property ("each CameoSketch bucket contains a subset of
//! the same CubeSketch bucket") from the Theorem 4.2 proof is asserted
//! in the tests below: with shared randomness, any singleton CubeSketch
//! bucket is either identical in the CameoSketch or the CameoSketch has
//! its element at the column's deepest occupied row.

use crate::hashing;
use crate::sketch::params::SketchParams;
use crate::sketch::seeds::SketchSeeds;

/// Stateless CubeSketch operations over the same bucket layout as
/// [`crate::sketch::CameoSketch`].
pub struct CubeSketch;

impl CubeSketch {
    /// Apply one index update to a full vertex sketch (all levels).
    #[inline]
    pub fn apply_update(
        buckets: &mut [u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        idx: u64,
    ) {
        debug_assert_eq!(buckets.len(), params.words());
        let wpl = params.words_per_level();
        for level in 0..params.levels {
            let base = level as usize * wpl;
            Self::apply_update_level(
                &mut buckets[base..base + wpl],
                params,
                seeds,
                level,
                idx,
            );
        }
    }

    /// Apply one index update to one level: rows `0..=depth` all get it.
    #[inline(always)]
    pub fn apply_update_level(
        level_buckets: &mut [u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        level: u32,
        idx: u64,
    ) {
        let rows = params.rows as usize;
        let chk = hashing::checksum(seeds.cseed(level), idx);
        for column in 0..params.columns {
            let h = hashing::depth_hash(seeds.dseed(level, column), idx);
            let depth = hashing::bucket_depth(h, params.rows) as usize;
            let col_base = column as usize * rows * 2;
            for row in 0..=depth {
                level_buckets[col_base + row * 2] ^= idx;
                level_buckets[col_base + row * 2 + 1] ^= chk;
            }
        }
    }

    /// Batch delta (for the CubeSketch worker mode of the ablations).
    pub fn delta_of_batch(
        params: &SketchParams,
        seeds: &SketchSeeds,
        indices: &[u64],
    ) -> Vec<u64> {
        let mut delta = vec![0u64; params.words()];
        for &idx in indices {
            if idx != 0 {
                Self::apply_update(&mut delta, params, seeds, idx);
            }
        }
        delta
    }

    /// Query is identical to CameoSketch's (the paper changes only the
    /// update procedure).
    pub fn query_level(
        level_buckets: &[u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        level: u32,
    ) -> Option<u64> {
        crate::sketch::CameoSketch::query_level(level_buckets, params, seeds, level)
    }

    /// Hash evaluations per update — same as CameoSketch (hashing is per
    /// column, the extra cost is bucket *writes*).
    pub fn hashes_per_update(params: &SketchParams) -> u64 {
        params.levels as u64 * (1 + params.columns as u64)
    }

    /// Expected bucket writes per update: rows 0..=d with E[d] ≈ 2, times
    /// columns and levels — the O(log n) vs O(1) per-column contrast is
    /// in the worst case (d can be R-1).
    pub fn worst_case_writes_per_update(params: &SketchParams) -> u64 {
        params.levels as u64 * params.columns as u64 * params.rows as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::encode_edge;
    use crate::sketch::CameoSketch;
    use crate::util::testkit::{arb_edge_set, Cases};

    #[test]
    fn insert_delete_cancels() {
        let params = SketchParams::for_vertices(64);
        let seeds = SketchSeeds::derive(&params, 3);
        let e = encode_edge(5, 6, 64);
        let delta = CubeSketch::delta_of_batch(&params, &seeds, &[e, e]);
        assert!(delta.iter().all(|&w| w == 0));
    }

    #[test]
    fn single_edge_recovered() {
        let params = SketchParams::for_vertices(64);
        let seeds = SketchSeeds::derive(&params, 8);
        let e = encode_edge(10, 30, 64);
        let delta = CubeSketch::delta_of_batch(&params, &seeds, &[e]);
        let wpl = params.words_per_level();
        assert_eq!(
            CubeSketch::query_level(&delta[..wpl], &params, &seeds, 0),
            Some(e)
        );
    }

    #[test]
    fn row0_matches_cameo_row0() {
        // both sketches update the deterministic bucket identically
        Cases::new(20).run(|rng| {
            let v = 128u64;
            let params = SketchParams::for_vertices(v);
            let seeds = SketchSeeds::derive(&params, rng.next_u64());
            let edges = arb_edge_set(rng, v, 30);
            let idx: Vec<u64> = edges.iter().map(|&(a, b)| encode_edge(a, b, v)).collect();
            let cube = CubeSketch::delta_of_batch(&params, &seeds, &idx);
            let cameo = CameoSketch::delta_of_batch(&params, &seeds, &idx);
            let rows = params.rows as usize;
            for level in 0..params.levels as usize {
                let base = level * params.words_per_level();
                for col in 0..params.columns as usize {
                    let off = base + col * rows * 2;
                    assert_eq!(cube[off], cameo[off], "alpha row0");
                    assert_eq!(cube[off + 1], cameo[off + 1], "gamma row0");
                }
            }
        });
    }

    #[test]
    fn cameo_good_whenever_cube_good() {
        // Theorem 4.2's proof obligation, checked empirically: with
        // shared randomness, if CubeSketch recovers an element from a
        // column then CameoSketch's query on the same column succeeds.
        Cases::new(30).run(|rng| {
            let v = 128u64;
            let params = SketchParams::for_vertices(v);
            let seeds = SketchSeeds::derive(&params, rng.next_u64());
            let edges = arb_edge_set(rng, v, 60);
            if edges.is_empty() {
                return;
            }
            let idx: Vec<u64> = edges.iter().map(|&(a, b)| encode_edge(a, b, v)).collect();
            let cube = CubeSketch::delta_of_batch(&params, &seeds, &idx);
            let cameo = CameoSketch::delta_of_batch(&params, &seeds, &idx);
            let wpl = params.words_per_level();
            for level in 0..params.levels {
                let b = level as usize * wpl;
                let cube_hit =
                    CubeSketch::query_level(&cube[b..b + wpl], &params, &seeds, level);
                let cameo_hit =
                    CameoSketch::query_level(&cameo[b..b + wpl], &params, &seeds, level);
                if cube_hit.is_some() {
                    assert!(
                        cameo_hit.is_some(),
                        "cube recovered but cameo failed at level {level}"
                    );
                }
            }
        });
    }

    #[test]
    fn write_cost_exceeds_cameo() {
        let p = SketchParams::for_vertices(1 << 13);
        // worst-case CubeSketch writes are R/2 times CameoSketch's 2/column
        assert!(
            CubeSketch::worst_case_writes_per_update(&p)
                > 4 * p.levels as u64 * p.columns as u64
        );
    }
}
