//! Linear graph sketches: CameoSketch (the paper's contribution),
//! CubeSketch (the prior state of the art, kept as the ablation
//! baseline), vertex-sketch storage, and batched delta computation.
//!
//! A *vertex sketch* for vertex `u` is `L` independent ℓ0-samplers of
//! u's characteristic vector `f_u ∈ Z_2^(V·V)`, one consumed per Borůvka
//! round.  Each sampler is a `C × R` matrix of buckets `(α, γ)`:
//! α = XOR of the indices hashed into the bucket, γ = XOR of their
//! checksums.  A bucket holding exactly one nonzero index is *good* —
//! its α is that index and its γ matches `checksum(α)`.
//!
//! Everything is linear over XOR, which is what lets Landscape compute
//! deltas remotely and merge them on the main node (paper §5.2).

#![deny(missing_docs)]

pub mod cameo;
pub mod cube;
pub mod params;
pub mod seeds;
pub mod shard;
pub mod store;

pub use cameo::CameoSketch;
pub use cube::CubeSketch;
pub use params::SketchParams;
pub use seeds::SketchSeeds;
pub use shard::ShardSpec;
pub use store::SketchStore;
