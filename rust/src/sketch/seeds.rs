//! Per-graph seed material for the sketch hash functions.
//!
//! Derivation must match `python/compile/model.py::seeds_for` — the Rust
//! coordinator feeds exactly these arrays to the AOT executable as
//! runtime inputs, and the native worker consumes them directly.

use crate::hashing;
use crate::sketch::params::SketchParams;

/// Flattened seed arrays for one sketch instance.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SketchSeeds {
    /// Depth-hash seeds, row-major `[level][column]`, length L·C.
    pub dseeds: Vec<u64>,
    /// Checksum seeds, one per level, length L.
    pub cseeds: Vec<u64>,
    columns: u32,
}

impl SketchSeeds {
    /// Derive all seeds for `graph_seed`.
    pub fn derive(params: &SketchParams, graph_seed: u64) -> Self {
        let mut dseeds = Vec::with_capacity((params.levels * params.columns) as usize);
        let mut cseeds = Vec::with_capacity(params.levels as usize);
        for level in 0..params.levels {
            cseeds.push(hashing::checksum_seed(graph_seed, level));
            for column in 0..params.columns {
                dseeds.push(hashing::depth_seed(graph_seed, level, column));
            }
        }
        Self {
            dseeds,
            cseeds,
            columns: params.columns,
        }
    }

    /// Seed used by k-connectivity copy `copy` (copy 0 == graph_seed).
    ///
    /// Each of the k independent connectivity sketches needs fresh
    /// randomness; deriving per-copy seeds keeps the worker protocol
    /// unchanged (seeds are runtime inputs).
    pub fn copy_seed(graph_seed: u64, copy: u32) -> u64 {
        if copy == 0 {
            graph_seed
        } else {
            hashing::splitmix64(graph_seed ^ (copy as u64).wrapping_mul(0xC2B2AE3D27D4EB4F))
        }
    }

    /// Depth seed for (level, column).
    #[inline(always)]
    pub fn dseed(&self, level: u32, column: u32) -> u64 {
        self.dseeds[(level * self.columns + column) as usize]
    }

    /// Checksum seed for `level`.
    #[inline(always)]
    pub fn cseed(&self, level: u32) -> u64 {
        self.cseeds[level as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivation_matches_hashing_primitives() {
        let p = SketchParams::for_vertices(128);
        let s = SketchSeeds::derive(&p, 42);
        for lvl in 0..p.levels {
            assert_eq!(s.cseed(lvl), hashing::checksum_seed(42, lvl));
            for col in 0..p.columns {
                assert_eq!(s.dseed(lvl, col), hashing::depth_seed(42, lvl, col));
            }
        }
    }

    #[test]
    fn all_seeds_distinct() {
        let p = SketchParams::for_vertices(1 << 12);
        let s = SketchSeeds::derive(&p, 7);
        let mut all: Vec<u64> = s.dseeds.clone();
        all.extend(&s.cseeds);
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }

    #[test]
    fn copy_seeds_distinct_and_stable() {
        let s0 = SketchSeeds::copy_seed(99, 0);
        assert_eq!(s0, 99);
        let mut seen = std::collections::HashSet::new();
        for k in 0..16 {
            assert!(seen.insert(SketchSeeds::copy_seed(99, k)));
        }
    }
}
