//! CameoSketch — the paper's ℓ0-sampler (§4.2, App. B.3).
//!
//! Native Rust implementation of the same update procedure the L1 Pallas
//! kernel computes, bit-identical by construction (shared hashing
//! contract + the `delta_golden.json` fixture + the runtime
//! equivalence test in `tests/xla_parity.rs`).
//!
//! Update procedure (Fig. 12): per (level, column) an index touches
//! exactly **two** buckets — the deterministic row 0 and one geometric
//! row — so the per-update work is `O(log 1/δ)` per level instead of
//! CubeSketch's `O(log n · log 1/δ)` (Theorem 4.2, Claim 1.2).

use crate::hashing;
use crate::sketch::params::SketchParams;
use crate::sketch::seeds::SketchSeeds;

/// Stateless CameoSketch operations over caller-owned bucket storage.
///
/// Bucket layout for one vertex: `[level][column][row][α|γ]` flattened
/// into `params.words()` u64 words (see [`SketchParams::bucket_offset`]).
pub struct CameoSketch;

impl CameoSketch {
    /// Apply one index update to a full vertex sketch (all levels).
    #[inline]
    pub fn apply_update(
        buckets: &mut [u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        idx: u64,
    ) {
        debug_assert_eq!(buckets.len(), params.words());
        debug_assert_ne!(idx, 0, "0 is the padding sentinel");
        let wpl = params.words_per_level();
        for level in 0..params.levels {
            let base = level as usize * wpl;
            Self::apply_update_level(
                &mut buckets[base..base + wpl],
                params,
                seeds,
                level,
                idx,
            );
        }
    }

    /// Apply one index update to a single level's `C × R` bucket matrix.
    #[inline(always)]
    pub fn apply_update_level(
        level_buckets: &mut [u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        level: u32,
        idx: u64,
    ) {
        let rows = params.rows as usize;
        let chk = hashing::checksum(seeds.cseed(level), idx);
        for column in 0..params.columns {
            let h = hashing::depth_hash(seeds.dseed(level, column), idx);
            let depth = hashing::bucket_depth(h, params.rows) as usize;
            let col_base = column as usize * rows * 2;
            // deterministic bucket (row 0)
            level_buckets[col_base] ^= idx;
            level_buckets[col_base + 1] ^= chk;
            // geometric bucket (row `depth`)
            level_buckets[col_base + depth * 2] ^= idx;
            level_buckets[col_base + depth * 2 + 1] ^= chk;
        }
    }

    /// Compute the sketch delta of a batch of indices — what a
    /// distributed worker does (paper §5.2).  Zero entries (padding) are
    /// skipped, mirroring the AOT kernel's sentinel handling.
    pub fn delta_of_batch(
        params: &SketchParams,
        seeds: &SketchSeeds,
        indices: &[u64],
    ) -> Vec<u64> {
        let mut delta = vec![0u64; params.words()];
        Self::delta_of_batch_into(&mut delta, params, seeds, indices);
        delta
    }

    /// Same as [`Self::delta_of_batch`] but reusing caller storage (the
    /// worker hot path: one scratch buffer per worker thread).
    ///
    /// Perf note (§Perf iteration 1): the loop is **level-major**, not
    /// update-major — one level's `C×R×2` bucket slice (~1–2 KiB) stays
    /// L1-resident while the whole batch streams through it, instead of
    /// every update touching all `L` level slices.  The per-level seeds
    /// also stay in registers.
    pub fn delta_of_batch_into(
        delta: &mut [u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        indices: &[u64],
    ) {
        debug_assert_eq!(delta.len(), params.words());
        delta.fill(0);
        let wpl = params.words_per_level();
        let rows = params.rows as usize;
        for level in 0..params.levels {
            let lvl_delta = &mut delta[level as usize * wpl..(level as usize + 1) * wpl];
            let cseed = seeds.cseed(level);
            for &idx in indices {
                if idx == 0 {
                    continue; // padding sentinel
                }
                let chk = hashing::checksum(cseed, idx);
                for column in 0..params.columns {
                    let h = hashing::depth_hash(seeds.dseed(level, column), idx);
                    let depth = hashing::bucket_depth(h, params.rows) as usize;
                    let col_base = column as usize * rows * 2;
                    lvl_delta[col_base] ^= idx;
                    lvl_delta[col_base + 1] ^= chk;
                    lvl_delta[col_base + depth * 2] ^= idx;
                    lvl_delta[col_base + depth * 2 + 1] ^= chk;
                }
            }
        }
    }

    /// XOR-merge `delta` into `acc` (linearity: S(x)+S(y) = S(x+y)).
    ///
    /// Hot-path kernel: 8-way unrolled over u64 chunks so the compiler
    /// emits eight independent load/XOR/store chains per iteration
    /// (auto-vectorizable, no nightly features).  Bit-for-bit identical
    /// to [`Self::merge_scalar`] for every length and alignment — the
    /// `unrolled_merge_matches_scalar_reference` property test holds the
    /// two together, including non-multiple-of-8 tails.
    #[inline]
    pub fn merge(acc: &mut [u64], delta: &[u64]) {
        debug_assert_eq!(acc.len(), delta.len());
        let mut ac = acc.chunks_exact_mut(8);
        let mut dc = delta.chunks_exact(8);
        for (a, d) in (&mut ac).zip(&mut dc) {
            let [a0, a1, a2, a3, a4, a5, a6, a7] = a else {
                unreachable!()
            };
            let [d0, d1, d2, d3, d4, d5, d6, d7] = d else {
                unreachable!()
            };
            *a0 ^= *d0;
            *a1 ^= *d1;
            *a2 ^= *d2;
            *a3 ^= *d3;
            *a4 ^= *d4;
            *a5 ^= *d5;
            *a6 ^= *d6;
            *a7 ^= *d7;
        }
        for (a, d) in ac.into_remainder().iter_mut().zip(dc.remainder()) {
            *a ^= *d;
        }
    }

    /// The scalar reference implementation of [`Self::merge`], retained
    /// as the correctness oracle for the unrolled kernel and as the
    /// `merge_scalar_*` baseline rows of `benches/micro_hot_paths.rs`
    /// (tracked in the committed `BENCH_micro.json` trajectory).
    #[inline]
    pub fn merge_scalar(acc: &mut [u64], delta: &[u64]) {
        debug_assert_eq!(acc.len(), delta.len());
        for (a, d) in acc.iter_mut().zip(delta) {
            *a ^= *d;
        }
    }

    /// Query one level for a nonzero index of the sketched vector.
    ///
    /// Scans each column deepest-row-first and returns the first *good*
    /// bucket's α.  A bucket is good iff α ≠ 0 and `checksum(α) == γ`;
    /// a bad bucket passes this test with probability 2^-64 (the
    /// polynomially-small checksum-error term of Theorem 4.2).
    pub fn query_level(
        level_buckets: &[u64],
        params: &SketchParams,
        seeds: &SketchSeeds,
        level: u32,
    ) -> Option<u64> {
        let rows = params.rows as usize;
        let cseed = seeds.cseed(level);
        for column in 0..params.columns as usize {
            let col_base = column * rows * 2;
            for row in (0..rows).rev() {
                let alpha = level_buckets[col_base + row * 2];
                let gamma = level_buckets[col_base + row * 2 + 1];
                if alpha != 0 && hashing::checksum(cseed, alpha) == gamma {
                    return Some(alpha);
                }
            }
        }
        None
    }

    /// Number of hash evaluations one update costs — used by the bench
    /// harness to report the paper's "hash calls per update" figure.
    pub fn hashes_per_update(params: &SketchParams) -> u64 {
        // per level: 1 checksum + C depth hashes
        params.levels as u64 * (1 + params.columns as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::encode_edge;
    use crate::util::json::Json;
    use crate::util::testkit::{arb_edge_set, Cases};

    fn fixture() -> Json {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../tests/fixtures/delta_golden.json"
        );
        let text = std::fs::read_to_string(path)
            .expect("delta_golden.json missing — run `make fixtures`");
        Json::parse(&text).unwrap()
    }

    #[test]
    fn delta_matches_python_golden() {
        let fx = fixture();
        let v = fx.get("vertices").unwrap().as_u64().unwrap();
        let gs = fx.get("graph_seed").unwrap().as_u64().unwrap();
        let params = SketchParams::for_vertices(v);
        let seeds = SketchSeeds::derive(&params, gs);
        let indices: Vec<u64> = fx
            .get("indices")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_u64().unwrap())
            .collect();
        let delta = CameoSketch::delta_of_batch(&params, &seeds, &indices);
        let want: Vec<u64> = fx
            .get("delta")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_u64().unwrap())
            .collect();
        assert_eq!(delta, want, "native kernel diverged from python oracle");
    }

    #[test]
    fn insert_delete_cancels() {
        let params = SketchParams::for_vertices(64);
        let seeds = SketchSeeds::derive(&params, 11);
        let e = encode_edge(3, 9, 64);
        let delta = CameoSketch::delta_of_batch(&params, &seeds, &[e, e]);
        assert!(delta.iter().all(|&w| w == 0));
    }

    #[test]
    fn padding_zeros_skipped() {
        let params = SketchParams::for_vertices(64);
        let seeds = SketchSeeds::derive(&params, 11);
        let e = encode_edge(1, 2, 64);
        let a = CameoSketch::delta_of_batch(&params, &seeds, &[e]);
        let b = CameoSketch::delta_of_batch(&params, &seeds, &[e, 0, 0, 0]);
        assert_eq!(a, b);
    }

    #[test]
    fn linearity_merge_equals_concat() {
        Cases::new(30).run(|rng| {
            let v = 64u64;
            let params = SketchParams::for_vertices(v);
            let seeds = SketchSeeds::derive(&params, rng.next_u64());
            let ea = arb_edge_set(rng, v, 20);
            let eb = arb_edge_set(rng, v, 20);
            let ia: Vec<u64> = ea.iter().map(|&(a, b)| encode_edge(a, b, v)).collect();
            let ib: Vec<u64> = eb.iter().map(|&(a, b)| encode_edge(a, b, v)).collect();
            let mut da = CameoSketch::delta_of_batch(&params, &seeds, &ia);
            let db = CameoSketch::delta_of_batch(&params, &seeds, &ib);
            let mut iab = ia.clone();
            iab.extend(&ib);
            let dab = CameoSketch::delta_of_batch(&params, &seeds, &iab);
            CameoSketch::merge(&mut da, &db);
            assert_eq!(da, dab);
        });
    }

    #[test]
    fn unrolled_merge_matches_scalar_reference() {
        // the unrolled kernel must be bit-for-bit the scalar fold for
        // every length (incl. 0 and non-multiple-of-8 tails) and for
        // every sub-slice alignment of a larger buffer
        Cases::new(60).run(|rng| {
            let len = (rng.next_u64() % 40) as usize;
            let off = (rng.next_u64() % 9) as usize;
            let total = off + len;
            let base: Vec<u64> = (0..total).map(|_| rng.next_u64()).collect();
            let delta: Vec<u64> = (0..total).map(|_| rng.next_u64()).collect();
            let mut a = base.clone();
            let mut b = base.clone();
            CameoSketch::merge(&mut a[off..], &delta[off..]);
            CameoSketch::merge_scalar(&mut b[off..], &delta[off..]);
            assert_eq!(a, b, "len {len} offset {off}");
        });
    }

    #[test]
    fn single_edge_always_recovered() {
        // with one nonzero, row-0 deterministic buckets are always good
        Cases::new(50).run(|rng| {
            let v = 256u64;
            let params = SketchParams::for_vertices(v);
            let seeds = SketchSeeds::derive(&params, rng.next_u64());
            let (a, b) = crate::util::testkit::arb_edge(rng, v);
            let idx = encode_edge(a, b, v);
            let delta = CameoSketch::delta_of_batch(&params, &seeds, &[idx]);
            for level in 0..params.levels {
                let wpl = params.words_per_level();
                let base = level as usize * wpl;
                let got = CameoSketch::query_level(
                    &delta[base..base + wpl],
                    &params,
                    &seeds,
                    level,
                );
                assert_eq!(got, Some(idx));
            }
        });
    }

    #[test]
    fn query_empty_sketch_is_none() {
        let params = SketchParams::for_vertices(64);
        let seeds = SketchSeeds::derive(&params, 5);
        let empty = vec![0u64; params.words_per_level()];
        assert_eq!(CameoSketch::query_level(&empty, &params, &seeds, 0), None);
    }

    #[test]
    fn query_returns_valid_index_with_many_nonzeros() {
        Cases::new(20).run(|rng| {
            let v = 256u64;
            let params = SketchParams::for_vertices(v);
            let seeds = SketchSeeds::derive(&params, rng.next_u64());
            let edges = arb_edge_set(rng, v, 100);
            if edges.is_empty() {
                return;
            }
            let set: std::collections::HashSet<u64> = edges
                .iter()
                .map(|&(a, b)| encode_edge(a, b, v))
                .collect();
            let indices: Vec<u64> = set.iter().copied().collect();
            let delta = CameoSketch::delta_of_batch(&params, &seeds, &indices);
            let mut recovered = 0;
            for level in 0..params.levels {
                let wpl = params.words_per_level();
                let base = level as usize * wpl;
                if let Some(got) = CameoSketch::query_level(
                    &delta[base..base + wpl],
                    &params,
                    &seeds,
                    level,
                ) {
                    assert!(set.contains(&got), "recovered a non-member index");
                    recovered += 1;
                }
            }
            // Lemma H.4: each level succeeds w.p. >= 2/3 per column group;
            // across L levels nearly all should recover *something*.
            assert!(
                recovered * 2 >= params.levels,
                "only {recovered}/{} levels recovered",
                params.levels
            );
        });
    }

    #[test]
    fn update_cost_is_log_v() {
        // Claim 1.2: per-update hashes scale with L (≈ log V), not L·R
        let p13 = SketchParams::for_vertices(1 << 13);
        assert_eq!(
            CameoSketch::hashes_per_update(&p13),
            p13.levels as u64 * 4
        );
    }
}
