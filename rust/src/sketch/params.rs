//! Sketch parameter derivation — must match `python/compile/params.py`
//! exactly (the AOT artifacts are compiled against these shapes).

/// Version tag for the seed-derivation scheme; the runtime refuses
/// artifacts whose manifest carries a different version.
pub const SEED_SCHEME_VERSION: u64 = 1;

/// Default number of columns per level (δ = 3^-C per column group, per
/// Theorem 4.3's `log_3(1/δ)` column count).
pub const DEFAULT_COLUMNS: u32 = 3;

/// Shape of one vertex sketch for a V-vertex graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SketchParams {
    /// Number of graph vertices.
    pub v: u64,
    /// Independent CameoSketch repetitions (one per Borůvka round):
    /// `ceil(log_{3/2} V)` (paper App. E.2).
    pub levels: u32,
    /// Columns per level.
    pub columns: u32,
    /// Bucket rows per column: `log2(n) + 6`, n = V²; row 0 is the
    /// deterministic bucket.
    pub rows: u32,
}

impl SketchParams {
    /// Derive the sketch shape for a V-vertex graph.
    pub fn for_vertices(v: u64) -> Self {
        Self::with_columns(v, DEFAULT_COLUMNS)
    }

    /// Same, with an explicit column count.
    pub fn with_columns(v: u64, columns: u32) -> Self {
        Self {
            v,
            levels: num_levels(v),
            columns,
            rows: num_rows(v),
        }
    }

    /// Buckets per level (C·R).
    #[inline]
    pub fn buckets_per_level(&self) -> usize {
        (self.columns * self.rows) as usize
    }

    /// u64 words per level — each bucket is an (α, γ) pair.
    #[inline]
    pub fn words_per_level(&self) -> usize {
        self.buckets_per_level() * 2
    }

    /// u64 words per vertex sketch.
    #[inline]
    pub fn words(&self) -> usize {
        self.levels as usize * self.words_per_level()
    }

    /// Bytes per vertex sketch.
    #[inline]
    pub fn bytes(&self) -> usize {
        self.words() * 8
    }

    /// Word offset of bucket (level, column, row) within a vertex sketch;
    /// the (α, γ) pair lives at `[off, off+1]`.
    #[inline(always)]
    pub fn bucket_offset(&self, level: u32, column: u32, row: u32) -> usize {
        debug_assert!(level < self.levels && column < self.columns && row < self.rows);
        ((level * self.columns * self.rows + column * self.rows + row) * 2) as usize
    }

    /// Default leaf-buffer / vertex-based-batch capacity in updates.
    ///
    /// Paper §5.1.1: a batch is sent when it holds `α·φ/log V` updates
    /// (φ = sketch bits), i.e. when the batch occupies `α×` the bytes of
    /// the sketch delta it will come back as.  With 8-byte updates and
    /// 16-byte buckets this is `α · L · C · R · 2` updates.
    pub fn batch_capacity(&self, alpha: u32) -> usize {
        self.words() * alpha as usize
    }
}

/// `ceil(log_{3/2} V)` sketch levels, min 1.
pub fn num_levels(v: u64) -> u32 {
    if v < 2 {
        return 1;
    }
    let l = ((v as f64).ln() / 1.5f64.ln()).ceil() as u32;
    l.max(1)
}

/// `log2(n) + 6` rows where n = V².
pub fn num_rows(v: u64) -> u32 {
    let n_bits = ((v.max(4) as f64).log2().ceil() as u32 * 2).max(1);
    n_bits + 6
}

/// Edge (u,v) → characteristic-vector index.  0 is reserved as the
/// padding sentinel, hence the +1 shift.  Orientation-invariant.
#[inline(always)]
pub fn encode_edge(u: u32, v: u32, num_vertices: u64) -> u64 {
    let (lo, hi) = if u < v { (u, v) } else { (v, u) };
    debug_assert!((hi as u64) < num_vertices && lo != hi);
    lo as u64 * num_vertices + hi as u64 + 1
}

/// Inverse of [`encode_edge`].
#[inline(always)]
pub fn decode_edge(idx: u64, num_vertices: u64) -> (u32, u32) {
    debug_assert!(idx != 0);
    let raw = idx - 1;
    ((raw / num_vertices) as u32, (raw % num_vertices) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{arb_edge, Cases};

    #[test]
    fn known_values_match_python() {
        // pinned against python/compile/params.py (test_model.py)
        assert_eq!(num_levels(1 << 13), 23);
        assert_eq!(num_rows(1 << 13), 32);
        assert_eq!(num_levels(1 << 17), 30);
        assert_eq!(num_rows(1 << 17), 40);
    }

    #[test]
    fn shape_matches_delta_golden_fixture() {
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../tests/fixtures/delta_golden.json"
        );
        let text = std::fs::read_to_string(path)
            .expect("delta_golden.json missing — run `make fixtures`");
        let fx = crate::util::json::Json::parse(&text).unwrap();
        let v = fx.get("vertices").unwrap().as_u64().unwrap();
        let p = SketchParams::for_vertices(v);
        assert_eq!(p.levels as u64, fx.get("levels").unwrap().as_u64().unwrap());
        assert_eq!(p.columns as u64, fx.get("columns").unwrap().as_u64().unwrap());
        assert_eq!(p.rows as u64, fx.get("rows").unwrap().as_u64().unwrap());
    }

    #[test]
    fn sketch_is_polylog_sized() {
        // Claim 1.1: sketch bytes << adjacency row for dense graphs
        let p = SketchParams::for_vertices(1 << 16);
        assert!(p.bytes() < 64 * 1024);
        assert!((p.bytes() as u64) < (1u64 << 16) * (1 << 16) / 8 / 4);
    }

    #[test]
    fn bucket_offsets_are_dense_and_disjoint() {
        let p = SketchParams::with_columns(64, 3);
        let mut seen = std::collections::HashSet::new();
        for l in 0..p.levels {
            for c in 0..p.columns {
                for r in 0..p.rows {
                    let off = p.bucket_offset(l, c, r);
                    assert!(off + 1 < p.words());
                    assert!(seen.insert(off), "offset collision at {l},{c},{r}");
                }
            }
        }
        assert_eq!(seen.len() * 2, p.words());
    }

    #[test]
    fn edge_encode_decode_roundtrip() {
        Cases::new(300).run(|rng| {
            let v = 2 + rng.next_below(1 << 20);
            let (a, b) = arb_edge(rng, v);
            let idx = encode_edge(a, b, v);
            assert_ne!(idx, 0);
            assert_eq!(decode_edge(idx, v), (a, b));
        });
    }

    #[test]
    fn encode_is_orientation_invariant() {
        assert_eq!(encode_edge(3, 7, 100), encode_edge(7, 3, 100));
    }

    #[test]
    fn batch_capacity_scales_with_alpha() {
        let p = SketchParams::for_vertices(1 << 10);
        assert_eq!(p.batch_capacity(2), 2 * p.words());
        // comm factor: delta bytes / batch bytes = 1/alpha
        let delta_bytes = p.bytes();
        let batch_bytes = p.batch_capacity(2) * 8;
        assert_eq!(batch_bytes, 2 * delta_bytes);
    }

    #[test]
    fn levels_monotone_in_v() {
        let mut prev = 0;
        for p in 1..22 {
            let l = num_levels(1 << p);
            assert!(l >= prev);
            prev = l;
        }
    }
}
