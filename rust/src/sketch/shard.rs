//! The vertex shard map shared by the merge path.
//!
//! The coordinator's distributor threads each own one shard of the graph
//! sketch: `shard(u) = hash(u) mod N`, with N ≈ the distributor thread
//! count.  Batches are routed shard-affine end-to-end (hypertree/gutter →
//! work queue → distributor → sketch store), so a shard is only ever
//! written by its owning thread during ingestion and the XOR merge never
//! serializes behind a global lock (the GraphZeppelin shared-map
//! bottleneck, arXiv 2203.14927).
//!
//! The shard hash is the identity: stream vertex ids are dense in
//! `[0, V)` (and pre-permuted by the stream layer), so round-robin modulo
//! is a perfectly balanced shard function whose within-shard slot index
//! (`u / N`) costs no lookup table — important because the merge path
//! resolves it once per delta word batch.

/// A shard map over vertex ids: `shard = u % N`, `slot = u / N`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    count: u32,
}

impl ShardSpec {
    /// The trivial single-shard map (everything in shard 0).
    pub const SINGLE: ShardSpec = ShardSpec { count: 1 };

    /// A map with `count` shards (≥ 1).
    pub fn new(count: usize) -> Self {
        assert!(count >= 1, "shard count must be at least 1");
        assert!(count <= u32::MAX as usize);
        Self {
            count: count as u32,
        }
    }

    /// Number of shards.
    #[inline(always)]
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Shard owning vertex `u`.
    #[inline(always)]
    pub fn shard_of(&self, u: u32) -> usize {
        (u % self.count) as usize
    }

    /// Dense within-shard slot of vertex `u`.
    #[inline(always)]
    pub fn slot_of(&self, u: u32) -> usize {
        (u / self.count) as usize
    }

    /// Inverse of (`shard_of`, `slot_of`).
    #[inline(always)]
    pub fn vertex_at(&self, shard: usize, slot: usize) -> u32 {
        slot as u32 * self.count + shard as u32
    }

    /// Vertices of a V-vertex graph assigned to `shard`.
    pub fn shard_len(&self, shard: usize, vertices: u64) -> usize {
        let shard = shard as u64;
        if shard >= vertices {
            return 0;
        }
        ((vertices - shard - 1) / self.count as u64 + 1) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_balance() {
        for n in [1usize, 2, 3, 8] {
            let spec = ShardSpec::new(n);
            let v = 100u64;
            let mut per_shard = vec![0usize; n];
            for u in 0..v as u32 {
                let (s, i) = (spec.shard_of(u), spec.slot_of(u));
                assert!(s < n);
                assert_eq!(spec.vertex_at(s, i), u);
                per_shard[s] += 1;
            }
            for (s, &len) in per_shard.iter().enumerate() {
                assert_eq!(len, spec.shard_len(s, v), "shard {s} of {n}");
            }
            assert_eq!(per_shard.iter().sum::<usize>(), v as usize);
            // modulo round-robin is balanced to within one vertex
            let (min, max) = (per_shard.iter().min(), per_shard.iter().max());
            assert!(max.unwrap() - min.unwrap() <= 1);
        }
    }

    #[test]
    fn shard_len_handles_small_graphs() {
        let spec = ShardSpec::new(8);
        assert_eq!(spec.shard_len(0, 3), 1);
        assert_eq!(spec.shard_len(2, 3), 1);
        assert_eq!(spec.shard_len(3, 3), 0);
        assert_eq!(spec.shard_len(7, 3), 0);
    }

    #[test]
    fn single_is_identity() {
        let spec = ShardSpec::SINGLE;
        assert_eq!(spec.shard_of(12345), 0);
        assert_eq!(spec.slot_of(12345), 12345);
        assert_eq!(spec.shard_len(0, 77), 77);
    }
}
