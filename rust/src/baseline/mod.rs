//! Comparator baselines (paper §2.1, App. F.2): the adjacency-matrix
//! lossless representation Landscape out-ingests on dense graphs, and
//! the exact streaming referee used for correctness validation.

pub mod adj_matrix;
pub mod referee;

pub use adj_matrix::AdjacencyMatrix;
pub use referee::Referee;
