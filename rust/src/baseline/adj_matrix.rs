//! Adjacency-matrix ingestion baseline (paper §2.1).
//!
//! The space-optimal lossless representation of a dense random graph:
//! one bit per unordered pair, updated by a single bit flip.  The paper's
//! striking observation is that Landscape's *sketch* ingestion outruns
//! even this — bit flips land on random cache lines, while sketch-delta
//! ingestion is mostly sequential.  This module exists to reproduce that
//! comparison and the crossover-size arithmetic.

use crate::stream::update::Update;

/// Bit-packed upper-triangular adjacency matrix.
pub struct AdjacencyMatrix {
    v: u64,
    bits: Vec<u64>,
}

impl AdjacencyMatrix {
    pub fn new(v: u64) -> Self {
        let pairs = v * (v - 1) / 2;
        Self {
            v,
            bits: vec![0u64; crate::util::div_ceil(pairs as usize, 64)],
        }
    }

    /// Triangular index of pair (a < b).
    #[inline(always)]
    fn pair_index(&self, a: u32, b: u32) -> u64 {
        debug_assert!(a < b && (b as u64) < self.v);
        // row-major upper triangle: offset(a) + (b - a - 1)
        let a = a as u64;
        let b = b as u64;
        a * self.v - a * (a + 1) / 2 + (b - a - 1)
    }

    /// Apply one update — insert and delete are both one bit flip (the
    /// cheapest conceivable update).
    #[inline(always)]
    pub fn apply(&mut self, upd: &Update) {
        let (a, b) = upd.endpoints();
        let i = self.pair_index(a, b);
        self.bits[(i / 64) as usize] ^= 1u64 << (i % 64);
    }

    /// Is edge (a, b) present?
    pub fn contains(&self, a: u32, b: u32) -> bool {
        let (a, b) = if a < b { (a, b) } else { (b, a) };
        let i = self.pair_index(a, b);
        self.bits[(i / 64) as usize] >> (i % 64) & 1 == 1
    }

    /// Number of set bits (edges).
    pub fn num_edges(&self) -> u64 {
        self.bits.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Storage bytes — the quantity the sketch's Θ(V log³V) beats once
    /// V exceeds the crossover (~310k vertices in the paper).
    pub fn bytes(&self) -> usize {
        self.bits.len() * 8
    }

    /// Edge list (for the correctness referee).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for a in 0..self.v as u32 {
            for b in (a + 1)..self.v as u32 {
                if self.contains(a, b) {
                    out.push((a, b));
                }
            }
        }
        out
    }

    pub fn vertices(&self) -> u64 {
        self.v
    }
}

/// Crossover arithmetic: smallest V where the sketch is smaller than the
/// adjacency matrix (paper: ~310,000 vertices).
pub fn sketch_smaller_crossover() -> u64 {
    let mut v = 1u64 << 10;
    loop {
        let sketch = crate::sketch::params::SketchParams::for_vertices(v).bytes() as u64 * v;
        let matrix = v * (v - 1) / 2 / 8;
        if sketch < matrix {
            return v;
        }
        v += v / 8;
        if v > 1 << 40 {
            return v; // unreachable with sane params
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{arb_edge, Cases};

    #[test]
    fn insert_delete_roundtrip() {
        let mut m = AdjacencyMatrix::new(16);
        m.apply(&Update::insert(2, 7));
        assert!(m.contains(2, 7));
        assert!(m.contains(7, 2));
        assert_eq!(m.num_edges(), 1);
        m.apply(&Update::delete(7, 2));
        assert!(!m.contains(2, 7));
        assert_eq!(m.num_edges(), 0);
    }

    #[test]
    fn pair_indices_are_unique_and_dense() {
        let v = 40u64;
        let m = AdjacencyMatrix::new(v);
        let mut seen = std::collections::HashSet::new();
        for a in 0..v as u32 {
            for b in (a + 1)..v as u32 {
                let i = m.pair_index(a, b);
                assert!(i < v * (v - 1) / 2);
                assert!(seen.insert(i), "collision at ({a},{b})");
            }
        }
    }

    #[test]
    fn random_update_sequences_track_reference() {
        Cases::new(20).run(|rng| {
            let v = 4 + rng.next_below(40);
            let mut m = AdjacencyMatrix::new(v);
            let mut reference = std::collections::HashSet::new();
            for _ in 0..rng.next_below(200) {
                let (a, b) = arb_edge(rng, v);
                if reference.contains(&(a, b)) {
                    m.apply(&Update::delete(a, b));
                    reference.remove(&(a, b));
                } else {
                    m.apply(&Update::insert(a, b));
                    reference.insert((a, b));
                }
            }
            assert_eq!(m.num_edges() as usize, reference.len());
            for &(a, b) in &reference {
                assert!(m.contains(a, b));
            }
        });
    }

    #[test]
    fn crossover_is_in_the_papers_regime() {
        let x = sketch_smaller_crossover();
        // paper reports ~310k vertices; our constants differ slightly but
        // the crossover must land in the same order of magnitude
        assert!(x > 50_000 && x < 5_000_000, "crossover {x}");
    }

    #[test]
    fn bytes_are_quadratic() {
        assert!(AdjacencyMatrix::new(1 << 12).bytes() > 4 * AdjacencyMatrix::new(1 << 11).bytes() / 2);
    }
}
