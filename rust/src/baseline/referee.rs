//! Exact streaming referee (App. F.2's correctness oracle): a lossless
//! edge set + DSU recomputation.  Slow and memory-hungry by design —
//! it exists to *check* the sketching system, never to compete with it.

use std::collections::HashSet;

use crate::connectivity::dsu::Dsu;
use crate::stream::update::{Update, UpdateKind};

/// Lossless dynamic-graph referee.
pub struct Referee {
    v: u64,
    edges: HashSet<(u32, u32)>,
}

impl Referee {
    pub fn new(v: u64) -> Self {
        Self {
            v,
            edges: HashSet::new(),
        }
    }

    /// Apply one update, enforcing stream validity (panics on
    /// double-insert / delete-of-absent, which the model forbids).
    pub fn apply(&mut self, upd: &Update) {
        let e = upd.endpoints();
        match upd.kind {
            UpdateKind::Insert => {
                assert!(self.edges.insert(e), "insert of present edge {e:?}");
            }
            UpdateKind::Delete => {
                assert!(self.edges.remove(&e), "delete of absent edge {e:?}");
            }
        }
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> impl Iterator<Item = &(u32, u32)> {
        self.edges.iter()
    }

    /// Exact component map (recomputed per call).
    pub fn component_map(&self) -> Vec<u32> {
        let mut dsu = Dsu::new(self.v as usize);
        for &(a, b) in &self.edges {
            dsu.union(a, b);
        }
        dsu.component_map()
    }

    /// Exact connectivity for a batch of pairs.
    pub fn reachability(&self, pairs: &[(u32, u32)]) -> Vec<bool> {
        let map = self.component_map();
        pairs
            .iter()
            .map(|&(a, b)| map[a as usize] == map[b as usize])
            .collect()
    }

    /// Exact edge connectivity capped at k (via Stoer–Wagner).
    pub fn k_connectivity(&self, k: u64) -> Option<u64> {
        let edges: Vec<(u32, u32)> = self.edges.iter().copied().collect();
        crate::connectivity::mincut::edge_connectivity_capped(self.v as usize, &edges, k)
    }

    /// Do two component maps describe the same partition (up to root
    /// renaming)?  Shared by the correctness benches and tests.
    pub fn same_partition(a: &[u32], b: &[u32]) -> bool {
        if a.len() != b.len() {
            return false;
        }
        let mut fwd = std::collections::HashMap::new();
        let mut bwd = std::collections::HashMap::new();
        for (x, y) in a.iter().zip(b) {
            if *fwd.entry(*x).or_insert(*y) != *y || *bwd.entry(*y).or_insert(*x) != *x {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::dynamify::Dynamify;
    use crate::stream::erdos::ErdosRenyi;
    use crate::stream::{edge_list, EdgeModel as _};

    #[test]
    fn tracks_stream_net_effect() {
        let model = ErdosRenyi::new(64, 0.2, 3);
        let mut referee = Referee::new(64);
        for upd in Dynamify::new(model, 5) {
            referee.apply(&upd);
        }
        let mut got: Vec<(u32, u32)> = referee.edges().copied().collect();
        got.sort_unstable();
        assert_eq!(got, edge_list(&model));
    }

    #[test]
    #[should_panic]
    fn rejects_invalid_stream() {
        let mut referee = Referee::new(8);
        referee.apply(&Update::delete(0, 1)); // deleting an absent edge
    }

    #[test]
    fn same_partition_detects_mismatch() {
        assert!(Referee::same_partition(&[0, 0, 2], &[5, 5, 9]));
        assert!(!Referee::same_partition(&[0, 0, 2], &[5, 6, 9]));
        assert!(!Referee::same_partition(&[0, 1, 2], &[5, 5, 9]));
        assert!(!Referee::same_partition(&[0, 0], &[0, 0, 0]));
    }

    #[test]
    fn reachability_consistent_with_components() {
        let mut referee = Referee::new(8);
        referee.apply(&Update::insert(0, 1));
        referee.apply(&Update::insert(2, 3));
        assert_eq!(
            referee.reachability(&[(0, 1), (1, 2), (2, 3)]),
            vec![true, false, true]
        );
    }
}
