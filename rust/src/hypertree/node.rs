//! Global group nodes: the shared levels of the pipeline hypertree.
//!
//! A group node buffers entries for `group_size` consecutive vertices
//! and owns those vertices' leaves.  One mutex covers both, so a flush
//! from buffer to leaves is a single-lock bulk operation.

/// One global node + its leaves.
pub struct GroupNode {
    /// (dest, idx) entries not yet routed to leaves.
    buffer: Vec<(u32, u32)>,
    /// Per-vertex gutters, indexed by `dest - base`.
    leaves: Vec<Vec<u32>>,
}

impl GroupNode {
    pub fn new(group_size: usize, _leaf_capacity: usize) -> Self {
        Self {
            buffer: Vec::new(),
            leaves: (0..group_size).map(|_| Vec::new()).collect(),
        }
    }

    /// Entries currently buffered (not yet in leaves).
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Bytes held by this node (buffer + leaves) for the space audit.
    pub fn buffered_bytes(&self) -> usize {
        self.buffer.len() * 8
            + self
                .leaves
                .iter()
                .map(|l| l.len() * 4)
                .sum::<usize>()
    }

    /// Bulk-append a run of entries destined for this group.
    pub fn append(&mut self, run: &[(u32, u32)], base: u32) {
        debug_assert!(run
            .iter()
            .all(|&(d, _)| (d - base) < self.leaves.len() as u32));
        self.buffer.extend_from_slice(run);
        let _ = base;
    }

    /// Route all buffered entries into leaves; emit each leaf that
    /// reaches `leaf_capacity` through `emit(vertex, indices)`.
    pub fn flush_to_leaves(
        &mut self,
        base: u32,
        leaf_capacity: usize,
        emit: &mut dyn FnMut(u32, Vec<u32>),
    ) {
        for i in 0..self.buffer.len() {
            let (dest, other) = self.buffer[i];
            let slot = (dest - base) as usize;
            let leaf = &mut self.leaves[slot];
            if leaf.capacity() == 0 {
                leaf.reserve_exact(leaf_capacity);
            }
            leaf.push(other);
            if leaf.len() >= leaf_capacity {
                let full = std::mem::take(leaf);
                emit(dest, full);
            }
        }
        self.buffer.clear();
    }

    /// Drain all leaves (after a [`Self::flush_to_leaves`]).  Leaves with
    /// at least `gamma_threshold` entries ship via `emit_full`; the rest
    /// go through `emit_local` (paper §5.3's hybrid policy).
    pub fn drain_leaves(
        &mut self,
        base: u32,
        gamma_threshold: usize,
        emit_full: &mut dyn FnMut(u32, &[u32]),
        emit_local: &mut dyn FnMut(u32, &[u32]),
    ) {
        for (slot, leaf) in self.leaves.iter_mut().enumerate() {
            if leaf.is_empty() {
                continue;
            }
            let vertex = base + slot as u32;
            if leaf.len() >= gamma_threshold.max(1) {
                emit_full(vertex, leaf);
            } else {
                emit_local(vertex, leaf);
            }
            leaf.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_then_flush_routes_by_vertex() {
        let mut node = GroupNode::new(4, 100);
        node.append(&[(10, 1), (11, 2), (10, 3)], 10);
        let mut emitted = Vec::new();
        node.flush_to_leaves(10, 100, &mut |v, idx| emitted.push((v, idx)));
        assert!(emitted.is_empty(), "capacity not reached");
        let mut full = Vec::new();
        let mut local = Vec::new();
        node.drain_leaves(
            10,
            3,
            &mut |v, idx| full.push((v, idx.to_vec())),
            &mut |v, idx| local.push((v, idx.to_vec())),
        );
        assert!(full.is_empty());
        assert_eq!(local.len(), 2);
        assert!(local.contains(&(10, vec![1, 3])));
        assert!(local.contains(&(11, vec![2])));
    }

    #[test]
    fn leaf_capacity_triggers_emit() {
        let mut node = GroupNode::new(2, 100);
        let entries: Vec<(u32, u32)> = (0..7).map(|i| (0u32, i + 1)).collect();
        node.append(&entries, 0);
        let mut emitted = Vec::new();
        node.flush_to_leaves(0, 3, &mut |v, idx| emitted.push((v, idx)));
        assert_eq!(emitted.len(), 2); // two full leaves of 3; 1 remains
        assert!(emitted.iter().all(|(v, idx)| *v == 0 && idx.len() == 3));
    }

    #[test]
    fn drain_is_idempotent() {
        let mut node = GroupNode::new(2, 10);
        node.append(&[(1, 5)], 0);
        node.flush_to_leaves(0, 10, &mut |_, _| {});
        let count = std::cell::Cell::new(0);
        node.drain_leaves(0, 1, &mut |_, _| count.set(count.get() + 1), &mut |_, _| {
            count.set(count.get() + 1)
        });
        assert_eq!(count.get(), 1);
        count.set(0);
        node.drain_leaves(0, 1, &mut |_, _| count.set(count.get() + 1), &mut |_, _| {
            count.set(count.get() + 1)
        });
        assert_eq!(count.get(), 0);
    }
}
