//! The pipeline hypertree (paper §5.1.2, App. C): a simplified, parallel
//! buffer-tree variant that consolidates arbitrarily ordered stream
//! updates into *vertex-based batches* while minimizing cache misses and
//! thread contention.
//!
//! Topology (mirroring App. E.2's parameters, scaled by config):
//!
//! * **Thread-local levels** — each ingest thread owns a level-0 buffer
//!   and a fan-out of level-1 buckets; no synchronization.
//! * **Global group nodes** — one per `group_size` consecutive vertices,
//!   mutex-protected, each owning its group's **leaves** (one per
//!   vertex).  Entries are appended in bulk, so the amortized cost of
//!   placing one update is far below one cache miss per update.
//! * **Leaves** — per-vertex gutters of `leaf_capacity` edge indices; a
//!   full leaf becomes a [`VertexBatch`] handed to the sink (the work
//!   queue in the full system).
//!
//! `force_flush` implements the γ-fullness hybrid policy of §5.3: leaves
//! at least `γ`-full are emitted as batches for distributed processing,
//! the rest are handed back for local processing on the main node.

pub mod node;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::Metrics;
use crate::sketch::shard::ShardSpec;
use node::GroupNode;

/// A vertex-based batch: all buffered updates incident to `vertex`,
/// each stored as the *other* endpoint only — the edge (vertex, other)
/// is reconstructed by the worker.  4 bytes per update is what keeps
/// the communication factor near the paper's 1.6× (§5.1.1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VertexBatch {
    pub vertex: u32,
    pub others: Vec<u32>,
}

impl VertexBatch {
    /// Wire size of the batch message (vertex + count + endpoints).
    pub fn wire_bytes(&self) -> u64 {
        8 + self.others.len() as u64 * 4
    }
}

/// Where completed batches go.
///
/// Emitters route every batch shard-affine: `shard` is always
/// `self.shards().shard_of(vertex)`, so a sink backed by per-shard
/// queues (the coordinator) hands each batch straight to the distributor
/// thread owning that slice of the sketch store, with no shared-map
/// contention on the merge path.
pub trait BatchSink {
    /// The vertex shard map batches are routed by.  The default
    /// single-shard map sends everything to shard 0 (tests, benches).
    fn shards(&self) -> ShardSpec {
        ShardSpec::SINGLE
    }
    /// A leaf reached capacity (or was ≥γ-full at a force flush).
    fn full_batch(&self, shard: usize, batch: VertexBatch);
    /// An underfull leaf at force-flush time: process locally (§5.3).
    fn local_batch(&self, shard: usize, vertex: u32, others: &[u32]);
}

/// Hypertree shape parameters.
#[derive(Clone, Copy, Debug)]
pub struct HypertreeConfig {
    pub vertices: u64,
    /// Leaf capacity in updates (the vertex-based batch size α·φ).
    pub leaf_capacity: usize,
    /// Level-0 buffer entries per thread.
    pub l0_capacity: usize,
    /// Level-1 fan-out per thread.
    pub l1_fanout: usize,
    /// Level-1 bucket entries.
    pub l1_capacity: usize,
    /// Vertices per global group node.
    pub group_size: usize,
    /// Buffered entries per group node before flushing into leaves.
    pub group_capacity: usize,
}

impl HypertreeConfig {
    /// Defaults scaled from the paper's App. E.2 parameters.
    pub fn for_vertices(vertices: u64, leaf_capacity: usize) -> Self {
        Self {
            vertices,
            leaf_capacity,
            l0_capacity: 1024,
            l1_fanout: 16,
            l1_capacity: 1024,
            group_size: 64,
            group_capacity: 8192,
        }
    }

    fn num_groups(&self) -> usize {
        crate::util::div_ceil(self.vertices as usize, self.group_size)
    }
}

/// The shared (global-level) part of the hypertree.
pub struct Hypertree {
    config: HypertreeConfig,
    groups: Vec<Mutex<GroupNode>>,
    metrics: Arc<Metrics>,
    /// Number of [`LocalIngest`] handles currently alive.
    live_locals: AtomicUsize,
}

impl Hypertree {
    pub fn new(config: HypertreeConfig, metrics: Arc<Metrics>) -> Self {
        let groups = (0..config.num_groups())
            .map(|g| {
                let start = g * config.group_size;
                let size = config
                    .group_size
                    .min(config.vertices as usize - start);
                Mutex::new(GroupNode::new(size, config.leaf_capacity))
            })
            .collect();
        Self {
            config,
            groups,
            metrics,
            live_locals: AtomicUsize::new(0),
        }
    }

    pub fn config(&self) -> &HypertreeConfig {
        &self.config
    }

    /// Create a per-thread ingestion handle.
    pub fn local(self: &Arc<Self>) -> LocalIngest {
        // lint: allow(relaxed-ordering) — diagnostic gauge of live handles; never used to synchronize teardown
        self.live_locals.fetch_add(1, Ordering::Relaxed);
        LocalIngest::new(self.clone())
    }

    /// Number of [`LocalIngest`] handles currently alive.
    pub fn live_locals(&self) -> usize {
        // lint: allow(relaxed-ordering) — diagnostic gauge read; stale values are acceptable by contract
        self.live_locals.load(Ordering::Relaxed)
    }

    /// Total buffered bytes across global nodes + leaves (space audit).
    pub fn buffered_bytes(&self) -> usize {
        self.groups
            .iter()
            .map(|g| g.lock().unwrap().buffered_bytes())
            .sum()
    }

    #[inline]
    fn group_of(&self, dest: u32) -> usize {
        dest as usize / self.config.group_size
    }

    /// Append a run of same-group entries to the group node; cascades
    /// into leaves and emits full batches.
    fn push_group_run<S: BatchSink>(&self, group: usize, run: &[(u32, u32)], sink: &S) {
        let mut node = self.groups[group].lock().unwrap();
        let base = (group * self.config.group_size) as u32;
        Metrics::add(&self.metrics.hypertree_moves, run.len() as u64);
        node.append(run, base);
        if node.buffered() >= self.config.group_capacity {
            self.flush_group_node(&mut node, base, sink);
        }
    }

    fn flush_group_node<S: BatchSink>(&self, node: &mut GroupNode, base: u32, sink: &S) {
        Metrics::add(&self.metrics.hypertree_moves, node.buffered() as u64);
        let spec = sink.shards();
        node.flush_to_leaves(base, self.config.leaf_capacity, &mut |vertex, others| {
            sink.full_batch(spec.shard_of(vertex), VertexBatch { vertex, others });
        });
    }

    /// Force-flush every group node and leaf (the query barrier, §5.3).
    ///
    /// Leaves at least `gamma`-full ship as batches; underfull leaves go
    /// through `sink.local_batch` for main-node processing.
    pub fn force_flush<S: BatchSink>(&self, gamma: f64, sink: &S) {
        let spec = sink.shards();
        for (g, group) in self.groups.iter().enumerate() {
            let base = (g * self.config.group_size) as u32;
            let mut node = group.lock().unwrap();
            self.flush_group_node(&mut node, base, sink);
            node.drain_leaves(
                base,
                (self.config.leaf_capacity as f64 * gamma).ceil() as usize,
                &mut |vertex, others| {
                    sink.full_batch(
                        spec.shard_of(vertex),
                        VertexBatch {
                            vertex,
                            others: others.to_vec(),
                        },
                    );
                },
                &mut |vertex, others| {
                    sink.local_batch(spec.shard_of(vertex), vertex, others);
                },
            );
        }
    }
}

/// Per-thread ingestion handle: the thread-local hypertree levels.
pub struct LocalIngest {
    tree: Arc<Hypertree>,
    l0: Vec<(u32, u32)>,
    l1: Vec<Vec<(u32, u32)>>,
    /// scratch for grouping runs by destination group
    scratch: Vec<(u32, u32)>,
    /// entries currently buffered in l0 + l1 (plain counter read by the
    /// session's per-handle pending gauge through [`Self::buffered`])
    buffered: usize,
}

impl LocalIngest {
    fn new(tree: Arc<Hypertree>) -> Self {
        let l0 = Vec::with_capacity(tree.config.l0_capacity);
        let l1 = (0..tree.config.l1_fanout)
            .map(|_| Vec::with_capacity(tree.config.l1_capacity))
            .collect();
        Self {
            tree,
            l0,
            l1,
            scratch: Vec::new(),
            buffered: 0,
        }
    }

    #[inline]
    fn l1_bucket(&self, dest: u32) -> usize {
        // route by destination so each bucket covers a contiguous range
        (dest as u64 as usize * self.tree.config.l1_fanout)
            / self.tree.config.vertices as usize
    }

    /// Insert one (destination, other-endpoint) entry.
    #[inline]
    pub fn insert<S: BatchSink>(&mut self, dest: u32, other: u32, sink: &S) {
        self.l0.push((dest, other));
        self.buffered += 1;
        if self.l0.len() >= self.tree.config.l0_capacity {
            self.flush_l0(sink);
        }
    }

    /// Entries currently buffered in this handle's thread-local levels
    /// (invisible to queries until [`Self::flush`]).
    pub fn buffered(&self) -> usize {
        self.buffered
    }

    fn flush_l0<S: BatchSink>(&mut self, sink: &S) {
        Metrics::add(&self.tree.metrics.hypertree_moves, self.l0.len() as u64);
        // move entries into their level-1 bucket; flush buckets that fill
        let cap = self.tree.config.l1_capacity;
        for i in 0..self.l0.len() {
            let (dest, other) = self.l0[i];
            let b = self.l1_bucket(dest);
            self.l1[b].push((dest, other));
            if self.l1[b].len() >= cap {
                self.flush_l1_bucket(b, sink);
            }
        }
        self.l0.clear();
    }

    fn flush_l1_bucket<S: BatchSink>(&mut self, bucket: usize, sink: &S) {
        // group entries by destination group, then push each run with a
        // single lock acquisition per group
        self.scratch.clear();
        self.scratch.append(&mut self.l1[bucket]);
        self.buffered -= self.scratch.len();
        let gs = self.tree.config.group_size as u32;
        self.scratch.sort_unstable_by_key(|&(d, _)| d / gs);
        let mut start = 0;
        while start < self.scratch.len() {
            let group = self.tree.group_of(self.scratch[start].0);
            let mut end = start + 1;
            while end < self.scratch.len() && self.tree.group_of(self.scratch[end].0) == group
            {
                end += 1;
            }
            self.tree
                .push_group_run(group, &self.scratch[start..end], sink);
            start = end;
        }
    }

    /// Drain every thread-local buffer into the global levels.
    pub fn flush<S: BatchSink>(&mut self, sink: &S) {
        self.flush_l0(sink);
        for b in 0..self.l1.len() {
            if !self.l1[b].is_empty() {
                self.flush_l1_bucket(b, sink);
            }
        }
        debug_assert_eq!(self.buffered, 0, "flush left entries behind");
    }
}

impl Drop for LocalIngest {
    fn drop(&mut self) {
        // a handle must be flushed before it goes away — `Drop` has no
        // sink to flush into, so anything still buffered is lost
        if self.buffered > 0 {
            crate::log_warn!(
                "hypertree: LocalIngest dropped with {} unflushed entries \
                 (call flush() before dropping the handle)",
                self.buffered
            );
        }
        // lint: allow(relaxed-ordering) — diagnostic gauge of live handles; never used to synchronize teardown
        self.tree.live_locals.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    /// Collects everything for assertions, checking shard routing.
    struct Collect {
        spec: ShardSpec,
        full: StdMutex<Vec<VertexBatch>>,
        local: StdMutex<Vec<(u32, Vec<u32>)>>,
    }

    impl Default for Collect {
        fn default() -> Self {
            Self::with_shards(ShardSpec::SINGLE)
        }
    }

    impl Collect {
        fn with_shards(spec: ShardSpec) -> Self {
            Self {
                spec,
                full: StdMutex::new(Vec::new()),
                local: StdMutex::new(Vec::new()),
            }
        }
    }

    impl BatchSink for Collect {
        fn shards(&self) -> ShardSpec {
            self.spec
        }
        fn full_batch(&self, shard: usize, batch: VertexBatch) {
            assert_eq!(shard, self.spec.shard_of(batch.vertex), "misrouted batch");
            self.full.lock().unwrap().push(batch);
        }
        fn local_batch(&self, shard: usize, vertex: u32, others: &[u32]) {
            assert_eq!(shard, self.spec.shard_of(vertex), "misrouted local batch");
            self.local
                .lock()
                .unwrap()
                .push((vertex, others.to_vec()));
        }
    }

    fn tree(v: u64, leaf_cap: usize) -> Arc<Hypertree> {
        let mut cfg = HypertreeConfig::for_vertices(v, leaf_cap);
        // small internal buffers so tests exercise the cascades
        cfg.l0_capacity = 8;
        cfg.l1_capacity = 16;
        cfg.group_capacity = 32;
        cfg.group_size = 16;
        Arc::new(Hypertree::new(cfg, Arc::new(Metrics::new())))
    }

    #[test]
    fn nothing_lost_between_insert_and_flush() {
        let t = tree(64, 10);
        let sink = Collect::default();
        let mut local = t.local();
        let mut want: Vec<(u32, u32)> = Vec::new();
        for i in 0..500u32 {
            let dest = i % 64;
            let other = i + 1;
            local.insert(dest, other, &sink);
            want.push((dest, other));
        }
        local.flush(&sink);
        t.force_flush(0.0, &sink); // gamma 0: everything ships as batches

        let mut got: Vec<(u32, u32)> = Vec::new();
        for b in sink.full.lock().unwrap().iter() {
            for &other in &b.others {
                got.push((b.vertex, other));
            }
        }
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn full_leaves_emit_batches_of_capacity() {
        let t = tree(64, 10);
        let sink = Collect::default();
        let mut local = t.local();
        // 35 updates for vertex 3: expect 3 full batches of 10 + 5 leftover
        for i in 0..35u32 {
            local.insert(3, i + 1, &sink);
        }
        local.flush(&sink);
        t.force_flush(1.0, &sink); // gamma 1.0: leftovers go local
        let full = sink.full.lock().unwrap();
        assert_eq!(full.len(), 3);
        assert!(full.iter().all(|b| b.vertex == 3 && b.others.len() == 10));
        let local_out = sink.local.lock().unwrap();
        assert_eq!(local_out.len(), 1);
        assert_eq!(local_out[0].1.len(), 5);
    }

    #[test]
    fn gamma_policy_splits_by_fullness() {
        let t = tree(64, 10);
        let sink = Collect::default();
        let mut local = t.local();
        // vertex 1: 6 updates (>= 50% full), vertex 2: 2 updates (< 50%)
        for i in 0..6u32 {
            local.insert(1, 100 + i, &sink);
        }
        for i in 0..2u32 {
            local.insert(2, 200 + i, &sink);
        }
        local.flush(&sink);
        t.force_flush(0.5, &sink);
        let full = sink.full.lock().unwrap();
        let local_out = sink.local.lock().unwrap();
        assert_eq!(full.len(), 1);
        assert_eq!(full[0].vertex, 1);
        assert_eq!(local_out.len(), 1);
        assert_eq!(local_out[0].0, 2);
    }

    #[test]
    fn multithreaded_ingest_loses_nothing() {
        let t = tree(256, 32);
        let sink = Arc::new(Collect::default());
        let threads = 4;
        let per_thread = 5_000u64;
        let mut handles = Vec::new();
        for tid in 0..threads {
            let t2 = t.clone();
            let s2 = sink.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = t2.local();
                for i in 0..per_thread {
                    let dest = ((tid * per_thread + i) % 256) as u32;
                    local.insert(dest, (tid * per_thread + i + 1) as u32, &*s2);
                }
                local.flush(&*s2);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        t.force_flush(0.0, &*sink);
        let total: usize = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.others.len())
            .sum();
        assert_eq!(total as u64, threads * per_thread);
    }

    #[test]
    fn batches_route_shard_affine() {
        // Collect asserts shard == shards().shard_of(vertex) on every
        // emission, so this exercises routing on both flush paths.
        let t = tree(64, 8);
        let sink = Collect::with_shards(ShardSpec::new(4));
        let mut local = t.local();
        for i in 0..1000u32 {
            local.insert(i % 64, i + 1, &sink);
        }
        local.flush(&sink);
        t.force_flush(0.5, &sink);
        let total: usize = sink
            .full
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.others.len())
            .sum::<usize>()
            + sink
                .local
                .lock()
                .unwrap()
                .iter()
                .map(|(_, o)| o.len())
                .sum::<usize>();
        assert_eq!(total, 1000);
    }

    #[test]
    fn batches_only_contain_their_vertex() {
        let t = tree(64, 8);
        let sink = Collect::default();
        let mut local = t.local();
        for i in 0..1000u32 {
            local.insert(i % 61, i + 1, &sink);
        }
        local.flush(&sink);
        t.force_flush(0.0, &sink);
        // values were assigned round-robin: other-1 mod 61 == vertex
        for b in sink.full.lock().unwrap().iter() {
            for &other in &b.others {
                assert_eq!((other - 1) % 61, b.vertex);
            }
        }
    }

    #[test]
    fn local_handle_and_buffered_accounting() {
        let t = tree(64, 10);
        assert_eq!(t.live_locals(), 0);
        let sink = Collect::default();
        let mut a = t.local();
        let mut b = t.local();
        assert_eq!(t.live_locals(), 2);
        a.insert(1, 2, &sink);
        assert_eq!(a.buffered(), 1);
        b.insert(3, 4, &sink);
        assert_eq!(b.buffered(), 1);
        a.flush(&sink);
        assert_eq!(a.buffered(), 0, "flush drains the local levels");
        assert_eq!(b.buffered(), 1, "b is untouched by a's flush");
        b.flush(&sink);
        assert_eq!(b.buffered(), 0);
        drop(b);
        drop(a);
        assert_eq!(t.live_locals(), 0);
    }

    #[test]
    fn wire_bytes_accounting() {
        let b = VertexBatch {
            vertex: 1,
            others: vec![1, 2, 3],
        };
        assert_eq!(b.wire_bytes(), 8 + 12);
    }

    #[test]
    fn moves_per_update_is_logarithmic_not_linear() {
        // amortized moves/update should be a small constant (~tree depth)
        let t = tree(256, 64);
        let sink = Collect::default();
        let mut local = t.local();
        let n = 50_000u64;
        for i in 0..n {
            local.insert((i % 256) as u32, (i + 1) as u32, &sink);
        }
        local.flush(&sink);
        t.force_flush(0.0, &sink);
        let moves = t.metrics.hypertree_moves.load(Ordering::Relaxed);
        let per_update = moves as f64 / n as f64;
        assert!(
            per_update < 6.0,
            "moves per update {per_update} (expected ~tree depth)"
        );
    }
}
