//! Union-find with path compression + union by rank — O(α(V)) amortized
//! per op (Tarjan & van Leeuwen), used by sketch-Borůvka, GreedyCC, and
//! the correctness referee.

/// Disjoint-set union over `0..n`.
#[derive(Clone, Debug)]
pub struct Dsu {
    parent: Vec<u32>,
    rank: Vec<u8>,
    components: usize,
}

impl Dsu {
    /// `n` singleton sets.
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).collect(),
            rank: vec![0; n],
            components: n,
        }
    }

    /// `n` elements pre-unioned over an edge list — the warm-start
    /// constructor (e.g. contracting a surviving spanning forest before
    /// a partial sketch-Borůvka run).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut d = Self::new(n);
        for &(a, b) in edges {
            d.union(a, b);
        }
        d
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Current number of disjoint sets.
    pub fn num_components(&self) -> usize {
        self.components
    }

    /// Find with iterative two-pass path compression.
    pub fn find(&mut self, x: u32) -> u32 {
        let mut root = x;
        while self.parent[root as usize] != root {
            root = self.parent[root as usize];
        }
        let mut cur = x;
        while self.parent[cur as usize] != root {
            let next = self.parent[cur as usize];
            self.parent[cur as usize] = root;
            cur = next;
        }
        root
    }

    /// Find without mutation (no compression) — for read-only contexts.
    pub fn find_const(&self, x: u32) -> u32 {
        let mut root = x;
        while self.parent[root as usize] != root {
            root = self.parent[root as usize];
        }
        root
    }

    /// Union the sets of `a` and `b`; returns true if they were distinct.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra as usize] >= self.rank[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo as usize] = hi;
        if self.rank[hi as usize] == self.rank[lo as usize] {
            self.rank[hi as usize] += 1;
        }
        self.components -= 1;
        true
    }

    /// Are `a` and `b` in the same set?
    pub fn connected(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    /// Component representative per element (compressed).
    pub fn component_map(&mut self) -> Vec<u32> {
        (0..self.parent.len() as u32).map(|i| self.find(i)).collect()
    }

    /// All current roots.
    pub fn roots(&mut self) -> Vec<u32> {
        let mut r: Vec<u32> = (0..self.parent.len() as u32)
            .filter(|&i| self.find(i) == i)
            .collect();
        r.sort_unstable();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{arb_edge, Cases};

    #[test]
    fn singletons_initially() {
        let mut d = Dsu::new(5);
        assert_eq!(d.num_components(), 5);
        for i in 0..5 {
            assert_eq!(d.find(i), i);
        }
    }

    #[test]
    fn union_reduces_components() {
        let mut d = Dsu::new(4);
        assert!(d.union(0, 1));
        assert!(!d.union(1, 0));
        assert!(d.union(2, 3));
        assert!(d.union(0, 3));
        assert_eq!(d.num_components(), 1);
        assert!(d.connected(1, 2));
    }

    #[test]
    fn component_map_is_consistent() {
        let mut d = Dsu::new(6);
        d.union(0, 1);
        d.union(2, 3);
        d.union(3, 4);
        let m = d.component_map();
        assert_eq!(m[0], m[1]);
        assert_eq!(m[2], m[3]);
        assert_eq!(m[3], m[4]);
        assert_ne!(m[0], m[2]);
        assert_ne!(m[5], m[0]);
    }

    #[test]
    fn matches_naive_reference() {
        // property: DSU connectivity == BFS connectivity on random graphs
        Cases::new(40).run(|rng| {
            let v = 2 + rng.next_below(40);
            let n_edges = rng.next_below(60) as usize;
            let mut dsu = Dsu::new(v as usize);
            let mut adj = vec![Vec::new(); v as usize];
            for _ in 0..n_edges {
                let (a, b) = arb_edge(rng, v);
                dsu.union(a, b);
                adj[a as usize].push(b);
                adj[b as usize].push(a);
            }
            // BFS reference from vertex 0
            let mut seen = vec![false; v as usize];
            let mut queue = std::collections::VecDeque::from([0u32]);
            seen[0] = true;
            while let Some(x) = queue.pop_front() {
                for &y in &adj[x as usize] {
                    if !seen[y as usize] {
                        seen[y as usize] = true;
                        queue.push_back(y);
                    }
                }
            }
            for i in 0..v as u32 {
                assert_eq!(dsu.connected(0, i), seen[i as usize]);
            }
        });
    }

    #[test]
    fn from_edges_matches_incremental_unions() {
        let mut a = Dsu::from_edges(6, &[(0, 1), (1, 2), (4, 5)]);
        let mut b = Dsu::new(6);
        b.union(0, 1);
        b.union(1, 2);
        b.union(4, 5);
        assert_eq!(a.component_map(), b.component_map());
        assert_eq!(a.num_components(), 3);
    }

    #[test]
    fn find_const_agrees_with_find() {
        let mut d = Dsu::new(10);
        d.union(1, 2);
        d.union(2, 9);
        assert_eq!(d.find_const(9), d.find(9));
        assert_eq!(d.find_const(1), d.find(2));
    }

    #[test]
    fn roots_enumerates_components() {
        let mut d = Dsu::new(5);
        d.union(0, 1);
        d.union(3, 4);
        assert_eq!(d.roots().len(), 3);
    }
}
