//! Borůvka's algorithm over vertex sketches (paper §4, App. A).
//!
//! Round r queries sketch level r: every current component X aggregates
//! its members' level-r sketches (S(f_X) = Σ_{u∈X} S(f_u), under which
//! intra-component edges cancel — the XOR trick of App. A), samples one
//! crossing edge, and merges.  Each level is used at most once so the
//! per-round randomness is fresh, which is what the O(log V) level count
//! is for.

use crate::connectivity::dsu::Dsu;
use crate::connectivity::SpanningForest;
use crate::sketch::params::decode_edge;
use crate::sketch::{CameoSketch, SketchStore};

/// Outcome of a sketch-Borůvka run.
#[derive(Clone, Debug)]
pub struct ConnectivityResult {
    /// The sampled spanning forest.
    pub forest: SpanningForest,
    /// Rounds actually executed (≤ sketch levels).
    pub rounds: u32,
    /// Components whose sketch query failed in some round (diagnostic;
    /// a component can still be completed in a later round).
    pub failed_queries: u64,
}

impl ConnectivityResult {
    pub fn num_components(&self) -> usize {
        self.forest.num_components()
    }
}

/// Compute a spanning forest of the sketched graph from scratch: fresh
/// DSU, every vertex active.
pub fn boruvka_components(store: &SketchStore) -> ConnectivityResult {
    let v = store.params().v as usize;
    let active: Vec<u32> = (0..v as u32).collect();
    boruvka_components_from(store, Dsu::new(v), Vec::new(), &active)
}

/// Warm-started sketch-Borůvka — the partial-query tier.
///
/// `dsu` carries the already-known component structure (e.g. a surviving
/// spanning forest contracted into supernodes) and `forest_edges` the
/// real edges backing it; both are folded into the result.  Rounds
/// aggregate level slices **only for the vertices in `active`**, so the
/// per-round cost scales with the dirty region instead of V.  This is
/// sound whenever the inactive components are exact connected components
/// of the graph (no crossing edges) — their aggregates would be zero, so
/// skipping them changes nothing.
///
/// Round-exit rule: a round that merges nothing only terminates the
/// algorithm if no component *failed* a query on a nonzero aggregate.
/// Failed queries are retried at the next level, whose randomness is
/// fresh — breaking on the first all-failed round (the seed behaviour)
/// abandons components that later levels would still connect.
pub fn boruvka_components_from(
    store: &SketchStore,
    mut dsu: Dsu,
    mut forest_edges: Vec<(u32, u32)>,
    active: &[u32],
) -> ConnectivityResult {
    let params = *store.params();
    let v = params.v as usize;
    let wpl = params.words_per_level();
    let mut failed_queries = 0u64;
    let mut rounds = 0u32;

    // scratch: one aggregate buffer per active component root, reused
    // per round
    let mut agg: Vec<u64> = Vec::new();
    let mut slot_of_root: Vec<u32> = vec![u32::MAX; v];

    // Hybrid exact pre-pass (arXiv 2605.15173): cold vertices expose
    // their exact edge sets, which are unioned directly — no ℓ₀ decode,
    // no failure probability.  After this pass every crossing edge with
    // at least one exact endpoint is already merged, so the sketch
    // rounds below only ever need to sample promoted↔promoted edges.
    // Dense-mode stores report no exact vertices and skip this entirely.
    let mut exact_buf: Vec<u64> = Vec::new();
    for &u in active {
        exact_buf.clear();
        if store.exact_indices_into(u, &mut exact_buf) {
            for &idx in &exact_buf {
                let (a, b) = decode_edge(idx, params.v);
                if dsu.union(a, b) {
                    forest_edges.push((a.min(b), a.max(b)));
                }
            }
        }
    }

    for level in 0..params.levels {
        if active.is_empty() || dsu.num_components() == 1 {
            break;
        }
        rounds = level + 1;
        // group active members by root and XOR-aggregate their slices
        let mut roots: Vec<u32> = Vec::new();
        for &u in active {
            let r = dsu.find(u);
            if slot_of_root[r as usize] == u32::MAX {
                slot_of_root[r as usize] = roots.len() as u32;
                roots.push(r);
            }
        }
        agg.clear();
        agg.resize(roots.len() * wpl, 0);
        for &u in active {
            let slot = slot_of_root[dsu.find(u) as usize] as usize;
            let agg_slice = &mut agg[slot * wpl..(slot + 1) * wpl];
            exact_buf.clear();
            if store.exact_indices_into(u, &mut exact_buf) {
                // compensation: an exact vertex stores no sketch words,
                // so apply its edges' level contributions here.  The
                // aggregate then equals the textbook cut sketch —
                // promoted↔exact edges internal to this supernode cancel
                // against the promoted endpoint's stored copy, and no
                // crossing edge survives with an exact endpoint (the
                // pre-pass merged those), so what remains is exactly
                // the promoted↔promoted cut.
                for &idx in &exact_buf {
                    CameoSketch::apply_update_level(
                        agg_slice,
                        &params,
                        store.seeds(),
                        level,
                        idx,
                    );
                }
            } else {
                store.xor_level_into(u, level, agg_slice);
            }
        }

        // sample one crossing edge per component
        let mut merged_any = false;
        let mut failed_live = false;
        for slot in 0..roots.len() {
            let buf = &agg[slot * wpl..(slot + 1) * wpl];
            let nonzero = buf.iter().any(|&w| w != 0);
            if !nonzero {
                continue; // isolated component: no crossing edges remain
            }
            match CameoSketch::query_level(buf, &params, store.seeds(), level) {
                Some(idx) => {
                    let (a, b) = decode_edge(idx, params.v);
                    if dsu.union(a, b) {
                        forest_edges.push((a.min(b), a.max(b)));
                        merged_any = true;
                    }
                }
                None => {
                    // nonzero aggregate but no decodable bucket: the
                    // component still has crossing edges — retry at the
                    // next level
                    failed_queries += 1;
                    failed_live = true;
                }
            }
        }

        // reset root slots for the next round
        for r in &roots {
            slot_of_root[*r as usize] = u32::MAX;
        }

        if !merged_any && !failed_live {
            break; // every active component's aggregate was zero: done
        }
    }

    ConnectivityResult {
        forest: SpanningForest {
            edges: forest_edges,
            component: dsu.component_map(),
        },
        rounds,
        failed_queries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::{encode_edge, SketchParams};
    use crate::util::testkit::{arb_edge_set, Cases};

    /// Build a store holding the given edge set (each edge applied to
    /// both endpoint sketches, as ingestion does).
    fn store_with_edges(v: u64, seed: u64, edges: &[(u32, u32)]) -> SketchStore {
        let s = SketchStore::new(SketchParams::for_vertices(v), seed);
        for &(a, b) in edges {
            let idx = encode_edge(a, b, v);
            s.apply_local(a, idx);
            s.apply_local(b, idx);
        }
        s
    }

    /// DSU reference components.
    fn ref_components(v: u64, edges: &[(u32, u32)]) -> Vec<u32> {
        let mut d = Dsu::new(v as usize);
        for &(a, b) in edges {
            d.union(a, b);
        }
        d.component_map()
    }

    fn same_partition(a: &[u32], b: &[u32]) -> bool {
        // component maps equal up to renaming
        let mut fwd = std::collections::HashMap::new();
        let mut bwd = std::collections::HashMap::new();
        for (x, y) in a.iter().zip(b) {
            if *fwd.entry(*x).or_insert(*y) != *y {
                return false;
            }
            if *bwd.entry(*y).or_insert(*x) != *x {
                return false;
            }
        }
        true
    }

    #[test]
    fn empty_graph_all_singletons() {
        let s = store_with_edges(16, 1, &[]);
        let r = boruvka_components(&s);
        assert_eq!(r.num_components(), 16);
        assert!(r.forest.edges.is_empty());
    }

    #[test]
    fn single_edge() {
        let s = store_with_edges(8, 2, &[(2, 5)]);
        let r = boruvka_components(&s);
        assert_eq!(r.num_components(), 7);
        assert_eq!(r.forest.edges, vec![(2, 5)]);
    }

    #[test]
    fn path_graph_connects_fully() {
        let v = 64u64;
        let edges: Vec<(u32, u32)> = (0..63).map(|i| (i, i + 1)).collect();
        let s = store_with_edges(v, 3, &edges);
        let r = boruvka_components(&s);
        assert_eq!(r.num_components(), 1, "failed queries: {}", r.failed_queries);
        assert_eq!(r.forest.edges.len(), 63);
    }

    #[test]
    fn two_cliques_stay_separate() {
        let v = 20u64;
        let mut edges = Vec::new();
        for a in 0..8u32 {
            for b in (a + 1)..8 {
                edges.push((a, b));
            }
        }
        for a in 10..16u32 {
            for b in (a + 1)..16 {
                edges.push((a, b));
            }
        }
        let s = store_with_edges(v, 4, &edges);
        let r = boruvka_components(&s);
        let want = ref_components(v, &edges);
        assert!(same_partition(&r.forest.component, &want));
    }

    #[test]
    fn random_graphs_match_reference() {
        Cases::new(25).run(|rng| {
            let v = 4 + rng.next_below(96);
            let edges = arb_edge_set(rng, v, 200);
            let s = store_with_edges(v, rng.next_u64(), &edges);
            let r = boruvka_components(&s);
            let want = ref_components(v, &edges);
            assert!(
                same_partition(&r.forest.component, &want),
                "V={v} |E|={} failed_queries={}",
                edges.len(),
                r.failed_queries
            );
            // forest must be spanning: edge count = V - #components
            assert_eq!(
                r.forest.edges.len(),
                v as usize - r.num_components()
            );
        });
    }

    #[test]
    fn forest_edges_are_real_edges() {
        Cases::new(15).run(|rng| {
            let v = 4 + rng.next_below(60);
            let edges = arb_edge_set(rng, v, 120);
            let set: std::collections::HashSet<(u32, u32)> =
                edges.iter().copied().collect();
            let s = store_with_edges(v, rng.next_u64(), &edges);
            let r = boruvka_components(&s);
            for e in &r.forest.edges {
                assert!(set.contains(e), "forest contains phantom edge {e:?}");
            }
        });
    }

    /// Regression for the early-exit bug: a round in which *every* query
    /// fails must not terminate the algorithm — later levels carry fresh
    /// randomness and can still connect the graph.
    ///
    /// The failed round is forced deterministically: XOR garbage into
    /// every level-0 checksum (γ) word of every vertex, so every level-0
    /// bucket fails validation (`checksum(α) ≠ γ`) and round 1 produces
    /// zero merges with nonzero aggregates.  Levels ≥ 1 are untouched.
    #[test]
    fn all_failed_round_does_not_terminate_boruvka() {
        // a star: every leaf has degree 1, so once a round runs on an
        // uncorrupted level, every leaf's query deterministically
        // returns its single incident edge and the graph connects
        let v = 64u64;
        let edges: Vec<(u32, u32)> = (1..64).map(|i| (0, i)).collect();
        let s = store_with_edges(v, 77, &edges);

        let params = *s.params();
        let wpl = params.words_per_level();
        let mut corrupt = vec![0u64; params.words()];
        for w in corrupt.iter_mut().take(wpl).skip(1).step_by(2) {
            *w = 0x5EED_BADC_0FFE_E000;
        }
        for u in 0..v as u32 {
            s.merge_delta(u, &corrupt);
        }
        // level 0 is now unanswerable for every vertex
        for u in 0..v as u32 {
            assert_eq!(s.query_vertex_level(u, 0), None);
        }

        let r = boruvka_components(&s);
        assert!(
            r.rounds >= 2,
            "round 1 fails for every component; the query must go on"
        );
        assert!(r.failed_queries >= v, "every vertex fails at level 0");
        assert_eq!(
            r.num_components(),
            1,
            "round 2 (level 1) must still connect the star"
        );
        assert_eq!(r.forest.edges.len(), 63);
    }

    #[test]
    fn warm_start_resolves_only_the_dirty_region() {
        // two paths: 0..7 (clean) and 8..15 with edge (11,12) deleted —
        // the graph holds both sub-paths but the warm-start forest lost
        // the edge, so Borůvka must rediscover it from the sketches
        let v = 16u64;
        let mut edges: Vec<(u32, u32)> = (0..7).map(|i| (i, i + 1)).collect();
        edges.extend((8..15).map(|i| (i, i + 1)));
        let s = store_with_edges(v, 12, &edges);

        let surviving: Vec<(u32, u32)> = edges
            .iter()
            .copied()
            .filter(|&e| e != (11, 12))
            .collect();
        let dsu = Dsu::from_edges(v as usize, &surviving);
        let active: Vec<u32> = (8..16).collect();
        let r = boruvka_components_from(&s, dsu, surviving, &active);

        let want = ref_components(v, &edges);
        assert!(same_partition(&r.forest.component, &want));
        // the rediscovered edge joins the surviving forest
        assert!(r.forest.edges.contains(&(11, 12)));
        assert_eq!(r.forest.edges.len(), 14);
    }

    #[test]
    fn warm_start_with_nothing_active_returns_seed_verbatim() {
        let v = 8u64;
        let edges: Vec<(u32, u32)> = (0..7).map(|i| (i, i + 1)).collect();
        let s = store_with_edges(v, 3, &edges);
        let dsu = Dsu::from_edges(v as usize, &edges);
        let r = boruvka_components_from(&s, dsu, edges.clone(), &[]);
        assert_eq!(r.rounds, 0);
        assert_eq!(r.forest.edges, edges);
        assert_eq!(r.num_components(), 1);
    }

    #[test]
    fn warm_start_matches_cold_start_on_random_graphs() {
        Cases::new(15).run(|rng| {
            let v = 4 + rng.next_below(60);
            let edges = arb_edge_set(rng, v, 120);
            let s = store_with_edges(v, rng.next_u64(), &edges);
            let cold = boruvka_components(&s);
            // warm start with an empty seed and all vertices active is
            // exactly the cold start
            let all: Vec<u32> = (0..v as u32).collect();
            let warm =
                boruvka_components_from(&s, Dsu::new(v as usize), Vec::new(), &all);
            assert_eq!(cold.forest.component, warm.forest.component);
            assert_eq!(cold.forest.edges, warm.forest.edges);
        });
    }

    #[test]
    fn deletions_disconnect() {
        let v = 16u64;
        // build a path 0-1-2-3, then delete the middle edge via re-apply
        let s = store_with_edges(v, 6, &[(0, 1), (1, 2), (2, 3)]);
        let idx = encode_edge(1, 2, v);
        s.apply_local(1, idx);
        s.apply_local(2, idx);
        let r = boruvka_components(&s);
        assert!(r.forest.connected(0, 1));
        assert!(r.forest.connected(2, 3));
        assert!(!r.forest.connected(1, 2));
    }
}
