//! Borůvka's algorithm over vertex sketches (paper §4, App. A).
//!
//! Round r queries sketch level r: every current component X aggregates
//! its members' level-r sketches (S(f_X) = Σ_{u∈X} S(f_u), under which
//! intra-component edges cancel — the XOR trick of App. A), samples one
//! crossing edge, and merges.  Each level is used at most once so the
//! per-round randomness is fresh, which is what the O(log V) level count
//! is for.

use crate::connectivity::dsu::Dsu;
use crate::connectivity::SpanningForest;
use crate::sketch::params::decode_edge;
use crate::sketch::{CameoSketch, SketchStore};

/// Outcome of a sketch-Borůvka run.
#[derive(Clone, Debug)]
pub struct ConnectivityResult {
    /// The sampled spanning forest.
    pub forest: SpanningForest,
    /// Rounds actually executed (≤ sketch levels).
    pub rounds: u32,
    /// Components whose sketch query failed in some round (diagnostic;
    /// a component can still be completed in a later round).
    pub failed_queries: u64,
}

impl ConnectivityResult {
    pub fn num_components(&self) -> usize {
        self.forest.num_components()
    }
}

/// Compute a spanning forest of the sketched graph.
pub fn boruvka_components(store: &SketchStore) -> ConnectivityResult {
    let params = *store.params();
    let v = params.v as usize;
    let wpl = params.words_per_level();
    let mut dsu = Dsu::new(v);
    let mut forest_edges = Vec::new();
    let mut failed_queries = 0u64;
    let mut rounds = 0u32;

    // scratch: one aggregate buffer per component root, reused per round
    let mut agg: Vec<u64> = Vec::new();
    let mut slot_of_root: Vec<u32> = vec![u32::MAX; v];

    for level in 0..params.levels {
        rounds = level + 1;
        // group members by root and XOR-aggregate their level slices
        let mut roots: Vec<u32> = Vec::new();
        for u in 0..v as u32 {
            let r = dsu.find(u);
            if slot_of_root[r as usize] == u32::MAX {
                slot_of_root[r as usize] = roots.len() as u32;
                roots.push(r);
            }
        }
        agg.clear();
        agg.resize(roots.len() * wpl, 0);
        for u in 0..v as u32 {
            let slot = slot_of_root[dsu.find(u) as usize] as usize;
            store.xor_level_into(u, level, &mut agg[slot * wpl..(slot + 1) * wpl]);
        }

        // sample one crossing edge per component
        let mut merged_any = false;
        for (slot, &root) in roots.iter().enumerate() {
            let buf = &agg[slot * wpl..(slot + 1) * wpl];
            let nonzero = buf.iter().any(|&w| w != 0);
            if !nonzero {
                continue; // isolated component: no crossing edges remain
            }
            match CameoSketch::query_level(buf, &params, store.seeds(), level) {
                Some(idx) => {
                    let (a, b) = decode_edge(idx, params.v);
                    if dsu.union(a, b) {
                        forest_edges.push((a.min(b), a.max(b)));
                        merged_any = true;
                    }
                }
                None => {
                    failed_queries += 1;
                    let _ = root;
                }
            }
        }

        // reset root slots for the next round
        for r in &roots {
            slot_of_root[*r as usize] = u32::MAX;
        }

        if !merged_any {
            break; // no component found an outgoing edge this round
        }
        if dsu.num_components() == 1 {
            break;
        }
    }

    ConnectivityResult {
        forest: SpanningForest {
            edges: forest_edges,
            component: dsu.component_map(),
        },
        rounds,
        failed_queries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::params::{encode_edge, SketchParams};
    use crate::util::testkit::{arb_edge_set, Cases};

    /// Build a store holding the given edge set (each edge applied to
    /// both endpoint sketches, as ingestion does).
    fn store_with_edges(v: u64, seed: u64, edges: &[(u32, u32)]) -> SketchStore {
        let s = SketchStore::new(SketchParams::for_vertices(v), seed);
        for &(a, b) in edges {
            let idx = encode_edge(a, b, v);
            s.apply_local(a, idx);
            s.apply_local(b, idx);
        }
        s
    }

    /// DSU reference components.
    fn ref_components(v: u64, edges: &[(u32, u32)]) -> Vec<u32> {
        let mut d = Dsu::new(v as usize);
        for &(a, b) in edges {
            d.union(a, b);
        }
        d.component_map()
    }

    fn same_partition(a: &[u32], b: &[u32]) -> bool {
        // component maps equal up to renaming
        let mut fwd = std::collections::HashMap::new();
        let mut bwd = std::collections::HashMap::new();
        for (x, y) in a.iter().zip(b) {
            if *fwd.entry(*x).or_insert(*y) != *y {
                return false;
            }
            if *bwd.entry(*y).or_insert(*x) != *x {
                return false;
            }
        }
        true
    }

    #[test]
    fn empty_graph_all_singletons() {
        let s = store_with_edges(16, 1, &[]);
        let r = boruvka_components(&s);
        assert_eq!(r.num_components(), 16);
        assert!(r.forest.edges.is_empty());
    }

    #[test]
    fn single_edge() {
        let s = store_with_edges(8, 2, &[(2, 5)]);
        let r = boruvka_components(&s);
        assert_eq!(r.num_components(), 7);
        assert_eq!(r.forest.edges, vec![(2, 5)]);
    }

    #[test]
    fn path_graph_connects_fully() {
        let v = 64u64;
        let edges: Vec<(u32, u32)> = (0..63).map(|i| (i, i + 1)).collect();
        let s = store_with_edges(v, 3, &edges);
        let r = boruvka_components(&s);
        assert_eq!(r.num_components(), 1, "failed queries: {}", r.failed_queries);
        assert_eq!(r.forest.edges.len(), 63);
    }

    #[test]
    fn two_cliques_stay_separate() {
        let v = 20u64;
        let mut edges = Vec::new();
        for a in 0..8u32 {
            for b in (a + 1)..8 {
                edges.push((a, b));
            }
        }
        for a in 10..16u32 {
            for b in (a + 1)..16 {
                edges.push((a, b));
            }
        }
        let s = store_with_edges(v, 4, &edges);
        let r = boruvka_components(&s);
        let want = ref_components(v, &edges);
        assert!(same_partition(&r.forest.component, &want));
    }

    #[test]
    fn random_graphs_match_reference() {
        Cases::new(25).run(|rng| {
            let v = 4 + rng.next_below(96);
            let edges = arb_edge_set(rng, v, 200);
            let s = store_with_edges(v, rng.next_u64(), &edges);
            let r = boruvka_components(&s);
            let want = ref_components(v, &edges);
            assert!(
                same_partition(&r.forest.component, &want),
                "V={v} |E|={} failed_queries={}",
                edges.len(),
                r.failed_queries
            );
            // forest must be spanning: edge count = V - #components
            assert_eq!(
                r.forest.edges.len(),
                v as usize - r.num_components()
            );
        });
    }

    #[test]
    fn forest_edges_are_real_edges() {
        Cases::new(15).run(|rng| {
            let v = 4 + rng.next_below(60);
            let edges = arb_edge_set(rng, v, 120);
            let set: std::collections::HashSet<(u32, u32)> =
                edges.iter().copied().collect();
            let s = store_with_edges(v, rng.next_u64(), &edges);
            let r = boruvka_components(&s);
            for e in &r.forest.edges {
                assert!(set.contains(e), "forest contains phantom edge {e:?}");
            }
        });
    }

    #[test]
    fn deletions_disconnect() {
        let v = 16u64;
        // build a path 0-1-2-3, then delete the middle edge via re-apply
        let s = store_with_edges(v, 6, &[(0, 1), (1, 2), (2, 3)]);
        let idx = encode_edge(1, 2, v);
        s.apply_local(1, idx);
        s.apply_local(2, idx);
        let r = boruvka_components(&s);
        assert!(r.forest.connected(0, 1));
        assert!(r.forest.connected(2, 3));
        assert!(!r.forest.connected(1, 2));
    }
}
