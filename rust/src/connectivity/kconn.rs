//! k-edge-connectivity via sketch certificates (paper §4.1, §5.4).
//!
//! k independent connectivity sketches are maintained in parallel (each
//! with fresh randomness).  At query time forest F_0 is extracted from
//! copy 0, F_0's edges are *deleted* from copies 1..k-1 (sketches are
//! linear — deleting is just re-applying the index), F_1 is extracted
//! from copy 1, and so on.  H = F_0 ∪ … ∪ F_{k-1} is a k-connectivity
//! certificate: H is k'-edge-connected iff G is, for every k' ≤ k.

use crate::connectivity::boruvka::boruvka_components;
use crate::connectivity::mincut;
use crate::sketch::params::{encode_edge, SketchParams};
use crate::sketch::seeds::SketchSeeds;
use crate::sketch::shard::ShardSpec;
use crate::sketch::store::{HybridConfig, TierTransitions};
use crate::sketch::SketchStore;

/// k parallel sketch copies + certificate extraction.
pub struct KConnectivity {
    k: u32,
    stores: Vec<SketchStore>,
}

/// A k-connectivity certificate: the union of k edge-disjoint spanning
/// forests, plus the per-forest breakdown.
#[derive(Clone, Debug)]
pub struct Certificate {
    pub forests: Vec<Vec<(u32, u32)>>,
}

impl Certificate {
    /// All certificate edges (the union H).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut all: Vec<(u32, u32)> = self.forests.iter().flatten().copied().collect();
        all.sort_unstable();
        all
    }
}

impl KConnectivity {
    /// Allocate k independent single-shard sketch copies (k ≥ 1).
    pub fn new(params: SketchParams, graph_seed: u64, k: u32) -> Self {
        Self::with_shards(params, graph_seed, k, ShardSpec::SINGLE)
    }

    /// Allocate k independent sketch copies, each partitioned by `spec`
    /// (the coordinator passes its distributor shard map so every copy
    /// shares the same shard-affine merge routing).
    pub fn with_shards(
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        spec: ShardSpec,
    ) -> Self {
        Self::with_shards_hybrid(params, graph_seed, k, spec, None)
    }

    /// Like [`Self::with_shards`], with the hybrid sparse/dense vertex
    /// tier enabled on every copy when `hybrid` is `Some`.  All copies
    /// share one configuration and see identical toggle sequences, so
    /// their tier states stay mirrored — transition metering can read
    /// copy 0 alone.
    pub fn with_shards_hybrid(
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        spec: ShardSpec,
        hybrid: Option<HybridConfig>,
    ) -> Self {
        assert!(k >= 1);
        let stores = (0..k)
            .map(|copy| {
                SketchStore::with_shards_hybrid(
                    params,
                    SketchSeeds::copy_seed(graph_seed, copy),
                    spec,
                    hybrid,
                )
            })
            .collect();
        Self { k, stores }
    }

    /// Like [`Self::with_shards`], but with every copy running on an
    /// explicit storage backing (the spill tier) — `backings` must
    /// hold exactly `k` entries, one per copy in copy order, each
    /// sized for `params.words()` blocks.  See [`crate::storage`].
    pub fn with_shards_storage(
        params: SketchParams,
        graph_seed: u64,
        k: u32,
        spec: ShardSpec,
        backings: Vec<crate::storage::Backing>,
    ) -> Self {
        assert!(k >= 1);
        assert_eq!(backings.len(), k as usize, "one backing per sketch copy");
        let stores = backings
            .into_iter()
            .enumerate()
            .map(|(copy, backing)| {
                SketchStore::with_backing(
                    params,
                    SketchSeeds::copy_seed(graph_seed, copy as u32),
                    spec,
                    backing,
                )
            })
            .collect();
        Self { k, stores }
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn params(&self) -> &SketchParams {
        self.stores[0].params()
    }

    /// Per-copy stores (the coordinator merges worker deltas into each).
    pub fn stores(&self) -> &[SketchStore] {
        &self.stores
    }

    /// Apply one edge update locally to all k copies (both endpoints).
    ///
    /// This is an **ingest**-path write: in hybrid mode it evaluates
    /// promotion/demotion and reports copy-0's transitions (all copies
    /// mirror each other, so metering one avoids k-fold counting).
    pub fn apply_local(&self, u: u32, v: u32) -> TierTransitions {
        let idx = encode_edge(u, v, self.params().v);
        let mut t = TierTransitions::default();
        for (copy, s) in self.stores.iter().enumerate() {
            let mut ct = s.ingest_index(u, idx);
            ct.absorb(s.ingest_index(v, idx));
            if copy == 0 {
                t = ct;
            }
        }
        t
    }

    /// Total resident bytes across all k copies (k × the connectivity
    /// footprint, Thm 5.4; in hybrid mode, what is actually allocated).
    pub fn bytes(&self) -> usize {
        self.stores.iter().map(|s| s.bytes()).sum()
    }

    /// Resident CAMEO sketch bytes across all k copies.
    pub fn sketch_bytes(&self) -> usize {
        self.stores.iter().map(|s| s.sketch_bytes()).sum()
    }

    /// Resident exact-set bytes across all k copies (hybrid only).
    pub fn exact_bytes(&self) -> usize {
        self.stores.iter().map(|s| s.exact_bytes()).sum()
    }

    /// `(exact, sketched)` vertex counts, read from copy 0 (all copies
    /// mirror each other's tier state).
    pub fn tier_counts(&self) -> (u64, u64) {
        self.stores[0].tier_counts()
    }

    /// Sketch bytes currently resident in memory across all k copies
    /// (spill mode: the bounded hot sets; the gauge source).
    pub fn resident_sketch_bytes(&self) -> u64 {
        self.stores.iter().map(|s| s.resident_sketch_bytes()).sum()
    }

    /// Cold-block faults across all k copies (spill only).
    pub fn block_faults(&self) -> u64 {
        self.stores.iter().map(|s| s.block_faults()).sum()
    }

    /// Bytes written to segment files across all k copies (spill only).
    pub fn spill_bytes_written(&self) -> u64 {
        self.stores.iter().map(|s| s.spill_bytes_written()).sum()
    }

    /// Whether the copies run on the spill backing.
    pub fn is_spill(&self) -> bool {
        self.stores[0].is_spill()
    }

    /// Ticket-retire maintenance for one shard, on every copy (spill:
    /// gutter flush + LRU eviction at a scheduling point).
    pub fn maintain(&self, shard: usize) {
        for s in &self.stores {
            s.maintain(shard);
        }
    }

    /// Persist + fsync every copy's backing state (the segment half of
    /// a durable cut; no-op when resident).
    pub fn checkpoint(&self) -> std::io::Result<()> {
        for s in &self.stores {
            s.checkpoint()?;
        }
        Ok(())
    }

    /// Extract the k-connectivity certificate.
    ///
    /// Mutates copies 1..k-1 by deleting earlier forests' edges, exactly
    /// as the paper's query algorithm does; call once per query (the
    /// stream continues to update all copies afterwards, but the deleted
    /// forest edges must be re-inserted to restore the invariant — see
    /// [`Self::restore_after_query`]).
    pub fn certificate(&self) -> Certificate {
        let v = self.params().v;
        let mut forests: Vec<Vec<(u32, u32)>> = Vec::with_capacity(self.k as usize);
        for copy in 0..self.k as usize {
            // delete all earlier forests' edges from this copy
            for earlier in &forests {
                for &(a, b) in earlier {
                    let idx = encode_edge(a, b, v);
                    self.stores[copy].apply_local(a, idx);
                    self.stores[copy].apply_local(b, idx);
                }
            }
            let result = boruvka_components(&self.stores[copy]);
            forests.push(result.forest.edges);
        }
        Certificate { forests }
    }

    /// Undo the certificate-extraction deletions so the sketches again
    /// reflect the stream (linearity makes this an exact inverse).
    pub fn restore_after_query(&self, cert: &Certificate) {
        let v = self.params().v;
        for copy in 1..self.k as usize {
            for earlier in &cert.forests[..copy] {
                for &(a, b) in earlier {
                    let idx = encode_edge(a, b, v);
                    self.stores[copy].apply_local(a, idx);
                    self.stores[copy].apply_local(b, idx);
                }
            }
        }
    }

    /// Answer Problem 2: `Some(w)` if the min cut w < k, else `None`
    /// ("at least k", the paper's ∞).
    pub fn query_capped_connectivity(&self) -> Option<u64> {
        let cert = self.certificate();
        let edges = cert.edges();
        let out = mincut::edge_connectivity_capped(
            self.params().v as usize,
            &edges,
            self.k as u64,
        );
        self.restore_after_query(&cert);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::Cases;

    fn kconn_with_edges(v: u64, k: u32, seed: u64, edges: &[(u32, u32)]) -> KConnectivity {
        let kc = KConnectivity::new(SketchParams::for_vertices(v), seed, k);
        for &(a, b) in edges {
            kc.apply_local(a, b);
        }
        kc
    }

    #[test]
    fn forests_are_edge_disjoint() {
        let mut edges = Vec::new();
        for a in 0..10u32 {
            for b in (a + 1)..10 {
                edges.push((a, b));
            }
        }
        let kc = kconn_with_edges(10, 3, 5, &edges);
        let cert = kc.certificate();
        let mut seen = std::collections::HashSet::new();
        for f in &cert.forests {
            for e in f {
                assert!(seen.insert(*e), "edge {e:?} appears in two forests");
            }
        }
    }

    #[test]
    fn bridge_detected_below_k() {
        // two K5s joined by one bridge: min cut 1 < k=3
        let mut edges = Vec::new();
        for a in 0..5u32 {
            for b in (a + 1)..5 {
                edges.push((a, b));
                edges.push((a + 5, b + 5));
            }
        }
        edges.push((0, 5));
        let kc = kconn_with_edges(10, 3, 6, &edges);
        assert_eq!(kc.query_capped_connectivity(), Some(1));
    }

    #[test]
    fn dense_graph_reports_at_least_k() {
        // K8 has edge connectivity 7 >= k=3
        let mut edges = Vec::new();
        for a in 0..8u32 {
            for b in (a + 1)..8 {
                edges.push((a, b));
            }
        }
        let kc = kconn_with_edges(8, 3, 7, &edges);
        assert_eq!(kc.query_capped_connectivity(), None);
    }

    #[test]
    fn disconnected_graph_reports_zero() {
        let kc = kconn_with_edges(6, 2, 8, &[(0, 1), (1, 2)]);
        assert_eq!(kc.query_capped_connectivity(), Some(0));
    }

    #[test]
    fn restore_after_query_is_exact() {
        let mut edges = Vec::new();
        for a in 0..8u32 {
            for b in (a + 1)..8 {
                edges.push((a, b));
            }
        }
        let kc = kconn_with_edges(8, 3, 9, &edges);
        let first = kc.query_capped_connectivity();
        // a second query must see identical sketch state
        let second = kc.query_capped_connectivity();
        assert_eq!(first, second);
    }

    #[test]
    fn certificate_preserves_connectivity_capped_at_k() {
        // property (certificate guarantee): min(mincut(H), k) == min(mincut(G), k)
        Cases::new(10).run(|rng| {
            let v = 6 + rng.next_below(5); // 6..10
            let k = 1 + rng.next_below(3) as u32; // 1..3
            let edges = crate::util::testkit::arb_edge_set(rng, v, 40);
            let kc = kconn_with_edges(v, k, rng.next_u64(), &edges);
            let got = kc.query_capped_connectivity();
            let want = mincut::edge_connectivity_capped(v as usize, &edges, k as u64);
            assert_eq!(got, want, "V={v} k={k} edges={edges:?}");
        });
    }

    #[test]
    fn memory_scales_linearly_in_k() {
        let p = SketchParams::for_vertices(64);
        let k1 = KConnectivity::new(p, 1, 1);
        let k4 = KConnectivity::new(p, 1, 4);
        assert_eq!(k4.bytes(), 4 * k1.bytes());
    }

    /// The full certificate query cycle (extract → delete → extract →
    /// restore) over a mixed-tier hybrid store must agree with the dense
    /// path, and repeated queries must see restored state.
    #[test]
    fn hybrid_kconn_matches_dense_certificate() {
        let v = 24u64;
        let p = SketchParams::for_vertices(v);
        // two K6s joined by one bridge: min cut 1 < k=2
        let mut edges = Vec::new();
        for a in 0..6u32 {
            for b in (a + 1)..6 {
                edges.push((a, b));
                edges.push((a + 8, b + 8));
            }
        }
        edges.push((0, 8));
        let dense = KConnectivity::new(p, 5, 2);
        let hybrid = KConnectivity::with_shards_hybrid(
            p,
            5,
            2,
            ShardSpec::SINGLE,
            Some(HybridConfig {
                threshold: 3,
                floor: 1,
            }),
        );
        for &(a, b) in &edges {
            dense.apply_local(a, b);
            hybrid.apply_local(a, b);
        }
        let (exact, sketched) = hybrid.tier_counts();
        assert!(sketched >= 12, "clique members promote, got {exact}/{sketched}");
        assert_eq!(
            dense.query_capped_connectivity(),
            hybrid.query_capped_connectivity()
        );
        // repeated hybrid queries see exactly restored state
        assert_eq!(hybrid.query_capped_connectivity(), Some(1));
    }
}
