//! Stoer–Wagner global minimum cut — the substrate used to evaluate the
//! edge connectivity of a k-connectivity certificate (paper §4.1).
//!
//! O(V·E + V² log V)-ish simple implementation over an adjacency matrix
//! of edge multiplicities; certificates have ≤ k·V edges and the V we
//! run it on is modest, so this is comfortably fast.

/// Compute the global min cut weight of an undirected multigraph given
/// as an edge list (parallel edges allowed).  Returns `None` if the
/// graph is disconnected (cut weight 0 is reported as `Some(0)` only
/// for graphs with ≥ 2 vertices).
pub fn stoer_wagner(num_vertices: usize, edges: &[(u32, u32)]) -> Option<u64> {
    if num_vertices < 2 {
        return None;
    }
    // adjacency weights between current supernodes
    let n = num_vertices;
    let mut w = vec![vec![0u64; n]; n];
    for &(a, b) in edges {
        if a != b {
            w[a as usize][b as usize] += 1;
            w[b as usize][a as usize] += 1;
        }
    }
    let mut active: Vec<usize> = (0..n).collect();
    let mut best = u64::MAX;

    while active.len() > 1 {
        // maximum-adjacency order starting from active[0]
        let mut in_a = vec![false; n];
        let mut weight_to_a = vec![0u64; n];
        let mut order = Vec::with_capacity(active.len());
        for _ in 0..active.len() {
            // pick the most tightly connected remaining vertex
            let mut pick = None;
            let mut pick_w = 0u64;
            for &v in &active {
                if !in_a[v] && (pick.is_none() || weight_to_a[v] > pick_w) {
                    pick = Some(v);
                    pick_w = weight_to_a[v];
                }
            }
            let v = pick.unwrap();
            in_a[v] = true;
            order.push(v);
            for &u in &active {
                if !in_a[u] {
                    weight_to_a[u] += w[v][u];
                }
            }
        }
        let t = *order.last().unwrap();
        let s = order[order.len() - 2];
        // cut-of-the-phase: t alone vs the rest
        let phase_cut: u64 = active.iter().filter(|&&u| u != t).map(|&u| w[t][u]).sum();
        best = best.min(phase_cut);
        // merge t into s
        for &u in &active {
            if u != t && u != s {
                w[s][u] += w[t][u];
                w[u][s] = w[s][u];
            }
        }
        active.retain(|&u| u != t);
    }
    Some(best)
}

/// Edge connectivity capped at `k`: the value the streaming
/// k-connectivity problem (Problem 2) reports.  Returns `min(mincut, k)`
/// semantics: `Some(w)` when w < k, `None` meaning "at least k" (∞ in
/// the paper's formulation).
pub fn edge_connectivity_capped(
    num_vertices: usize,
    edges: &[(u32, u32)],
    k: u64,
) -> Option<u64> {
    match stoer_wagner(num_vertices, edges) {
        Some(w) if w < k => Some(w),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{arb_edge_set, Cases};

    #[test]
    fn single_edge_cut_is_one() {
        assert_eq!(stoer_wagner(2, &[(0, 1)]), Some(1));
    }

    #[test]
    fn disconnected_cut_is_zero() {
        assert_eq!(stoer_wagner(3, &[(0, 1)]), Some(0));
    }

    #[test]
    fn triangle_cut_is_two() {
        assert_eq!(stoer_wagner(3, &[(0, 1), (1, 2), (0, 2)]), Some(2));
    }

    #[test]
    fn parallel_edges_count() {
        assert_eq!(stoer_wagner(2, &[(0, 1), (0, 1), (0, 1)]), Some(3));
    }

    #[test]
    fn complete_graph_k5() {
        let mut edges = Vec::new();
        for a in 0..5u32 {
            for b in (a + 1)..5 {
                edges.push((a, b));
            }
        }
        assert_eq!(stoer_wagner(5, &edges), Some(4));
    }

    #[test]
    fn barbell_cut_is_bridge() {
        // two K4s joined by one edge
        let mut edges = Vec::new();
        for a in 0..4u32 {
            for b in (a + 1)..4 {
                edges.push((a, b));
                edges.push((a + 4, b + 4));
            }
        }
        edges.push((0, 4));
        assert_eq!(stoer_wagner(8, &edges), Some(1));
    }

    #[test]
    fn capped_semantics() {
        let tri = [(0, 1), (1, 2), (0, 2)];
        assert_eq!(edge_connectivity_capped(3, &tri, 3), Some(2));
        assert_eq!(edge_connectivity_capped(3, &tri, 2), None); // >= k
    }

    /// Brute-force min cut over all 2^(V-1) bipartitions for tiny V.
    fn brute_mincut(v: usize, edges: &[(u32, u32)]) -> u64 {
        let mut best = u64::MAX;
        for mask in 1..(1u32 << (v - 1)) {
            // vertex v-1 always on side 0 to halve the space
            let side = |x: u32| -> bool {
                if (x as usize) == v - 1 {
                    false
                } else {
                    (mask >> x) & 1 == 1
                }
            };
            let cut = edges
                .iter()
                .filter(|&&(a, b)| side(a) != side(b))
                .count() as u64;
            best = best.min(cut);
        }
        best
    }

    #[test]
    fn matches_brute_force_on_small_graphs() {
        Cases::new(30).run(|rng| {
            let v = 3 + rng.next_below(6) as usize; // 3..8
            let edges = arb_edge_set(rng, v as u64, 20);
            let got = stoer_wagner(v, &edges).unwrap();
            let want = brute_mincut(v, &edges);
            assert_eq!(got, want, "V={v} edges={edges:?}");
        });
    }
}
