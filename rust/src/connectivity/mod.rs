//! Connectivity machinery: union-find, sketch-Borůvka spanning forests,
//! the GreedyCC query accelerator, and k-edge-connectivity certificates.

pub mod boruvka;
pub mod dsu;
pub mod greedycc;
pub mod kconn;
pub mod mincut;

pub use boruvka::{boruvka_components, boruvka_components_from, ConnectivityResult};
pub use dsu::Dsu;
pub use greedycc::{GreedyCC, PartialSeed};
pub use kconn::KConnectivity;

/// A spanning forest: edges (u, v) with u < v, plus the component map.
#[derive(Clone, Debug, Default)]
pub struct SpanningForest {
    /// Forest edges.
    pub edges: Vec<(u32, u32)>,
    /// Component representative (DSU root) per vertex.
    pub component: Vec<u32>,
}

impl SpanningForest {
    /// Number of distinct components.
    pub fn num_components(&self) -> usize {
        let mut roots: Vec<u32> = self.component.clone();
        roots.sort_unstable();
        roots.dedup();
        roots.len()
    }

    /// Are `u` and `v` connected?
    pub fn connected(&self, u: u32, v: u32) -> bool {
        self.component[u as usize] == self.component[v as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forest_component_queries() {
        let f = SpanningForest {
            edges: vec![(0, 1), (2, 3)],
            component: vec![0, 0, 2, 2, 4],
        };
        assert_eq!(f.num_components(), 3);
        assert!(f.connected(0, 1));
        assert!(!f.connected(1, 2));
        assert!(!f.connected(4, 0));
    }
}
