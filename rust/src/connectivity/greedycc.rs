//! GreedyCC — the query accelerator (paper App. E.4), with
//! *incremental invalidation*.
//!
//! After a full sketch-Borůvka query, Landscape retains the spanning
//! forest in a union-find + a hash set of forest edges.  Subsequent
//! insertions keep it current in O(α(V)); subsequent *global* queries
//! return the forest in O(V) and reachability pairs in O(α(V)) each —
//! the 10²–10⁴× latency win of Fig. 5.
//!
//! Deleting a forest edge destroys information (a replacement edge can
//! only be found in the sketches), but only *locally*: instead of
//! throwing the whole structure away, the component containing the
//! deleted edge is marked **dirty**.  Clean components remain exact —
//! the DSU partition is always a coarsening of true connectivity, every
//! surviving forest edge is a real edge, and a clean component has never
//! lost a forest edge, so it is still connected and (because DSU only
//! ever merges) no current edge can leave it.  Dirty components may have
//! split; resolving them needs a sketch query, but only over the dirty
//! region — the partial tier of the coordinator's `QueryEngine`
//! (`boruvka_components_from`), which warm-starts from
//! [`GreedyCC::partial_seed`].

use std::collections::HashSet;

use crate::connectivity::dsu::Dsu;
use crate::connectivity::SpanningForest;

/// Warm-start state for a partial (dirty-region-only) sketch query: the
/// surviving forest contracted into a fresh DSU, plus the vertices whose
/// components need Borůvka rounds.
#[derive(Clone, Debug)]
pub struct PartialSeed {
    /// Fresh DSU over the *surviving* forest edges: clean components are
    /// fully contracted supernodes; dirty components appear as the
    /// sub-forests left after the deletions.
    pub dsu: Dsu,
    /// Surviving forest edges (all still present in the graph).
    pub forest_edges: Vec<(u32, u32)>,
    /// Vertices belonging to dirty components — the only vertices whose
    /// sketches Borůvka rounds must aggregate.
    pub dirty_vertices: Vec<u32>,
    /// Number of dirty (DSU-root) components being resolved.
    pub dirty_components: usize,
}

/// Reusable prior-query state.
#[derive(Clone, Debug)]
pub struct GreedyCC {
    dsu: Dsu,
    forest_edges: HashSet<(u32, u32)>,
    /// DSU roots of components that may have split (a forest edge inside
    /// them was deleted).  Empty ⇔ the whole partition is exact.
    dirty: HashSet<u32>,
}

impl GreedyCC {
    /// Seed from a freshly computed spanning forest.
    pub fn from_forest(num_vertices: u64, forest: &SpanningForest) -> Self {
        let mut dsu = Dsu::new(num_vertices as usize);
        let mut forest_edges = HashSet::with_capacity(forest.edges.len());
        for &(a, b) in &forest.edges {
            dsu.union(a, b);
            forest_edges.insert((a.min(b), a.max(b)));
        }
        Self {
            dsu,
            forest_edges,
            dirty: HashSet::new(),
        }
    }

    /// Empty-graph GreedyCC (valid from the start of the stream — the
    /// empty graph's forest is trivially known).
    pub fn fresh(num_vertices: u64) -> Self {
        Self {
            dsu: Dsu::new(num_vertices as usize),
            forest_edges: HashSet::new(),
            dirty: HashSet::new(),
        }
    }

    /// Fully exact — no component has lost a forest edge since the last
    /// (re-)seed?
    pub fn is_valid(&self) -> bool {
        self.dirty.is_empty()
    }

    /// Number of components currently marked dirty.
    pub fn dirty_count(&self) -> usize {
        self.dirty.len()
    }

    /// Observe an edge insertion from the stream.
    pub fn on_insert(&mut self, u: u32, v: u32) {
        let (ru, rv) = (self.dsu.find(u), self.dsu.find(v));
        if ru == rv {
            return; // cycle edge: partition unchanged
        }
        // dirtiness is contagious: merging with a possibly-split
        // component yields a possibly-split component
        let tainted = self.dirty.remove(&ru) | self.dirty.remove(&rv);
        self.dsu.union(u, v);
        if tainted {
            self.dirty.insert(self.dsu.find(u));
        }
        // the edge joins the forest: it is a real edge connecting two
        // previously-separate DSU components
        self.forest_edges.insert((u.min(v), u.max(v)));
    }

    /// Observe an edge deletion from the stream.  Deleting a forest edge
    /// marks its component dirty (paper: "GreedyCC does not retain
    /// enough information to find a replacement edge" — but only for
    /// that component).  Returns the number of previously-clean
    /// components that transitioned to dirty (the `dirty_components`
    /// metric; 0, 1, or — for a reordered delete — 2).
    ///
    /// Updates may arrive through concurrent ingest handles whose logs
    /// drain in an order that is *not* a valid serialization of the
    /// original stream: a delete can be observed before the insert it
    /// cancels.  Such a delete reaches neither arm of the fast path —
    /// the edge is not in the forest, and its endpoints may still be in
    /// different DSU components.  Treating it as a no-op would be
    /// unsound: the pending insert would later union the endpoints into
    /// a clean component even though the true graph has no such edge.
    /// Instead both endpoint components are marked dirty; dirtiness is
    /// contagious through [`Self::on_insert`], so when the matching
    /// insert arrives the merged component stays dirty and the next
    /// query resolves it exactly from the sketches.
    pub fn on_delete(&mut self, u: u32, v: u32) -> usize {
        if !self.forest_edges.remove(&(u.min(v), u.max(v))) {
            let (ru, rv) = (self.dsu.find(u), self.dsu.find(v));
            if ru == rv {
                return 0; // cycle-edge deletion: partition unchanged
            }
            // delete observed before its insert (multi-producer log
            // reordering): conservatively dirty both sides
            return self.dirty.insert(ru) as usize + self.dirty.insert(rv) as usize;
        }
        // u and v share a root by construction (the edge was in the forest)
        self.dirty.insert(self.dsu.find(u)) as usize
    }

    /// Global connectivity answer in O(V).  `None` if any component is
    /// dirty — fall through to the partial tier.
    pub fn components(&mut self) -> Option<SpanningForest> {
        if !self.dirty.is_empty() {
            return None;
        }
        let mut edges: Vec<(u32, u32)> = self.forest_edges.iter().copied().collect();
        edges.sort_unstable();
        Some(SpanningForest {
            edges,
            component: self.dsu.component_map(),
        })
    }

    /// Batched reachability in O(α(V)) per pair.  `None` if any queried
    /// pair touches a dirty component (conservative: a dirty component's
    /// DSU answer may be a false positive).
    pub fn reachability(&mut self, pairs: &[(u32, u32)]) -> Option<Vec<bool>> {
        if !self.dirty.is_empty() {
            let touches_dirty = pairs.iter().any(|&(a, b)| {
                let (ra, rb) = (self.dsu.find(a), self.dsu.find(b));
                self.dirty.contains(&ra) || self.dirty.contains(&rb)
            });
            if touches_dirty {
                return None;
            }
            // all queried pairs live in clean (exact) components: the
            // DSU answer is authoritative even while other components
            // are dirty
        }
        Some(
            pairs
                .iter()
                .map(|&(a, b)| self.dsu.connected(a, b))
                .collect(),
        )
    }

    /// Extract the warm-start state for a partial sketch query, or
    /// `None` when nothing is dirty (tier 0 can answer directly).
    ///
    /// The returned DSU is rebuilt from the surviving forest edges, so
    /// each dirty component decomposes into the sub-forests left by the
    /// deletions; every such sub-component's vertices are listed in
    /// `dirty_vertices`.  Clean components contract to supernodes that
    /// Borůvka never has to touch (they have no crossing edges).
    pub fn partial_seed(&mut self) -> Option<PartialSeed> {
        if self.dirty.is_empty() {
            return None;
        }
        let n = self.dsu.len();
        // no sort: consumers only need the edge *set* (XOR aggregation
        // and DSU unions are order-independent), and sorting would put
        // an O(V log V) term on every partial query for nothing
        let forest_edges: Vec<(u32, u32)> =
            self.forest_edges.iter().copied().collect();
        let dsu = Dsu::from_edges(n, &forest_edges);
        let mut dirty_vertices = Vec::new();
        for u in 0..n as u32 {
            if self.dirty.contains(&self.dsu.find(u)) {
                dirty_vertices.push(u);
            }
        }
        Some(PartialSeed {
            dsu,
            forest_edges,
            dirty_components: self.dirty.len(),
            dirty_vertices,
        })
    }

    /// Memory estimate in bytes (the paper's O(V) compactness claim).
    pub fn bytes(&self) -> usize {
        self.dsu.len() * 5 + self.forest_edges.len() * 8 + self.dirty.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{arb_edge, Cases};

    #[test]
    fn fresh_tracks_insertions() {
        let mut g = GreedyCC::fresh(8);
        g.on_insert(0, 1);
        g.on_insert(1, 2);
        let f = g.components().unwrap();
        assert!(f.connected(0, 2));
        assert!(!f.connected(0, 3));
        assert_eq!(f.num_components(), 6);
    }

    #[test]
    fn non_forest_deletion_keeps_validity() {
        let mut g = GreedyCC::fresh(4);
        g.on_insert(0, 1);
        g.on_insert(1, 2);
        g.on_insert(0, 2); // cycle edge: not in forest
        assert_eq!(g.on_delete(0, 2), 0);
        assert!(g.is_valid());
        assert!(g.components().unwrap().connected(0, 2));
    }

    #[test]
    fn forest_deletion_dirties_only_its_component() {
        let mut g = GreedyCC::fresh(6);
        g.on_insert(0, 1);
        g.on_insert(2, 3);
        g.on_insert(4, 5);
        assert_eq!(g.on_delete(0, 1), 1, "first forest delete newly dirties");
        assert!(!g.is_valid());
        assert_eq!(g.dirty_count(), 1);
        assert!(g.components().is_none());
        // pairs entirely inside clean components still answer
        assert_eq!(g.reachability(&[(2, 3), (2, 4)]), Some(vec![true, false]));
        // pairs touching the dirty component do not
        assert!(g.reachability(&[(0, 1)]).is_none());
    }

    #[test]
    fn second_delete_in_same_component_is_not_a_new_transition() {
        let mut g = GreedyCC::fresh(4);
        g.on_insert(0, 1);
        g.on_insert(1, 2);
        assert_eq!(g.on_delete(0, 1), 1);
        assert_eq!(g.on_delete(1, 2), 0, "component already dirty");
        assert_eq!(g.dirty_count(), 1);
    }

    #[test]
    fn dirtiness_is_contagious_through_inserts() {
        let mut g = GreedyCC::fresh(6);
        g.on_insert(0, 1);
        g.on_insert(2, 3);
        g.on_delete(0, 1); // {0,1} dirty
        g.on_insert(1, 2); // merges dirty {0,1} with clean {2,3}
        assert_eq!(g.dirty_count(), 1);
        assert!(g.reachability(&[(2, 3)]).is_none(), "merged component is dirty");
        // untouched singletons remain clean and answerable
        assert_eq!(g.reachability(&[(4, 5)]), Some(vec![false]));
    }

    #[test]
    fn partial_seed_contracts_clean_and_exposes_dirty() {
        let mut g = GreedyCC::fresh(8);
        // clean path component {4,5,6}
        g.on_insert(4, 5);
        g.on_insert(5, 6);
        // dirty component {0,1,2,3}: path 0-1-2-3, delete 1-2
        g.on_insert(0, 1);
        g.on_insert(1, 2);
        g.on_insert(2, 3);
        g.on_delete(1, 2);

        let seed = g.partial_seed().unwrap();
        assert_eq!(seed.dirty_components, 1);
        assert_eq!(seed.dirty_vertices, vec![0, 1, 2, 3]);
        // surviving forest: 0-1, 2-3, 4-5, 5-6 — deleted edge is gone
        // (set comparison: partial_seed does not order its edges)
        let mut got = seed.forest_edges.clone();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (2, 3), (4, 5), (5, 6)]);
        let mut dsu = seed.dsu;
        assert!(dsu.connected(0, 1));
        assert!(!dsu.connected(1, 2), "deleted edge must not be contracted");
        assert!(dsu.connected(4, 6));
    }

    #[test]
    fn partial_seed_none_when_clean() {
        let mut g = GreedyCC::fresh(4);
        g.on_insert(0, 1);
        assert!(g.partial_seed().is_none());
    }

    #[test]
    fn from_forest_matches_forest() {
        let forest = SpanningForest {
            edges: vec![(0, 1), (2, 3)],
            component: vec![0, 0, 2, 2, 4],
        };
        let mut g = GreedyCC::from_forest(5, &forest);
        let r = g.reachability(&[(0, 1), (1, 2), (2, 3), (4, 0)]).unwrap();
        assert_eq!(r, vec![true, false, true, false]);
    }

    #[test]
    fn insert_only_streams_match_dsu_reference() {
        Cases::new(30).run(|rng| {
            let v = 4 + rng.next_below(60);
            let mut g = GreedyCC::fresh(v);
            let mut d = Dsu::new(v as usize);
            for _ in 0..rng.next_below(150) {
                let (a, b) = arb_edge(rng, v);
                g.on_insert(a, b);
                d.union(a, b);
            }
            assert!(g.is_valid());
            let f = g.components().unwrap();
            for i in 0..v as u32 {
                for j in (i + 1)..(v as u32).min(i + 5) {
                    assert_eq!(f.connected(i, j), d.connected(i, j));
                }
            }
        });
    }

    #[test]
    fn clean_components_stay_exact_under_random_dirtying() {
        // property: whatever interleaving of inserts and forest/non-forest
        // deletes, reachability answers (when given) match a from-scratch
        // DSU over the live edge set
        Cases::new(25).run(|rng| {
            let v = 4 + rng.next_below(40);
            let mut g = GreedyCC::fresh(v);
            let mut live = std::collections::BTreeSet::new();
            for _ in 0..rng.next_below(120) {
                if !live.is_empty() && rng.next_below(4) == 0 {
                    let i = rng.next_below(live.len() as u64) as usize;
                    let e: (u32, u32) = *live.iter().nth(i).unwrap();
                    live.remove(&e);
                    g.on_delete(e.0, e.1);
                } else {
                    let e = arb_edge(rng, v);
                    if live.insert(e) {
                        g.on_insert(e.0, e.1);
                    }
                }
            }
            let mut d = Dsu::new(v as usize);
            for &(a, b) in &live {
                d.union(a, b);
            }
            let pairs: Vec<(u32, u32)> =
                (0..8).map(|_| arb_edge(rng, v)).collect();
            if let Some(answers) = g.reachability(&pairs) {
                for (&(a, b), got) in pairs.iter().zip(answers) {
                    assert_eq!(got, d.connected(a, b), "pair ({a},{b})");
                }
            }
        });
    }

    #[test]
    fn delete_before_insert_dirties_both_sides() {
        // a delete observed before its insert (multi-producer log
        // reordering) must not let the later insert build a clean —
        // but false — forest edge
        let mut g = GreedyCC::fresh(4);
        assert_eq!(g.on_delete(0, 1), 2, "both singleton components dirty");
        g.on_insert(0, 1); // the reordered insert arrives
        assert!(!g.is_valid(), "canceled edge must not look clean");
        assert!(g.components().is_none());
        // untouched vertices stay clean and answerable
        assert_eq!(g.reachability(&[(2, 3)]), Some(vec![false]));
    }

    #[test]
    fn arbitrary_reorderings_never_certify_a_wrong_answer() {
        // property: build a valid insert/delete stream, apply it in a
        // random per-update permutation (the multi-producer drain
        // order), and check every reachability answer GreedyCC is
        // willing to give against a DSU over the true final edge set
        Cases::new(25).run(|rng| {
            let v = 4 + rng.next_below(32);
            let mut live = std::collections::BTreeSet::new();
            let mut stream: Vec<(bool, (u32, u32))> = Vec::new();
            for _ in 0..rng.next_below(100) {
                if !live.is_empty() && rng.next_below(3) == 0 {
                    let i = rng.next_below(live.len() as u64) as usize;
                    let e: (u32, u32) = *live.iter().nth(i).unwrap();
                    live.remove(&e);
                    stream.push((false, e));
                } else {
                    let e = arb_edge(rng, v);
                    if live.insert(e) {
                        stream.push((true, e));
                    }
                }
            }
            // random permutation (Fisher–Yates)
            for i in (1..stream.len()).rev() {
                let j = rng.next_below(i as u64 + 1) as usize;
                stream.swap(i, j);
            }
            let mut g = GreedyCC::fresh(v);
            for &(insert, (a, b)) in &stream {
                if insert {
                    g.on_insert(a, b);
                } else {
                    g.on_delete(a, b);
                }
            }
            let mut d = Dsu::new(v as usize);
            for &(a, b) in &live {
                d.union(a, b);
            }
            let pairs: Vec<(u32, u32)> = (0..8).map(|_| arb_edge(rng, v)).collect();
            if let Some(answers) = g.reachability(&pairs) {
                for (&(a, b), got) in pairs.iter().zip(answers) {
                    assert_eq!(got, d.connected(a, b), "pair ({a},{b})");
                }
            }
        });
    }

    #[test]
    fn compact_memory() {
        let mut g = GreedyCC::fresh(1000);
        for i in 0..999 {
            g.on_insert(i, i + 1);
        }
        // O(V): well under sketch sizes (tens of KB per vertex)
        assert!(g.bytes() < 32 * 1000);
    }
}
