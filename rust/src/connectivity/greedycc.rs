//! GreedyCC — the query accelerator (paper App. E.4).
//!
//! After a full sketch-Borůvka query, Landscape retains the spanning
//! forest in a union-find + a hash set of forest edges.  Subsequent
//! insertions keep it current in O(α(V)); subsequent *global* queries
//! return the forest in O(V) and reachability pairs in O(α(V)) each —
//! the 10²–10⁴× latency win of Fig. 5.  Deleting a forest edge destroys
//! the information (a replacement edge can only be found in the
//! sketches), so the structure *invalidates* itself and the next query
//! falls back to Borůvka.

use std::collections::HashSet;

use crate::connectivity::dsu::Dsu;
use crate::connectivity::SpanningForest;

/// Reusable prior-query state.
#[derive(Clone, Debug)]
pub struct GreedyCC {
    dsu: Dsu,
    forest_edges: HashSet<(u32, u32)>,
    valid: bool,
}

impl GreedyCC {
    /// Seed from a freshly computed spanning forest.
    pub fn from_forest(num_vertices: u64, forest: &SpanningForest) -> Self {
        let mut dsu = Dsu::new(num_vertices as usize);
        let mut forest_edges = HashSet::with_capacity(forest.edges.len());
        for &(a, b) in &forest.edges {
            dsu.union(a, b);
            forest_edges.insert((a.min(b), a.max(b)));
        }
        Self {
            dsu,
            forest_edges,
            valid: true,
        }
    }

    /// Empty-graph GreedyCC (valid from the start of the stream — the
    /// empty graph's forest is trivially known).
    pub fn fresh(num_vertices: u64) -> Self {
        Self {
            dsu: Dsu::new(num_vertices as usize),
            forest_edges: HashSet::new(),
            valid: true,
        }
    }

    /// Still usable for answering queries?
    pub fn is_valid(&self) -> bool {
        self.valid
    }

    /// Observe an edge insertion from the stream.
    pub fn on_insert(&mut self, u: u32, v: u32) {
        if !self.valid {
            return;
        }
        if self.dsu.union(u, v) {
            // u,v were in different components: this edge joins the forest
            self.forest_edges.insert((u.min(v), u.max(v)));
        }
    }

    /// Observe an edge deletion from the stream.  Deleting a forest edge
    /// invalidates the structure (paper: "GreedyCC does not retain enough
    /// information to find a replacement edge").
    pub fn on_delete(&mut self, u: u32, v: u32) {
        if !self.valid {
            return;
        }
        if self.forest_edges.contains(&(u.min(v), u.max(v))) {
            self.valid = false;
            self.forest_edges.clear();
        }
    }

    /// Global connectivity answer in O(V).  `None` if invalidated.
    pub fn components(&mut self) -> Option<SpanningForest> {
        if !self.valid {
            return None;
        }
        let mut edges: Vec<(u32, u32)> = self.forest_edges.iter().copied().collect();
        edges.sort_unstable();
        Some(SpanningForest {
            edges,
            component: self.dsu.component_map(),
        })
    }

    /// Batched reachability in O(α(V)) per pair.  `None` if invalidated.
    pub fn reachability(&mut self, pairs: &[(u32, u32)]) -> Option<Vec<bool>> {
        if !self.valid {
            return None;
        }
        Some(
            pairs
                .iter()
                .map(|&(a, b)| self.dsu.connected(a, b))
                .collect(),
        )
    }

    /// Memory estimate in bytes (the paper's O(V) compactness claim).
    pub fn bytes(&self) -> usize {
        self.dsu.len() * 5 + self.forest_edges.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{arb_edge, Cases};

    #[test]
    fn fresh_tracks_insertions() {
        let mut g = GreedyCC::fresh(8);
        g.on_insert(0, 1);
        g.on_insert(1, 2);
        let f = g.components().unwrap();
        assert!(f.connected(0, 2));
        assert!(!f.connected(0, 3));
        assert_eq!(f.num_components(), 6);
    }

    #[test]
    fn non_forest_deletion_keeps_validity() {
        let mut g = GreedyCC::fresh(4);
        g.on_insert(0, 1);
        g.on_insert(1, 2);
        g.on_insert(0, 2); // cycle edge: not in forest
        g.on_delete(0, 2);
        assert!(g.is_valid());
        assert!(g.components().unwrap().connected(0, 2));
    }

    #[test]
    fn forest_deletion_invalidates() {
        let mut g = GreedyCC::fresh(4);
        g.on_insert(0, 1);
        g.on_delete(0, 1);
        assert!(!g.is_valid());
        assert!(g.components().is_none());
        assert!(g.reachability(&[(0, 1)]).is_none());
    }

    #[test]
    fn updates_after_invalidation_are_ignored() {
        let mut g = GreedyCC::fresh(4);
        g.on_insert(0, 1);
        g.on_delete(0, 1);
        g.on_insert(2, 3); // no panic, no effect
        assert!(!g.is_valid());
    }

    #[test]
    fn from_forest_matches_forest() {
        let forest = SpanningForest {
            edges: vec![(0, 1), (2, 3)],
            component: vec![0, 0, 2, 2, 4],
        };
        let mut g = GreedyCC::from_forest(5, &forest);
        let r = g.reachability(&[(0, 1), (1, 2), (2, 3), (4, 0)]).unwrap();
        assert_eq!(r, vec![true, false, true, false]);
    }

    #[test]
    fn insert_only_streams_match_dsu_reference() {
        Cases::new(30).run(|rng| {
            let v = 4 + rng.next_below(60);
            let mut g = GreedyCC::fresh(v);
            let mut d = Dsu::new(v as usize);
            for _ in 0..rng.next_below(150) {
                let (a, b) = arb_edge(rng, v);
                g.on_insert(a, b);
                d.union(a, b);
            }
            assert!(g.is_valid());
            let f = g.components().unwrap();
            for i in 0..v as u32 {
                for j in (i + 1)..(v as u32).min(i + 5) {
                    assert_eq!(f.connected(i, j), d.connected(i, j));
                }
            }
        });
    }

    #[test]
    fn compact_memory() {
        let mut g = GreedyCC::fresh(1000);
        for i in 0..999 {
            g.on_insert(i, i + 1);
        }
        // O(V): well under sketch sizes (tens of KB per vertex)
        assert!(g.bytes() < 32 * 1000);
    }
}
