//! Process-level self-test for `landscape-lint` (see
//! `docs/INVARIANTS.md`).
//!
//! The unit tests inside `rust/src/bin/landscape_lint.rs` exercise the
//! scanner and rules in-process; this test runs the compiled binary the
//! way CI does and checks its exit codes: zero on the clean fixture
//! tree AND on the real `rust/src` (the self-hosting acceptance
//! criterion), nonzero — with the seeded diagnostic on stdout — for
//! each per-rule violation fixture.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("tests")
        .join("lint_fixtures")
}

fn run_lint(root: &Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_landscape_lint"))
        .arg("--root")
        .arg(root)
        .output()
        .expect("spawn landscape_lint")
}

fn assert_flags(tree: &str, rule_tag: &str) {
    let out = run_lint(&fixtures().join(tree));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        !out.status.success(),
        "lint exited 0 on seeded fixture `{tree}`:\n{stdout}"
    );
    assert!(
        stdout.contains(&format!("[{rule_tag}]")),
        "fixture `{tree}` did not report [{rule_tag}]:\n{stdout}"
    );
    assert_eq!(
        stdout.matches(": [").count(),
        1,
        "fixture `{tree}` should seed exactly one violation:\n{stdout}"
    );
}

#[test]
fn clean_fixture_tree_exits_zero() {
    let out = run_lint(&fixtures().join("clean"));
    assert!(
        out.status.success(),
        "lint flagged the clean fixture tree:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}

#[test]
fn real_source_tree_exits_zero() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let out = run_lint(&src);
    assert!(
        out.status.success(),
        "landscape-lint must pass on rust/src (fix the violation or add a \
         justified `// lint: allow` — docs/INVARIANTS.md):\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}

#[test]
fn seeded_violations_exit_nonzero() {
    assert_flags("relaxed_ordering", "relaxed-ordering");
    assert_flags("eprintln", "eprintln");
    assert_flags("hot_path_unwrap", "hot-path-unwrap");
    assert_flags("thread_sleep", "thread-sleep");
    assert_flags("missing_docs", "missing-docs-attr");
}

#[test]
fn unknown_flag_exits_nonzero() {
    let out = Command::new(env!("CARGO_BIN_EXE_landscape_lint"))
        .arg("--no-such-flag")
        .output()
        .expect("spawn landscape_lint");
    assert!(!out.status.success());
}
