//! Crash-recovery and spill-budget integration tests for the
//! external-memory storage tier (`storage/`).
//!
//! The property test drives random valid insert/delete streams through
//! a spilling session, takes one durable cut partway (`flush()`), lets
//! more batches merge *without* a durable mark, and then "crashes" —
//! dropping the session with the post-cut tail living only in the WAL
//! and (partially, via evictions) in the segment files.  Recovery must
//! replay that tail idempotently, the remaining stream is ingested, and
//! the final partition must equal the from-scratch DSU referee with
//! `batches_dropped == 0`.  The companion e2e scenario
//! (`--scenario recovery`) repeats this with a real `process::abort()`.
//!
//! The V = 2^17 test is the acceptance criterion for the resident
//! budget: an ingest touching far more sketch blocks than the budget
//! can hold must keep the `resident_sketch_bytes` gauge at or below
//! the configured bound while faulting and spilling.

use landscape::baseline::Referee;
use landscape::connectivity::dsu::Dsu;
use landscape::session::ConfigError;
use landscape::stream::update::Update;
use landscape::sketch::params::DEFAULT_COLUMNS;
use landscape::util::rng::Xoshiro256;
use landscape::util::testkit::arb_edge;
use landscape::{Landscape, LandscapeBuilder, SketchParams};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "landscape-storage-recovery-{}-{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A valid random insert/delete stream plus its final live edge set.
fn random_stream(rng: &mut Xoshiro256, v: u64, len: usize) -> (Vec<Update>, Vec<(u32, u32)>) {
    let mut live = std::collections::BTreeSet::new();
    let mut stream = Vec::new();
    while stream.len() < len {
        if !live.is_empty() && rng.next_below(3) == 0 {
            let i = rng.next_below(live.len() as u64) as usize;
            let e: (u32, u32) = *live.iter().nth(i).unwrap();
            live.remove(&e);
            stream.push(Update::delete(e.0, e.1));
        } else {
            let e = arb_edge(rng, v);
            if live.insert(e) {
                stream.push(Update::insert(e.0, e.1));
            }
        }
    }
    (stream, live.into_iter().collect())
}

fn ref_partition(v: u64, edges: &[(u32, u32)]) -> Vec<u32> {
    let mut d = Dsu::new(v as usize);
    for &(a, b) in edges {
        d.union(a, b);
    }
    d.component_map()
}

fn spill_builder(v: u64, dir: &std::path::Path, budget: u64) -> LandscapeBuilder {
    Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .update_log_capacity(32)
        .storage_dir(dir)
        .resident_budget_bytes(budget)
}

fn ingest_all(session: &Landscape, updates: &[Update]) {
    let mut h = session.ingest_handle();
    for u in updates {
        h.ingest(*u);
    }
    h.flush();
}

#[test]
fn random_streams_survive_a_crash_at_a_random_batch() {
    let v = 96u64;
    let params = SketchParams::with_columns(v, DEFAULT_COLUMNS);
    // a handful of resident blocks per copy: evictions happen even on
    // these small streams, so recovery mixes checkpointed, evicted, and
    // WAL-tail-only state
    let budget = 8 * (8 + params.words() as u64 * 8);
    let mut rng = Xoshiro256::new(0x5709_4A11);

    for case in 0..6u32 {
        let dir = tmp(&format!("prop-{case}"));
        let (stream, live) = random_stream(&mut rng, v, 120 + case as usize * 40);
        let want = ref_partition(v, &live);
        // durable point d, crash point c, with d <= c <= len
        let d = rng.next_below(stream.len() as u64) as usize;
        let c = d + rng.next_below((stream.len() - d + 1) as u64) as usize;

        let session = spill_builder(v, &dir, budget).build().unwrap();
        ingest_all(&session, &stream[..d]);
        session.flush(); // durable cut: checkpoint + fsync'd marker
        ingest_all(&session, &stream[d..c]);
        // settle the tail so it is merged and WAL-logged, but take NO
        // durable mark — exactly the state a crash leaves behind
        let cut = session.cut();
        session.wait_for(cut);
        assert_eq!(session.metrics().batches_dropped, 0, "case {case}");
        drop(session); // "crash": no final checkpoint runs

        let recovered = spill_builder(v, &dir, budget).recover().unwrap();
        let m = recovered.metrics();
        assert_eq!(m.recoveries, 1, "case {case}");
        // replay the rest of the stream and compare to the referee
        ingest_all(&recovered, &stream[c..]);
        recovered.flush();
        let forest = recovered.query_handle().connected_components();
        assert!(
            Referee::same_partition(&forest.component, &want),
            "case {case}: post-recovery partition diverged from the DSU referee \
             (d = {d}, c = {c}, |stream| = {})",
            stream.len()
        );
        assert_eq!(recovered.metrics().batches_dropped, 0, "case {case}");
        drop(recovered);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn double_crash_replays_idempotently() {
    // crash, recover, then crash again WITHOUT a new durable cut: the
    // second recovery replays the same WAL tail over segments that may
    // already hold some of its effects (evicted during the first
    // recovery's ingest) — the per-block LSN rule must skip those
    let v = 64u64;
    let params = SketchParams::with_columns(v, DEFAULT_COLUMNS);
    let budget = 6 * (8 + params.words() as u64 * 8);
    let dir = tmp("double-crash");
    let mut rng = Xoshiro256::new(0xD0_5E_ED);
    let (stream, live) = random_stream(&mut rng, v, 160);
    let want = ref_partition(v, &live);
    let mid = stream.len() / 2;

    let session = spill_builder(v, &dir, budget).build().unwrap();
    ingest_all(&session, &stream[..mid]);
    let cut = session.cut();
    session.wait_for(cut);
    drop(session); // first crash: nothing was ever durably marked

    let recovered = spill_builder(v, &dir, budget).recover().unwrap();
    ingest_all(&recovered, &stream[mid..]);
    let cut = recovered.cut();
    recovered.wait_for(cut);
    drop(recovered); // second crash, still no durable mark

    let again = spill_builder(v, &dir, budget).recover().unwrap();
    again.flush();
    let forest = again.query_handle().connected_components();
    assert!(
        Referee::same_partition(&forest.component, &want),
        "double-crash recovery diverged from the DSU referee"
    );
    assert_eq!(again.metrics().batches_dropped, 0);
    drop(again);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recover_without_storage_dir_is_a_typed_error() {
    let err = Landscape::builder()
        .vertices(16)
        .recover()
        .err()
        .expect("recover without storage_dir must be rejected");
    assert!(matches!(err, ConfigError::StorageIo(_)), "{err:?}");
}

#[test]
fn v17_ingest_over_budget_respects_the_resident_gauge() {
    // the acceptance criterion: V = 2^17, a stream touching far more
    // sketch blocks than the budget can hold resident
    let v = 1u64 << 17;
    let params = SketchParams::with_columns(v, DEFAULT_COLUMNS);
    let block_bytes = 8 + params.words() as u64 * 8;
    let budget = 192 * block_bytes; // ~192 resident blocks across 2 stripes
    let dir = tmp("v17-budget");

    // a ring over ~1.5k distinct vertices spread across the full 2^17
    // range (plus chords), so thousands of blocks are touched
    let mut updates = Vec::new();
    let n = 1536u64;
    let stride = v / n; // spreads vertices across every segment
    let at = |i: u64| ((i % n) * stride) as u32;
    for i in 0..n {
        updates.push(Update::insert(at(i), at(i + 1)));
    }
    let mut rng = Xoshiro256::new(0x17_B0D6E7);
    for _ in 0..512 {
        let a = rng.next_below(n);
        let b = rng.next_below(n);
        if at(a) != at(b) {
            updates.push(Update::insert(at(a), at(b)));
        }
    }
    let edges: Vec<(u32, u32)> = updates
        .iter()
        .map(|u| (u.u, u.v))
        .collect();
    let want = ref_partition(v, &edges);

    let session = spill_builder(v, &dir, budget).build().unwrap();
    ingest_all(&session, &updates);
    session.flush();
    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0);
    assert!(
        m.resident_sketch_bytes <= budget,
        "resident gauge {} exceeds the budget {budget}",
        m.resident_sketch_bytes
    );
    assert!(
        m.block_faults > 0,
        "an over-budget ingest must fault cold blocks back in"
    );
    assert!(
        m.spill_bytes_written > 0,
        "evictions and gutter flushes must have written through"
    );
    assert!(m.wal_bytes > 0);
    let forest = session.query_handle().connected_components();
    assert!(
        Referee::same_partition(&forest.component, &want),
        "spilled partition diverged from the DSU referee"
    );
    drop(session);
    let _ = std::fs::remove_dir_all(&dir);
}
