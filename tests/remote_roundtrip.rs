//! Remote-worker round trip over real TCP on an ephemeral loopback port:
//! an in-process `WorkerServer` answers batches for a coordinator in
//! `WorkerKind::Remote` mode, and the result must match the
//! single-machine `NativeWorker` path exactly.  Network traffic is
//! metered at the `net::Message` framing layer and checked against the
//! Theorem 5.2 constant-factor bound.

use std::sync::atomic::Ordering;

use landscape::connectivity::dsu::Dsu;
use landscape::coordinator::{CoordinatorConfig, WorkerKind};
use landscape::net::Message;
use landscape::sketch::params::SketchParams;
use landscape::stream::dynamify::Dynamify;
use landscape::stream::erdos::ErdosRenyi;
use landscape::stream::edge_list;
use landscape::worker::remote::{RemoteWorker, ServeOptions, WorkerServer};
use landscape::worker::WorkerBackend;
use landscape::Landscape;

fn same_partition(a: &[u32], b: &[u32]) -> bool {
    let mut fwd = std::collections::HashMap::new();
    let mut bwd = std::collections::HashMap::new();
    for (x, y) in a.iter().zip(b) {
        if *fwd.entry(*x).or_insert(*y) != *y || *bwd.entry(*y).or_insert(*x) != *x {
            return false;
        }
    }
    true
}

fn config(v: u64, addr: String) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.alpha = 1;
    cfg.distributor_threads = 2;
    cfg.use_greedycc = false; // force the sketch path end-to-end
    cfg.worker = WorkerKind::Remote { addrs: vec![addr] };
    cfg
}

#[test]
fn remote_ingest_matches_native_and_obeys_communication_bound() {
    // dense enough that per-vertex leaves clear the γ-flush threshold
    // (3·E[deg] ≈ 229 ≥ γ·capacity ≈ 148 at V=256), so real BATCH/DELTA
    // traffic crosses the wire for the bound to measure
    let v = 256u64;
    let model = ErdosRenyi::new(v, 0.3, 4242);

    // exact reference partition
    let mut dsu = Dsu::new(v as usize);
    for (a, b) in edge_list(&model) {
        dsu.union(a, b);
    }

    // native single-machine run on the same stream
    let mut native_cfg = CoordinatorConfig::for_vertices(v);
    native_cfg.alpha = 1;
    native_cfg.distributor_threads = 2;
    native_cfg.use_greedycc = false;
    let native = Landscape::from_config(native_cfg).unwrap();
    let mut native_ingest = native.ingest_handle();
    native_ingest.ingest_all(Dynamify::new(model, 3)); // ErdosRenyi is Copy
    native_ingest.flush();
    let native_forest = native.query_handle().full_connectivity_query();

    // remote run: in-process TCP worker server on an ephemeral port
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.serve(2));

    let session = Landscape::from_config(config(v, addr)).unwrap();
    let mut ingest = session.ingest_handle();
    ingest.ingest_all(Dynamify::new(model, 3));
    ingest.flush();
    let forest = session.query_handle().full_connectivity_query();

    assert!(
        same_partition(&forest.component, &native_forest.component),
        "remote and native partitions diverge"
    );
    assert!(
        same_partition(&forest.component, &dsu.component_map()),
        "remote partition diverges from the exact reference"
    );

    // Theorem 5.2: network bytes <= (3 + 1/(gamma*alpha)) x stream bytes,
    // metered at the batch/delta layer by the session.
    let m = session.metrics();
    assert!(m.stream_bytes > 0 && m.network_bytes() > 0);
    let bound = (3.0 + 1.0 / (session.config().gamma * session.config().alpha as f64))
        * m.stream_bytes as f64;
    assert!(
        (m.network_bytes() as f64) < bound,
        "network {} exceeds Theorem 5.2 bound {bound}",
        m.network_bytes()
    );

    drop(ingest);
    drop(session); // closes both connections so the server thread exits
    let _ = server_thread.join();
}

/// Kill one of two worker servers mid-stream: the distributor must
/// observe the death, requeue every unacknowledged batch onto the
/// surviving server, and finish with a partition identical to the exact
/// DSU referee — zero batches lost.
#[test]
fn worker_failover_requeues_unacked_batches_with_zero_drops() {
    // dense enough (see above) that every shard ships many batches, so
    // the injected crash is guaranteed to strand some in flight
    let v = 256u64;
    let model = ErdosRenyi::new(v, 0.3, 1717);

    let mut dsu = Dsu::new(v as usize);
    for (a, b) in edge_list(&model) {
        dsu.union(a, b);
    }

    // server A answers 2 batches, then crashes its connection on the
    // next data frame (dropping that frame's batches unanswered);
    // server B stays healthy and absorbs A's distributor after failover
    let flaky = WorkerServer::bind_with(
        "127.0.0.1:0",
        ServeOptions {
            fail_after_batches: Some(2),
            ..Default::default()
        },
    )
    .unwrap();
    let healthy = WorkerServer::bind("127.0.0.1:0").unwrap();
    let flaky_addr = flaky.local_addr().unwrap().to_string();
    let healthy_addr = healthy.local_addr().unwrap().to_string();
    let flaky_thread = std::thread::spawn(move || flaky.serve(1));
    let healthy_thread = std::thread::spawn(move || healthy.serve(2));

    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.alpha = 1;
    cfg.distributor_threads = 2;
    cfg.use_greedycc = false;
    cfg.remote_window = 8;
    cfg.worker = WorkerKind::Remote {
        addrs: vec![flaky_addr, healthy_addr],
    };
    let session = Landscape::from_config(cfg).unwrap();
    let mut ingest = session.ingest_handle();
    ingest.ingest_all(Dynamify::new(model, 3));
    ingest.flush();
    let forest = session.query_handle().full_connectivity_query();

    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0, "failover must not lose a single batch");
    assert!(
        m.worker_failures >= 1,
        "the injected crash must surface as a worker failure"
    );
    assert!(
        m.batches_requeued >= 1,
        "the crash strands unacknowledged batches that must be requeued"
    );
    assert!(
        same_partition(&forest.component, &dsu.component_map()),
        "partition after failover diverges from the exact reference"
    );

    drop(ingest);
    drop(session); // closes the surviving connections so the servers exit
    let _ = flaky_thread.join();
    let _ = healthy_thread.join();
}

#[test]
fn remote_worker_meters_exact_wire_bytes() {
    let v = 64u64;
    let params = SketchParams::for_vertices(v);
    let server = WorkerServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.serve(1));

    let graph_seed = 99u64;
    let k = 2u32;
    let remote = RemoteWorker::connect(&addr, params, graph_seed, k).unwrap();

    let others: Vec<u32> = vec![1, 2, 3, 60];
    let mut out = Vec::new();
    remote.process(0, &others, &mut out).unwrap();
    assert_eq!(out.len(), params.words() * k as usize);

    // sent = HELLO handshake + one BATCH frame, byte-exact
    let hello = Message::Hello {
        vertices: v,
        columns: params.columns,
        graph_seed,
        k,
        threshold: 0,
    };
    let batch = Message::Batch {
        vertex: 0,
        others: others.clone(),
    };
    assert_eq!(
        remote.bytes_sent.load(Ordering::Relaxed),
        hello.wire_bytes() + batch.wire_bytes()
    );

    // received = one DELTA frame carrying k sketch copies, byte-exact
    let delta = Message::Delta {
        vertex: 0,
        delta: out.clone(),
    };
    assert_eq!(
        remote.bytes_received.load(Ordering::Relaxed),
        delta.wire_bytes()
    );

    remote.shutdown();
    server_thread.join().unwrap().unwrap();
}
