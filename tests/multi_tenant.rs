//! Multi-tenant serving-layer acceptance: N logical graphs over one
//! shared pipeline must behave exactly like N independent sessions.
//!
//! * Property: 3 tenants ingest disjoint random insert/delete streams
//!   **concurrently** over one fabric; each tenant's queried partition
//!   must equal its own from-scratch DSU referee (which any
//!   cross-tenant bleed would break), with per-tenant
//!   `batches_dropped == 0` and exact per-tenant update accounting.
//! * Quota isolation: a saturating tenant collects metered
//!   `quota_rejections` (refusals carry a retry-after hint, its
//!   admitted updates are never dropped) while an idle tenant's
//!   snapshot query still returns inside a promptness bound.

use landscape::baseline::Referee;
use landscape::connectivity::dsu::Dsu;
use landscape::serve::{Fabric, FabricConfig, TenantConfig};
use landscape::stream::update::Update;
use landscape::util::rng::Xoshiro256;
use landscape::util::testkit::{arb_edge, Cases};

fn fabric(v: u64) -> Fabric {
    let mut cfg = FabricConfig::for_vertices(v);
    cfg.base.alpha = 1;
    cfg.base.distributor_threads = 2;
    // small log so producer drains genuinely interleave
    cfg.update_log_capacity = 16;
    Fabric::spawn(cfg).unwrap()
}

/// A valid random insert/delete stream plus its final live edge set
/// (same construction as tests/concurrent_ingest.rs).
fn random_stream(rng: &mut Xoshiro256, v: u64) -> (Vec<Update>, Vec<(u32, u32)>) {
    let mut live = std::collections::BTreeSet::new();
    let mut stream = Vec::new();
    for _ in 0..(60 + rng.next_below(120)) {
        if !live.is_empty() && rng.next_below(3) == 0 {
            let i = rng.next_below(live.len() as u64) as usize;
            let e: (u32, u32) = *live.iter().nth(i).unwrap();
            live.remove(&e);
            stream.push(Update::delete(e.0, e.1));
        } else {
            let e = arb_edge(rng, v);
            if live.insert(e) {
                stream.push(Update::insert(e.0, e.1));
            }
        }
    }
    (stream, live.into_iter().collect())
}

#[test]
fn three_concurrent_tenants_match_their_referees() {
    Cases::new(4).run(|rng| {
        let v = 16 + rng.next_below(48);
        let f = fabric(v);
        // three tenants over the SAME logical id range: any leak of one
        // tenant's edges into another's sketches moves that tenant off
        // its referee partition
        let mut tenants = Vec::new();
        for name in ["a", "b", "c"] {
            let id = f.create_tenant(TenantConfig::named(name, v)).unwrap();
            let (stream, live) = random_stream(rng, v);
            let mut d = Dsu::from_edges(v as usize, &live);
            let want = d.component_map();
            tenants.push((id, stream, want));
        }
        std::thread::scope(|scope| {
            for (id, stream, _) in &tenants {
                let mut handle = f.ingest_handle(*id).unwrap();
                scope.spawn(move || {
                    for &u in stream {
                        handle.ingest(u);
                    }
                    // drop publishes the tail
                });
            }
        });
        for (id, stream, want) in &tenants {
            f.flush(*id).unwrap();
            let forest = f.connected_components(*id).unwrap();
            assert!(
                Referee::same_partition(&forest.component, want),
                "tenant {id} diverges from its own DSU referee"
            );
            let m = f.tenant_metrics(*id).unwrap();
            assert_eq!(
                m.updates_ingested,
                stream.len() as u64,
                "tenant {id} update accounting"
            );
            assert_eq!(m.batches_dropped, 0, "tenant {id} dropped batches");
            assert_eq!(m.quota_rejections, 0, "tenant {id} was never throttled");
        }
        let fm = f.metrics();
        assert_eq!(fm.tenants.len(), 3);
        assert_eq!(fm.fabric.tenants_active, 3);
        assert_eq!(
            fm.fabric.batches_dropped, 0,
            "no orphaned work at the fabric level either"
        );
    });
}

#[test]
fn saturating_tenant_is_throttled_while_idle_tenant_stays_prompt() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};

    let v = 256u64;
    let f = fabric(v);
    let hot = f
        .create_tenant(TenantConfig::named("hot", v).quota(2_000, 500))
        .unwrap();
    let idle = f.create_tenant(TenantConfig::named("idle", v)).unwrap();

    // the idle tenant's graph: an 8-cycle, published and settled before
    // the hot tenant starts hammering
    let mut ih = f.ingest_handle(idle).unwrap();
    for i in 0..8u32 {
        ih.ingest(Update::insert(i, (i + 1) % 8));
    }
    drop(ih);
    f.flush(idle).unwrap();

    let stop = AtomicBool::new(false);
    let (latency, forest, hot_m) = std::thread::scope(|scope| {
        let stop = &stop;
        let fref = &f;
        let saturator = scope.spawn(move || {
            let mut handle = fref.ingest_handle(hot).unwrap();
            let mut admitted = 0u64;
            let mut rejected = 0u64;
            let mut i = 0u32;
            // hammer 100-update chunks through admission far above the
            // 2k/s rate: the bucket refuses most of them (each refusal
            // metered, chunk NOT applied — no silent loss), and the few
            // admitted ones flow through the shared pipeline
            while !stop.load(Ordering::Acquire) {
                match fref.admit(hot, 100).unwrap() {
                    Ok(()) => {
                        for _ in 0..100 {
                            let (a, b) = (i % v as u32, (i + 1) % v as u32);
                            handle.ingest(Update::insert(a, b));
                            i += 1;
                        }
                        handle.flush();
                        admitted += 100;
                    }
                    Err(_backoff) => rejected += 1,
                }
            }
            handle.flush();
            (admitted, rejected)
        });

        // give the saturator a moment to exhaust its burst
        while !stop.load(Ordering::Acquire) {
            let m = f.tenant_metrics(hot).unwrap();
            if m.quota_rejections > 0 {
                break;
            }
            std::thread::yield_now();
        }

        // the promptness claim: with the neighbor tenant saturating its
        // quota, the idle tenant's snapshot query is bounded by its OWN
        // in-flight work (none) — not by the hot tenant's backlog
        let t0 = Instant::now();
        let snap = f.query_handle(idle).unwrap().snapshot();
        let forest = snap.connected_components();
        let latency = t0.elapsed();

        stop.store(true, Ordering::Release);
        let (admitted, rejected) = saturator.join().unwrap();
        assert!(rejected > 0, "the quota must actually refuse chunks");
        let hot_m = f.tenant_metrics(hot).unwrap();
        assert_eq!(
            hot_m.updates_ingested, admitted,
            "every admitted update ingested, every refused chunk withheld"
        );
        (latency, forest, hot_m)
    });

    let bound = Duration::from_secs(10);
    assert!(
        latency < bound,
        "idle tenant's snapshot took {latency:?} under a hot neighbor"
    );
    // the idle tenant's answer is its own graph: one 8-cycle plus
    // singletons, untouched by the hot tenant's chain over the same ids
    assert_eq!(forest.num_components(), (v as usize - 8) + 1);
    assert_eq!(hot_m.batches_dropped, 0, "throttling must not drop batches");
    assert!(hot_m.quota_rejections > 0, "rejections are metered");
    let idle_m = f.tenant_metrics(idle).unwrap();
    assert_eq!(idle_m.quota_rejections, 0, "the idle tenant is never throttled");
    assert_eq!(idle_m.batches_dropped, 0);
    assert_eq!(idle_m.updates_ingested, 8);
}
