// Fixture: sketch/store.rs is the whitelisted home of relaxed atomics —
// the single-writer XOR merge kernels need no per-site justification.

pub fn merge_word(slot: &core::sync::atomic::AtomicU64, delta: u64) {
    slot.fetch_xor(delta, core::sync::atomic::Ordering::Relaxed);
}
