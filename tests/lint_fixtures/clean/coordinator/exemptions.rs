// Fixture: every rule exemption the lint must honor, in one hot-path
// file.  This tree must lint clean.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

pub struct Q {
    state: Mutex<u64>,
    cv: Condvar,
    // lint: allow(relaxed-ordering) — statistics counter, carries no
    // synchronization role; readers tolerate stale values
    hits: AtomicU64,
}

impl Q {
    pub fn poll(&self) -> u64 {
        // the lock-poisoning idiom is exempt: propagating a panic that
        // happened while the lock was held is the invariant
        let mut g = self.state.lock().unwrap();
        let (g2, _timeout) = self
            .cv
            .wait_timeout(g, Duration::from_millis(50))
            .unwrap();
        g = g2;
        self.hits.fetch_add(1, Ordering::Relaxed); // lint: allow(relaxed-ordering) — stats only
        *g
    }

    pub fn spin_hint(&self) {
        // lint: allow(thread-sleep) — test-rig backoff path, bounded at 1ms
        std::thread::sleep(Duration::from_millis(1));
    }

    pub fn len_of(&self, s: &str) -> usize {
        // lint: allow(hot-path-unwrap) — s is validated by the caller, so a failure here is a programming error worth a loud panic
        s.parse::<usize>().unwrap()
    }

    pub fn strings_do_not_match(&self) -> &'static str {
        // patterns inside string literals must never fire
        "Ordering::Relaxed eprintln! .unwrap() thread::sleep"
    }

    pub fn raw_strings_either(&self) -> &'static str {
        r#"{"eprintln!": ".unwrap()", "ordering": "Ordering::Relaxed"}"#
    }

    pub fn char_literals(&self, c: char) -> bool {
        c == '{' || c == '}' || c == '\''
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_blocks_are_fully_exempt() {
        // unwrap, expect, sleep: all fine under #[cfg(test)]
        let n: u32 = "7".parse().unwrap();
        let m: u32 = "8".parse().expect("parses");
        std::thread::sleep(std::time::Duration::from_millis(0));
        assert_eq!(n + m, 15);
    }
}
