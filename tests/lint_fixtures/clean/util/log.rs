// Fixture: util/log.rs is the logging facility itself — the one place
// a bare eprintln! is the implementation, not a bypass.

pub fn emit(line: &str) {
    eprintln!("{line}");
}
