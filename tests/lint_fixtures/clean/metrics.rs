//! Fixture: a file on the missing-docs required list that carries the
//! attribute, as CI expects.
#![deny(missing_docs)]

/// A documented item.
pub fn documented() {}
