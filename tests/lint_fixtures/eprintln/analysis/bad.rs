// Fixture: one bare eprintln! outside util/log.rs.

pub fn report(err: &str) {
    eprintln!("landscape: {err}");
}
