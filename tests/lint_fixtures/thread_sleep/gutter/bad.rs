// Fixture: one unjustified thread::sleep on a hot-path module.

use std::time::Duration;

pub fn backoff() {
    std::thread::sleep(Duration::from_millis(5));
}
