// Fixture: one unjustified relaxed atomic outside sketch/store.rs.

use std::sync::atomic::{AtomicU64, Ordering};

pub fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}
