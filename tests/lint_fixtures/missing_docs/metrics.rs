// Fixture: metrics.rs is on the CI #![deny(missing_docs)] list but the
// attribute is absent here.

pub fn undocumented_surface() {}
