// Fixture: one unjustified unwrap on a hot-path module (not the
// lock-poisoning idiom).

pub fn shard_of(s: &str) -> usize {
    s.parse::<usize>().unwrap()
}
