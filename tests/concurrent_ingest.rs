//! Concurrent multi-producer ingest: a randomly generated valid
//! insert/delete stream is randomly split across 1 / 2 / 4
//! `IngestHandle`s driven from separate threads, for both the pipeline
//! hypertree and the gutter (ablation) buffer.  The final queried
//! partition must equal the from-scratch DSU referee every time, with
//! `batches_dropped == 0` — no update may be lost or double-applied no
//! matter how the producers' logs interleave.

use landscape::baseline::Referee;
use landscape::connectivity::dsu::Dsu;
use landscape::coordinator::BufferKind;
use landscape::stream::update::Update;
use landscape::util::rng::Xoshiro256;
use landscape::util::testkit::{arb_edge, Cases};
use landscape::Landscape;

fn session(v: u64, buffer: BufferKind) -> Landscape {
    Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .buffer(buffer)
        // small log so drains genuinely interleave across producers
        .update_log_capacity(16)
        .build()
        .unwrap()
}

/// A valid random insert/delete stream plus its final live edge set.
fn random_stream(rng: &mut Xoshiro256, v: u64) -> (Vec<Update>, Vec<(u32, u32)>) {
    let mut live = std::collections::BTreeSet::new();
    let mut stream = Vec::new();
    for _ in 0..(60 + rng.next_below(120)) {
        if !live.is_empty() && rng.next_below(3) == 0 {
            let i = rng.next_below(live.len() as u64) as usize;
            let e: (u32, u32) = *live.iter().nth(i).unwrap();
            live.remove(&e);
            stream.push(Update::delete(e.0, e.1));
        } else {
            let e = arb_edge(rng, v);
            if live.insert(e) {
                stream.push(Update::insert(e.0, e.1));
            }
        }
    }
    (stream, live.into_iter().collect())
}

/// Randomly deal the stream over `producers` threads (order preserved
/// within each producer, arbitrary interleaving between them), ingest
/// concurrently, and return the queried partition.
fn concurrent_partition(
    rng: &mut Xoshiro256,
    v: u64,
    updates: &[Update],
    producers: usize,
    buffer: BufferKind,
) -> Vec<u32> {
    let mut chunks: Vec<Vec<Update>> = vec![Vec::new(); producers];
    for &u in updates {
        chunks[rng.next_below(producers as u64) as usize].push(u);
    }
    let session = session(v, buffer);
    std::thread::scope(|scope| {
        for chunk in chunks {
            let mut handle = session.ingest_handle();
            scope.spawn(move || {
                for u in chunk {
                    handle.ingest(u);
                }
                // handle drop publishes the tail
            });
        }
    });
    assert_eq!(session.pending_producers(), 0, "all producers published");
    let forest = session.query_handle().connected_components();
    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0, "no update may vanish at the queue");
    assert_eq!(m.handles_spawned, producers as u64);
    assert_eq!(m.updates_ingested, updates.len() as u64);
    forest.component
}

fn check_buffer(buffer: BufferKind) {
    Cases::new(6).run(|rng| {
        let v = 8 + rng.next_below(40);
        let (updates, live) = random_stream(rng, v);
        let mut d = Dsu::from_edges(v as usize, &live);
        let want = d.component_map();
        for producers in [1usize, 2, 4] {
            let got = concurrent_partition(rng, v, &updates, producers, buffer);
            assert!(
                Referee::same_partition(&got, &want),
                "{buffer:?} with {producers} producers diverges from the DSU referee",
            );
        }
    });
}

#[test]
fn random_splits_match_dsu_referee_hypertree() {
    check_buffer(BufferKind::Hypertree);
}

#[test]
fn random_splits_match_dsu_referee_gutter() {
    check_buffer(BufferKind::Gutter);
}

/// The acceptance scenario at a fixed seed: a denser stream through 4
/// producers must reproduce the single-producer partition exactly.
#[test]
fn four_producer_partition_is_identical_to_single_producer() {
    let v = 128u64;
    let mut rng = Xoshiro256::new(0xC0FFEE);
    let (updates, live) = {
        // build a denser stream than the property cases
        let mut live = std::collections::BTreeSet::new();
        let mut stream = Vec::new();
        for _ in 0..2000 {
            if !live.is_empty() && rng.next_below(3) == 0 {
                let i = rng.next_below(live.len() as u64) as usize;
                let e: (u32, u32) = *live.iter().nth(i).unwrap();
                live.remove(&e);
                stream.push(Update::delete(e.0, e.1));
            } else {
                let e = arb_edge(&mut rng, v);
                if live.insert(e) {
                    stream.push(Update::insert(e.0, e.1));
                }
            }
        }
        (stream, live.into_iter().collect::<Vec<(u32, u32)>>())
    };
    let mut d = Dsu::from_edges(v as usize, &live);
    let want = d.component_map();

    let single = concurrent_partition(&mut rng, v, &updates, 1, BufferKind::Hypertree);
    let quad = concurrent_partition(&mut rng, v, &updates, 4, BufferKind::Hypertree);
    assert!(Referee::same_partition(&single, &want));
    assert!(Referee::same_partition(&quad, &single));
}
