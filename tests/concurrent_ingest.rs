//! Concurrent multi-producer ingest: a randomly generated valid
//! insert/delete stream is randomly split across 1 / 2 / 4
//! `IngestHandle`s driven from separate threads, for both the pipeline
//! hypertree and the gutter (ablation) buffer.  The final queried
//! partition must equal the from-scratch DSU referee every time, with
//! `batches_dropped == 0` — no update may be lost or double-applied no
//! matter how the producers' logs interleave.

use landscape::baseline::Referee;
use landscape::connectivity::dsu::Dsu;
use landscape::coordinator::BufferKind;
use landscape::stream::update::Update;
use landscape::util::rng::Xoshiro256;
use landscape::util::testkit::{arb_edge, churn_chord, cycle_graph, Cases};
use landscape::Landscape;

fn session(v: u64, buffer: BufferKind) -> Landscape {
    Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .buffer(buffer)
        // small log so drains genuinely interleave across producers
        .update_log_capacity(16)
        .build()
        .unwrap()
}

/// A valid random insert/delete stream plus its final live edge set.
fn random_stream(rng: &mut Xoshiro256, v: u64) -> (Vec<Update>, Vec<(u32, u32)>) {
    let mut live = std::collections::BTreeSet::new();
    let mut stream = Vec::new();
    for _ in 0..(60 + rng.next_below(120)) {
        if !live.is_empty() && rng.next_below(3) == 0 {
            let i = rng.next_below(live.len() as u64) as usize;
            let e: (u32, u32) = *live.iter().nth(i).unwrap();
            live.remove(&e);
            stream.push(Update::delete(e.0, e.1));
        } else {
            let e = arb_edge(rng, v);
            if live.insert(e) {
                stream.push(Update::insert(e.0, e.1));
            }
        }
    }
    (stream, live.into_iter().collect())
}

/// Randomly deal the stream over `producers` threads (order preserved
/// within each producer, arbitrary interleaving between them), ingest
/// concurrently, and return the queried partition.
fn concurrent_partition(
    rng: &mut Xoshiro256,
    v: u64,
    updates: &[Update],
    producers: usize,
    buffer: BufferKind,
) -> Vec<u32> {
    let mut chunks: Vec<Vec<Update>> = vec![Vec::new(); producers];
    for &u in updates {
        chunks[rng.next_below(producers as u64) as usize].push(u);
    }
    let session = session(v, buffer);
    std::thread::scope(|scope| {
        for chunk in chunks {
            let mut handle = session.ingest_handle();
            scope.spawn(move || {
                for u in chunk {
                    handle.ingest(u);
                }
                // handle drop publishes the tail
            });
        }
    });
    assert_eq!(session.pending_producers(), 0, "all producers published");
    let forest = session.query_handle().connected_components();
    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0, "no update may vanish at the queue");
    assert_eq!(m.handles_spawned, producers as u64);
    assert_eq!(m.updates_ingested, updates.len() as u64);
    forest.component
}

fn check_buffer(buffer: BufferKind) {
    Cases::new(6).run(|rng| {
        let v = 8 + rng.next_below(40);
        let (updates, live) = random_stream(rng, v);
        let mut d = Dsu::from_edges(v as usize, &live);
        let want = d.component_map();
        for producers in [1usize, 2, 4] {
            let got = concurrent_partition(rng, v, &updates, producers, buffer);
            assert!(
                Referee::same_partition(&got, &want),
                "{buffer:?} with {producers} producers diverges from the DSU referee",
            );
        }
    });
}

#[test]
fn random_splits_match_dsu_referee_hypertree() {
    check_buffer(BufferKind::Hypertree);
}

#[test]
fn random_splits_match_dsu_referee_gutter() {
    check_buffer(BufferKind::Gutter);
}

/// Liveness regression (the epoch-barrier redesign's acceptance
/// scenario): a global connectivity query issued during sustained,
/// never-idle 4-producer ingest must return promptly — bounded by the
/// work in flight at cut time, not by stream length — and match the
/// DSU referee.
///
/// Under the retired `wait_idle` barrier this hung: the query waited
/// for an instant of global pipeline idleness, and four producers
/// flushing every iteration never provide one.
///
/// Correctness setup: a base graph of disjoint cycles is published
/// first; the churn phase then inserts/deletes only *chords* inside
/// those cycles (each producer owns a disjoint chord set, toggled
/// strictly insert→delete).  At every possible merge state each chord
/// is either present or absent, and either way the partition equals the
/// base partition — so the one-sided snapshot guarantee ("covers all
/// updates published before the cut, may include later ones") still
/// pins the full answer.
#[test]
fn query_under_sustained_load_returns_promptly_and_correctly() {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    let producers = 4usize;
    let cycles = 8u32;
    let span = 16u32; // vertices per cycle
    let v = (cycles * span) as u64;

    let session = Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        // no GreedyCC: its amortized log drains share a lock with the
        // query path, which would make producers pause behind a running
        // query — with it off, the producers NEVER stop publishing, so
        // the retired idle-waiting barrier would hang here forever
        .greedycc(false)
        .build()
        .unwrap();

    // base graph: `cycles` disjoint cycles (removing any one chord can
    // never disconnect anything)
    let base = cycle_graph(cycles, span);
    let mut d = Dsu::new(v as usize);
    for u in &base {
        d.union(u.u, u.v);
    }
    let want = d.component_map();

    let stop = AtomicBool::new(false);
    let published = AtomicUsize::new(0);
    let results = std::thread::scope(|scope| {
        for p in 0..producers {
            let mut handle = session.ingest_handle();
            let chunk: Vec<Update> = base
                .iter()
                .copied()
                .skip(p)
                .step_by(producers)
                .collect();
            // producer p toggles its own disjoint in-cycle chord set
            let chords: Vec<(u32, u32)> = (0..cycles)
                .map(|c| churn_chord(c * span, p, span))
                .collect();
            let stop = &stop;
            let published = &published;
            scope.spawn(move || {
                for u in chunk {
                    handle.ingest(u);
                }
                handle.flush();
                published.fetch_add(1, Ordering::Release);
                // sustained full-rate phase: never idle until told to
                // stop, flushing every round so the shared pipeline
                // (queues + in-flight batches) is continuously busy
                let mut i = 0usize;
                while !stop.load(Ordering::Acquire) {
                    let (a, b) = chords[i % chords.len()];
                    handle.ingest(Update::insert(a, b));
                    handle.ingest(Update::delete(a, b));
                    handle.flush();
                    i += 1;
                }
            });
        }

        // wait until every producer has published the base graph (the
        // churn keeps running the whole time)
        while published.load(Ordering::Acquire) < producers {
            std::thread::sleep(Duration::from_millis(1));
        }

        // run both query flavours while the load is live; assert only
        // after stopping the producers, so a failure can't wedge the
        // scope behind still-spinning churn threads
        let t0 = Instant::now();
        let forest = session.query_handle().full_connectivity_query();
        let direct_latency = t0.elapsed();

        // same via a pinned snapshot: cheap cut, bounded wait, correct
        let t0 = Instant::now();
        let snap = session.query_handle().snapshot();
        let sf = snap.connected_components();
        let snap_latency = t0.elapsed();

        stop.store(true, Ordering::Release);
        (forest, direct_latency, sf, snap_latency)
    });

    let (forest, direct_latency, sf, snap_latency) = results;

    // the old barrier could wait forever (the pipeline is never idle);
    // the cut barrier is bounded by in-flight work at cut time, so even
    // a generous ceiling proves the hang cannot recur
    let deadline = Duration::from_secs(20);
    assert!(
        direct_latency < deadline,
        "query under sustained load took {direct_latency:?}"
    );
    assert!(
        snap_latency < deadline,
        "snapshot query under sustained load took {snap_latency:?}"
    );
    assert!(
        Referee::same_partition(&forest.component, &want),
        "query under sustained load diverges from the DSU referee"
    );
    assert!(
        Referee::same_partition(&sf.component, &want),
        "snapshot under sustained load diverges from the DSU referee"
    );

    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0, "no update may vanish at the queue");
    assert!(m.cuts_taken >= 2, "both queries must have taken cuts");
    assert!(
        m.epoch_current >= 2,
        "the epoch must advance with every cut (got {})",
        m.epoch_current
    );
}

/// The acceptance scenario at a fixed seed: a denser stream through 4
/// producers must reproduce the single-producer partition exactly.
#[test]
fn four_producer_partition_is_identical_to_single_producer() {
    let v = 128u64;
    let mut rng = Xoshiro256::new(0xC0FFEE);
    let (updates, live) = {
        // build a denser stream than the property cases
        let mut live = std::collections::BTreeSet::new();
        let mut stream = Vec::new();
        for _ in 0..2000 {
            if !live.is_empty() && rng.next_below(3) == 0 {
                let i = rng.next_below(live.len() as u64) as usize;
                let e: (u32, u32) = *live.iter().nth(i).unwrap();
                live.remove(&e);
                stream.push(Update::delete(e.0, e.1));
            } else {
                let e = arb_edge(&mut rng, v);
                if live.insert(e) {
                    stream.push(Update::insert(e.0, e.1));
                }
            }
        }
        (stream, live.into_iter().collect::<Vec<(u32, u32)>>())
    };
    let mut d = Dsu::from_edges(v as usize, &live);
    let want = d.component_map();

    let single = concurrent_partition(&mut rng, v, &updates, 1, BufferKind::Hypertree);
    let quad = concurrent_partition(&mut rng, v, &updates, 4, BufferKind::Hypertree);
    assert!(Referee::same_partition(&single, &want));
    assert!(Referee::same_partition(&quad, &single));
}
