//! Three-layer composition proof: the AOT-compiled Pallas kernel
//! (python L1/L2 → HLO text → PJRT) must be *bit-identical* to the
//! native Rust CameoSketch kernel, and a full coordinator run in XLA
//! worker mode must produce correct connectivity.
//!
//! Compiled only with `--features xla` (the PJRT path needs the external
//! `xla` crate); at runtime each test additionally skips with a clear
//! message unless `make artifacts` has produced `artifacts/manifest.json`.
#![cfg(feature = "xla")]

use std::path::PathBuf;

use landscape::connectivity::dsu::Dsu;
use landscape::coordinator::{CoordinatorConfig, WorkerKind};
use landscape::runtime::Runtime;
use landscape::sketch::params::{encode_edge, SketchParams};
use landscape::sketch::seeds::SketchSeeds;
use landscape::sketch::CameoSketch;
use landscape::stream::dynamify::Dynamify;
use landscape::stream::erdos::ErdosRenyi;
use landscape::stream::{edge_list, EdgeModel};
use landscape::util::rng::Xoshiro256;
use landscape::Landscape;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"));
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "skipping XLA parity test: {} missing — run `make artifacts`",
            dir.join("manifest.json").display()
        );
        return None;
    }
    Some(dir)
}

#[test]
fn xla_delta_bit_identical_to_native() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let v = 1u64 << 10;
    let params = SketchParams::for_vertices(v);
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_delta_executable(&dir, params).unwrap();

    let mut rng = Xoshiro256::new(0xABCD);
    for trial in 0..5 {
        let graph_seed = rng.next_u64();
        let seeds = SketchSeeds::derive(&params, graph_seed);
        let n = (rng.next_below(600) + 1) as usize; // exercises chunking (B=512)
        let indices: Vec<u64> = (0..n)
            .map(|_| {
                let a = rng.next_below(v - 1) as u32;
                let b = a + 1 + rng.next_below(v - 1 - a as u64) as u32;
                encode_edge(a, b, v)
            })
            .collect();

        let xla = exe.compute_delta(&indices, &seeds).unwrap();
        let native = CameoSketch::delta_of_batch(&params, &seeds, &indices);
        assert_eq!(xla, native, "trial {trial}: XLA and native deltas diverged");
    }
}

#[test]
fn xla_delta_empty_and_padding_cases() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let v = 1u64 << 10;
    let params = SketchParams::for_vertices(v);
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_delta_executable(&dir, params).unwrap();
    let seeds = SketchSeeds::derive(&params, 7);

    // empty batch → all-zero delta
    let empty = exe.compute_delta(&[], &seeds).unwrap();
    assert!(empty.iter().all(|&w| w == 0));

    // exact batch-size boundary (512) vs 513 (forces a second chunk)
    let idx: Vec<u64> = (0..513)
        .map(|i| encode_edge(0, 1 + (i % (v as u32 - 1)), v))
        .collect();
    let a = exe.compute_delta(&idx[..512], &seeds).unwrap();
    let b = exe.compute_delta(&idx[..513], &seeds).unwrap();
    let native_a = CameoSketch::delta_of_batch(&params, &seeds, &idx[..512]);
    let native_b = CameoSketch::delta_of_batch(&params, &seeds, &idx[..513]);
    assert_eq!(a, native_a);
    assert_eq!(b, native_b);
}

#[test]
fn coordinator_in_xla_mode_computes_correct_components() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let v = 1u64 << 8; // shares the L14/R22 artifact shape
    let model = ErdosRenyi::new(v, 0.1, 123);
    let mut want = Dsu::new(v as usize);
    for (a, b) in edge_list(&model) {
        want.union(a, b);
    }

    let mut cfg = CoordinatorConfig::for_vertices(v);
    cfg.alpha = 1;
    cfg.distributor_threads = 1;
    cfg.worker = WorkerKind::Xla { artifact_dir: dir };
    cfg.use_greedycc = false;
    let session = Landscape::from_config(cfg).unwrap();
    let mut ingest = session.ingest_handle();
    ingest.ingest_all(Dynamify::new(model, 3));
    ingest.flush();
    let forest = session.query_handle().connected_components();

    for a in 0..v as u32 {
        for b in (a + 1)..(v as u32).min(a + 4) {
            assert_eq!(
                forest.connected(a, b),
                want.connected(a, b),
                "pair ({a},{b})"
            );
        }
    }
    assert_eq!(forest.num_components(), want.num_components());
}

#[test]
fn artifact_covers_every_example_scale() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let manifest = landscape::runtime::Manifest::load(&dir).unwrap();
    for p in [8u32, 10, 11, 12, 13, 14, 16] {
        let params = SketchParams::for_vertices(1 << p);
        assert!(
            manifest.find(&params).is_some(),
            "missing artifact for V=2^{p}"
        );
    }
}

#[test]
fn xla_worker_throughput_is_reported() {
    // not a perf assertion — just exercises the worker-mode timing path
    // so EXPERIMENTS.md has a measured XLA-vs-native number
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let v = 1u64 << 10;
    let params = SketchParams::for_vertices(v);
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_delta_executable(&dir, params).unwrap();
    let seeds = SketchSeeds::derive(&params, 3);
    let indices: Vec<u64> = (0..512u32).map(|i| encode_edge(i, i + 1, v)).collect();

    let (_, xla_secs) = landscape::util::timer::timed(|| {
        exe.compute_delta(&indices, &seeds).unwrap()
    });
    let (_, native_secs) = landscape::util::timer::timed(|| {
        CameoSketch::delta_of_batch(&params, &seeds, &indices)
    });
    eprintln!(
        "batch=512 V=2^10: xla {:.3} ms, native {:.3} ms ({}x)",
        xla_secs * 1e3,
        native_secs * 1e3,
        (xla_secs / native_secs.max(1e-9)) as u64
    );
}
