//! End-to-end exercise of the tiered query path: random insert / delete
//! / query interleavings through the full coordinator pipeline must
//! produce partitions identical to a from-scratch DSU reference *no
//! matter which tier answered*, and the tier accounting must add up.

use landscape::baseline::Referee;
use landscape::connectivity::dsu::Dsu;
use landscape::coordinator::QueryTier;
use landscape::stream::update::Update;
use landscape::stream::VecStream;
use landscape::util::testkit::{arb_edge, Cases};
use landscape::Landscape;

fn small_session(v: u64) -> Landscape {
    Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .build()
        .unwrap()
}

fn same_partition(a: &[u32], b: &[u32]) -> bool {
    Referee::same_partition(a, b)
}

#[test]
fn random_interleavings_match_dsu_reference_on_every_tier() {
    Cases::new(8).run(|rng| {
        let v = 8 + rng.next_below(40);
        let session = small_session(v);
        let mut producer = session.ingest_handle();
        let reader = session.query_handle();
        let mut live: std::collections::BTreeSet<(u32, u32)> =
            std::collections::BTreeSet::new();
        let mut queries = 0u64;

        for step in 0..(40 + rng.next_below(80)) {
            if !live.is_empty() && rng.next_below(4) == 0 {
                // delete a random live edge (may or may not be forest)
                let i = rng.next_below(live.len() as u64) as usize;
                let e = *live.iter().nth(i).unwrap();
                live.remove(&e);
                producer.ingest(Update::delete(e.0, e.1));
            } else {
                let e = arb_edge(rng, v);
                if live.insert(e) {
                    producer.ingest(Update::insert(e.0, e.1));
                }
            }

            if step % 13 == 5 {
                queries += 1;
                producer.flush(); // publish before querying
                let edges: Vec<(u32, u32)> = live.iter().copied().collect();
                let mut d = Dsu::from_edges(v as usize, &edges);
                let forest = reader.connected_components();
                assert!(
                    same_partition(&forest.component, &d.component_map()),
                    "partition diverges at step {step} (tier accounting: {:?})",
                    session.metrics()
                );
            }
        }

        // final query + accounting
        queries += 1;
        producer.flush();
        let edges: Vec<(u32, u32)> = live.iter().copied().collect();
        let mut d = Dsu::from_edges(v as usize, &edges);
        let forest = reader.connected_components();
        assert!(same_partition(&forest.component, &d.component_map()));

        let m = session.metrics();
        // with the accelerator on, tier 2 is never needed: every query is
        // answered by GreedyCC or the partial tier
        assert_eq!(m.queries_full, 0, "tiered path must never fall to full");
        assert_eq!(m.queries_greedy + m.queries_partial, queries);
        // no update may vanish at the queue boundary
        assert_eq!(m.batches_dropped, 0);
    });
}

#[test]
fn non_forest_deletes_keep_the_query_on_tier_zero() {
    let v = 32u64;
    let session = small_session(v);
    let mut producer = session.ingest_handle();
    let reader = session.query_handle();
    let mut updates = Vec::new();
    // a triangle fan: edges (0,i) form the forest, (i,i+1) are cycles
    for i in 1..10u32 {
        updates.push(Update::insert(0, i));
    }
    for i in 1..9u32 {
        updates.push(Update::insert(i, i + 1));
    }
    // delete every cycle edge — none is in the spanning forest
    for i in 1..9u32 {
        updates.push(Update::delete(i, i + 1));
    }
    producer.ingest_all(VecStream::new(v, updates));
    producer.flush();

    assert_eq!(reader.query_plan(), QueryTier::Greedy);
    let before = session.metrics();
    let forest = reader.connected_components();
    let after = session.metrics();

    assert_eq!(after.queries_full, before.queries_full, "no full query");
    assert_eq!(after.queries_full, 0);
    assert_eq!(after.queries_partial, 0, "no partial query either");
    assert_eq!(after.queries_greedy, 1);
    assert_eq!(after.dirty_components, 0);
    assert_eq!(after.batches_dropped, 0);
    assert!(forest.connected(1, 9), "fan stays connected through vertex 0");
}

#[test]
fn forest_delete_partial_query_then_back_to_tier_zero() {
    let v = 64u64;
    let session = small_session(v);
    let mut producer = session.ingest_handle();
    let reader = session.query_handle();
    let mut updates: Vec<Update> = (0..31).map(|i| Update::insert(i, i + 1)).collect();
    updates.push(Update::delete(15, 16)); // forest edge mid-path
    producer.ingest_all(VecStream::new(v, updates));
    producer.flush();

    assert_eq!(reader.query_plan(), QueryTier::Partial);
    let forest = reader.connected_components();
    assert!(forest.connected(0, 15));
    assert!(forest.connected(16, 31));
    assert!(!forest.connected(15, 16));

    let m = session.metrics();
    assert_eq!(m.queries_partial, 1);
    assert_eq!(m.queries_full, 0);
    assert_eq!(m.dirty_components, 1);
    assert_eq!(m.batches_dropped, 0);

    // the partial query re-seeded GreedyCC: next query is free again
    assert_eq!(reader.query_plan(), QueryTier::Greedy);
    let again = reader.connected_components();
    assert_eq!(session.metrics().queries_greedy, 1);
    assert!(!again.connected(15, 16));
}
