//! Hybrid vertex-tier property test: random insert/delete/query
//! interleavings that repeatedly cross the promotion threshold in both
//! directions (promote → demote → promote churn), driven through the
//! full session pipeline from multiple concurrent producers, with
//! snapshot queries taken mid-churn.  Every answer must equal the
//! from-scratch DSU referee exactly, with `batches_dropped == 0`.

use landscape::baseline::Referee;
use landscape::connectivity::dsu::Dsu;
use landscape::stream::update::Update;
use landscape::util::rng::Xoshiro256;
use landscape::util::testkit::{arb_edge, Cases};
use landscape::Landscape;

const THRESHOLD: u32 = 4;
const FLOOR: u32 = 2;

fn hybrid_session(v: u64) -> Landscape {
    Landscape::builder()
        .vertices(v)
        .alpha(1)
        .distributor_threads(2)
        .hybrid_threshold(THRESHOLD)
        .hybrid_demote_floor(FLOOR)
        // small log so producer drains genuinely interleave
        .update_log_capacity(16)
        .build()
        .unwrap()
}

/// A valid random insert/delete stream biased to churn one designated
/// hub vertex across the promotion threshold: phases of hub fan-out
/// inserts (degree climbs past THRESHOLD → promote) alternate with
/// phases that delete the hub's edges (degree falls below FLOOR →
/// demote), with random background edges mixed throughout.
fn churny_stream(rng: &mut Xoshiro256, v: u64, hub: u32) -> (Vec<Update>, Vec<(u32, u32)>) {
    let mut live = std::collections::BTreeSet::new();
    let mut stream = Vec::new();
    let phases = 3 + rng.next_below(3); // 3..6 grow/shrink rounds
    for _ in 0..phases {
        // grow the hub well past the threshold
        let fan = THRESHOLD + 2 + rng.next_below(4) as u32;
        let mut added = 0u32;
        let mut probe = 0u32;
        while added < fan && (probe as u64) < v - 1 {
            let other = (hub + 1 + probe) % v as u32;
            probe += 1;
            if other == hub {
                continue;
            }
            let e = (hub.min(other), hub.max(other));
            if live.insert(e) {
                stream.push(Update::insert(e.0, e.1));
                added += 1;
            }
        }
        // background noise, inserts and deletes
        for _ in 0..rng.next_below(20) {
            if !live.is_empty() && rng.next_below(3) == 0 {
                let i = rng.next_below(live.len() as u64) as usize;
                let e: (u32, u32) = *live.iter().nth(i).unwrap();
                live.remove(&e);
                stream.push(Update::delete(e.0, e.1));
            } else {
                let e = arb_edge(rng, v);
                if live.insert(e) {
                    stream.push(Update::insert(e.0, e.1));
                }
            }
        }
        // strip the hub back down below the demotion floor
        let hub_edges: Vec<(u32, u32)> = live
            .iter()
            .copied()
            .filter(|&(a, b)| a == hub || b == hub)
            .collect();
        for e in hub_edges {
            live.remove(&e);
            stream.push(Update::delete(e.0, e.1));
        }
    }
    (stream, live.into_iter().collect())
}

/// Deal the stream over `producers` threads (order preserved within a
/// producer), take a snapshot query mid-churn from the main thread, and
/// return the final queried partition.
fn churn_partition(
    rng: &mut Xoshiro256,
    v: u64,
    updates: &[Update],
    producers: usize,
) -> (Vec<u32>, landscape::metrics::MetricsSnapshot) {
    let mut chunks: Vec<Vec<Update>> = vec![Vec::new(); producers];
    for &u in updates {
        chunks[rng.next_below(producers as u64) as usize].push(u);
    }
    let session = hybrid_session(v);
    std::thread::scope(|scope| {
        for chunk in chunks {
            let mut handle = session.ingest_handle();
            scope.spawn(move || {
                for u in chunk {
                    handle.ingest(u);
                }
                // handle drop publishes the tail
            });
        }
        // a pinned snapshot taken while producers are mid-churn: it
        // must answer (one-sided coverage) without wedging or panicking
        // while promotions/demotions race underneath
        let snap = session.query_handle().snapshot();
        let _ = snap.connected_components();
    });
    assert_eq!(session.pending_producers(), 0, "all producers published");
    let forest = session.query_handle().connected_components();
    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0, "no update may vanish at the queue");
    (forest.component, m)
}

#[test]
fn hybrid_churn_matches_dsu_referee() {
    Cases::new(6).run(|rng| {
        let v = 24 + rng.next_below(40);
        let hub = rng.next_below(v) as u32;
        let (updates, live) = churny_stream(rng, v, hub);
        let mut d = Dsu::from_edges(v as usize, &live);
        let want = d.component_map();
        for producers in [1usize, 3] {
            let (got, m) = churn_partition(rng, v, &updates, producers);
            assert!(
                Referee::same_partition(&got, &want),
                "hybrid store with {producers} producers diverges from the DSU referee"
            );
            assert_eq!(
                m.vertices_exact + m.vertices_sketched,
                v,
                "every vertex sits in exactly one tier"
            );
        }
    });
}

/// A fixed-seed single-producer run where the promotion/demotion walk is
/// deterministic: the hub must be metered promoting AND demoting, and
/// repeated queries across the churn must stay referee-exact.
#[test]
fn hybrid_churn_meters_promotions_and_demotions() {
    let v = 48u64;
    let hub = 7u32;
    let mut rng = Xoshiro256::new(0x5EED_CAFE);
    let (updates, live) = churny_stream(&mut rng, v, hub);
    let session = hybrid_session(v);
    let mut handle = session.ingest_handle();
    let mid = updates.len() / 2;
    for u in &updates[..mid] {
        handle.ingest(*u);
    }
    handle.flush();
    // mid-churn query: a prefix of the stream is also a valid stream
    let _ = session.query_handle().connected_components();
    for u in &updates[mid..] {
        handle.ingest(*u);
    }
    handle.flush();

    let forest = session.query_handle().connected_components();
    let mut d = Dsu::from_edges(v as usize, &live);
    assert!(
        Referee::same_partition(&forest.component, &d.component_map()),
        "post-churn partition diverges from the DSU referee"
    );
    let m = session.metrics();
    assert_eq!(m.batches_dropped, 0);
    assert!(
        m.promotions > 0,
        "the hub crossed the threshold: promotions must be metered"
    );
    assert!(
        m.demotions > 0,
        "the hub was stripped below the floor: demotions must be metered"
    );
    assert!(
        m.promotions >= m.demotions,
        "each demotion pairs with an earlier promotion (got {} vs {})",
        m.promotions,
        m.demotions
    );
}
