//! Regenerates Fig. 4: the CameoSketch × pipeline-hypertree ablation.
fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let t = landscape::experiments::fig4_ablation(quick);
    landscape::experiments::emit(&t, "fig4_ablation");
}
