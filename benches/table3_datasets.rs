//! Regenerates Table 2 + Table 3: dataset inventory, ingestion rates and
//! communication factors.
fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let t2 = landscape::experiments::table2_datasets(quick);
    landscape::experiments::emit(&t2, "table2_datasets");
    let t3 = landscape::experiments::table3_ingestion(quick);
    landscape::experiments::emit(&t3, "table3_ingestion");
}
