//! Regenerates Table 6: CameoSketch column success probabilities,
//! analytic recurrence vs Monte-Carlo, plus Fig. 1's survey and the
//! App. F.2 correctness trials (the cheap analytic benches).
fn main() {
    let t = landscape::experiments::table6_success_prob();
    landscape::experiments::emit(&t, "table6_success_prob");
    let f1 = landscape::experiments::fig1_survey();
    landscape::experiments::emit(&f1, "fig1_survey");
    let quick = !std::env::args().any(|a| a == "--full");
    let c = landscape::experiments::correctness(quick);
    landscape::experiments::emit(&c, "correctness");
}
