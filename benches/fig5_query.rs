//! Regenerates Fig. 5: GreedyCC query-burst latencies.
fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let t = landscape::experiments::fig5_query_bursts(quick);
    landscape::experiments::emit(&t, "fig5_query_bursts");
}
