//! Regenerates Table 4 (+ Table 5 with --full): k-connectivity scaling.
fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let t = landscape::experiments::table4_kconn(quick);
    landscape::experiments::emit(&t, "table4_kconn");
    if !quick {
        let t5 = landscape::experiments::table5_kconn_all(false);
        landscape::experiments::emit(&t5, "table5_kconn_all");
    }
}
