//! Regenerates Fig. 16: single-machine Landscape vs GraphZeppelin-mode.
fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let t = landscape::experiments::fig16_single_machine(quick);
    landscape::experiments::emit(&t, "fig16_single_machine");
}
