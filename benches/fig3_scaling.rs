//! Regenerates Fig. 3: ingestion rate vs distributed workers, with
//! RAM-bandwidth reference lines.  `cargo bench --bench fig3_scaling`.
fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let t = landscape::experiments::fig3_scaling(quick);
    landscape::experiments::emit(&t, "fig3_scaling");
}
